//! CLI for `c3o-lint`.
//!
//! ```text
//! c3o-lint [--config PATH] [--root PATH] [--json] [--list-suppressed]
//! ```
//!
//! Exit status: 0 when the tree is clean, 1 on any unsuppressed
//! finding, 2 on usage/configuration errors. CI runs
//! `cargo run -p c3o-lint -- --json` from the repository root.

use c3o_lint::{scan_tree, to_json, LintConfig};
use std::path::PathBuf;
use std::process::ExitCode;

/// Default config locations, tried in order relative to the working
/// directory (the second makes `cargo run -p c3o-lint` work from the
/// workspace root without flags).
const CONFIG_CANDIDATES: &[&str] = &["lint.toml", "rust/lint/lint.toml"];

struct Args {
    config: Option<PathBuf>,
    root: Option<PathBuf>,
    json: bool,
    list_suppressed: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        config: None,
        root: None,
        json: false,
        list_suppressed: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--config" => {
                args.config = Some(PathBuf::from(
                    it.next().ok_or("--config requires a path")?,
                ))
            }
            "--root" => {
                args.root = Some(PathBuf::from(it.next().ok_or("--root requires a path")?))
            }
            "--json" => args.json = true,
            "--list-suppressed" => args.list_suppressed = true,
            "--help" | "-h" => {
                println!(
                    "c3o-lint [--config PATH] [--root PATH] [--json] [--list-suppressed]\n\
                     Repo-specific invariant lint; see rust/lint/README.md."
                );
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("c3o-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let config_path = args.config.clone().or_else(|| {
        CONFIG_CANDIDATES
            .iter()
            .map(PathBuf::from)
            .find(|p| p.exists())
    });
    let Some(config_path) = config_path else {
        eprintln!(
            "c3o-lint: no lint.toml found (tried {}); pass --config",
            CONFIG_CANDIDATES.join(", ")
        );
        return ExitCode::from(2);
    };
    let mut cfg = match LintConfig::load(&config_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("c3o-lint: {e}");
            return ExitCode::from(2);
        }
    };
    if let Some(root) = args.root {
        cfg.root = root;
    }
    let result = match scan_tree(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("c3o-lint: {e}");
            return ExitCode::from(2);
        }
    };
    if args.json {
        print!("{}", to_json(&result, args.list_suppressed));
    } else {
        for f in &result.findings {
            println!("{}:{}: {}: {}", f.file, f.line, f.rule, f.message);
        }
        if args.list_suppressed {
            for f in &result.suppressed {
                println!("{}:{}: suppressed {}: {}", f.file, f.line, f.rule, f.message);
            }
        }
        println!(
            "c3o-lint: {} file(s), {} finding(s), {} suppressed",
            result.files_scanned,
            result.findings.len(),
            result.suppressed.len()
        );
    }
    if result.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
