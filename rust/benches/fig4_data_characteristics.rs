//! Bench: regenerate Fig. 4 (influence of key data characteristics on the
//! runtime — linear) and measure the per-sweep simulation cost.

use c3o::cloud::Cloud;
use c3o::figures;
use c3o::util::bench::{black_box, Bench};

fn main() {
    let cloud = Cloud::aws_like();

    let fig = figures::fig4(&cloud, 42);
    println!("{}", fig.render());
    assert!(fig.all_claims_hold(), "Fig. 4 reproduction failed");

    let mut b = Bench::new("fig4_data_characteristics");
    b.run("full_fig4_sweep", || {
        black_box(figures::fig4(&cloud, 42).table.rows.len())
    });
    b.finish();
}
