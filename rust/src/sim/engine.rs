//! The execution engine: turns (machine type, scale-out, stages) into a
//! simulated runtime with a per-stage breakdown.
//!
//! ## Timing model
//!
//! For each stage on a cluster of `n` nodes × `c` vCPUs (relative speed
//! `p`):
//!
//! * **CPU**: per-task CPU work is `cpu_core_s / tasks`; tasks run in
//!   `ceil(tasks / (n·c))` waves, so
//!   `t_cpu = waves · cpu_core_s / (tasks · p)`.
//! * **Disk**: aggregate bandwidth is `n · disk_mb_s` (serial stages: one
//!   node), so `t_disk = (reads + writes + spill traffic) / (n · disk_mb_s)`.
//! * **Network**: an all-to-all shuffle moves `(n-1)/n` of the shuffle
//!   volume across the wire with aggregate bandwidth `n · net_mb_s`:
//!   `t_net = shuffle · (n-1)/n / (n · net_mb_s)`.
//! * **Overlap**: `t = ov · max(t_cpu, t_disk, t_net) + (1-ov) · Σ t_i` —
//!   Spark pipelines I/O with compute imperfectly.
//! * **Memory**: executor memory per node is
//!   `spark_exec_fraction · memory`. If the stage's working set per node
//!   exceeds it, the overflow spills: `2×` the overflow in extra disk
//!   traffic (write + re-read) plus a CPU serialization penalty
//!   proportional to the spilled fraction. Because *each iteration stage
//!   carries the working set*, iterative jobs pay this penalty per
//!   iteration — the paper's SGD/K-Means memory-bottleneck mechanism.
//! * **Overheads**: fixed job startup (driver/JVM/context) plus a
//!   per-stage scheduling barrier that grows mildly with `n`; small jobs
//!   with many stages (PageRank on MB-scale graphs) are dominated by
//!   these terms and thus scale poorly (Fig. 6).
//! * **Variance**: seeded log-normal noise per stage plus occasional
//!   straggler waves; medians over repetitions are stable.

use crate::cloud::MachineType;
use crate::sim::stage::{Stage, StageKind};
use crate::util::rng::Pcg32;

/// Engine tuning constants. Defaults are calibrated so the five workloads
/// reproduce the paper's qualitative results (see `figures::` benches).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Fraction of node RAM available to executor storage+execution
    /// (Spark's unified memory region ≈ 0.6).
    pub exec_mem_fraction: f64,
    /// Fixed job startup in seconds (driver, JVM, YARN negotiation).
    pub job_startup_s: f64,
    /// Per-stage scheduling barrier: `a + b·n` seconds.
    pub stage_overhead_base_s: f64,
    pub stage_overhead_per_node_s: f64,
    /// Log-normal sigma of per-stage multiplicative noise.
    pub noise_sigma: f64,
    /// Probability that a stage hits a straggler wave, and the
    /// multiplicative tail it adds.
    pub straggler_prob: f64,
    pub straggler_penalty: f64,
    /// CPU penalty per unit spilled fraction (serialization overhead).
    pub spill_cpu_penalty: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            exec_mem_fraction: 0.60,
            job_startup_s: 12.0,
            stage_overhead_base_s: 0.9,
            stage_overhead_per_node_s: 0.05,
            noise_sigma: 0.04,
            straggler_prob: 0.06,
            straggler_penalty: 0.35,
            spill_cpu_penalty: 0.6,
        }
    }
}

impl SimConfig {
    /// Noise-free configuration (unit tests / model-form analysis).
    pub fn deterministic() -> Self {
        SimConfig {
            noise_sigma: 0.0,
            straggler_prob: 0.0,
            ..SimConfig::default()
        }
    }
}

/// Per-stage execution report.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub kind: StageKind,
    pub seconds: f64,
    pub cpu_s: f64,
    pub disk_s: f64,
    pub net_s: f64,
    pub spilled_mb: f64,
    pub waves: u32,
}

/// Result of one simulated job execution.
#[derive(Debug, Clone)]
pub struct SimulationResult {
    /// End-to-end job runtime in seconds (excluding cluster provisioning).
    pub runtime_s: f64,
    pub stages: Vec<StageReport>,
    /// Total MB spilled across all stages (0 when memory sufficed).
    pub total_spilled_mb: f64,
}

impl SimulationResult {
    /// True if any stage hit the spill path.
    pub fn memory_bottlenecked(&self) -> bool {
        self.total_spilled_mb > 0.0
    }

    /// Sum of a stage-level field, for reports.
    pub fn total_cpu_s(&self) -> f64 {
        self.stages.iter().map(|s| s.cpu_s).sum()
    }
}

/// The simulator: executes stage lists against the machine catalog.
#[derive(Debug, Clone, Default)]
pub struct Simulator {
    pub config: SimConfig,
}

impl Simulator {
    pub fn new(config: SimConfig) -> Self {
        Simulator { config }
    }

    /// Execute `stages` on `n` × `machine`, seeded for reproducible noise.
    ///
    /// # Panics
    /// Panics if a stage fails validation or `n == 0`.
    pub fn run(
        &self,
        machine: &MachineType,
        n: u32,
        stages: &[Stage],
        rng: &mut Pcg32,
    ) -> SimulationResult {
        assert!(n > 0, "cluster must have at least one node");
        let cfg = &self.config;
        let mut reports = Vec::with_capacity(stages.len());
        let mut total = cfg.job_startup_s;
        let mut total_spill = 0.0;

        for stage in stages {
            stage
                .validate()
                .unwrap_or_else(|e| panic!("invalid stage: {e}"));
            let r = self.run_stage(machine, n, stage, rng);
            total += r.seconds + cfg.stage_overhead_base_s + cfg.stage_overhead_per_node_s * n as f64;
            total_spill += r.spilled_mb;
            reports.push(r);
        }

        SimulationResult {
            runtime_s: total,
            stages: reports,
            total_spilled_mb: total_spill,
        }
    }

    /// Allocation-free fast path: identical timing math to [`Self::run`]
    /// but returns only the end-to-end runtime (no per-stage reports).
    /// Used by the corpus generator and the profiling oracle, whose inner
    /// loops run millions of simulations (§Perf iteration 3).
    pub fn run_runtime_only(
        &self,
        machine: &MachineType,
        n: u32,
        stages: &[Stage],
        rng: &mut Pcg32,
    ) -> f64 {
        assert!(n > 0, "cluster must have at least one node");
        let cfg = &self.config;
        let mut total = cfg.job_startup_s;
        for stage in stages {
            debug_assert!(stage.validate().is_ok());
            let (seconds, _spilled) = self.stage_time(machine, n, stage, rng);
            total += seconds + cfg.stage_overhead_base_s + cfg.stage_overhead_per_node_s * n as f64;
        }
        total
    }

    fn run_stage(
        &self,
        machine: &MachineType,
        n: u32,
        stage: &Stage,
        rng: &mut Pcg32,
    ) -> StageReport {
        let (cpu_s, disk_s, net_s, spilled_mb, waves) = self.stage_phases(machine, n, stage);
        let seconds = self.combine_and_perturb(stage, cpu_s, disk_s, net_s, waves, rng);
        StageReport {
            name: stage.name.clone(),
            kind: stage.kind,
            seconds,
            cpu_s,
            disk_s,
            net_s,
            spilled_mb,
            waves,
        }
    }

    /// Timing math shared by [`Self::run`] and [`Self::run_runtime_only`]:
    /// (seconds, spilled_mb) for one stage.
    #[inline]
    fn stage_time(
        &self,
        machine: &MachineType,
        n: u32,
        stage: &Stage,
        rng: &mut Pcg32,
    ) -> (f64, f64) {
        let (cpu_s, disk_s, net_s, spilled_mb, waves) = self.stage_phases(machine, n, stage);
        (
            self.combine_and_perturb(stage, cpu_s, disk_s, net_s, waves, rng),
            spilled_mb,
        )
    }

    #[inline]
    fn combine_and_perturb(
        &self,
        stage: &Stage,
        cpu_s: f64,
        disk_s: f64,
        net_s: f64,
        waves: u32,
        rng: &mut Pcg32,
    ) -> f64 {
        let cfg = &self.config;
        let bound = cpu_s.max(disk_s).max(net_s);
        let sum = cpu_s + disk_s + net_s;
        let mut seconds = stage.overlap * bound + (1.0 - stage.overlap) * sum;
        if cfg.noise_sigma > 0.0 {
            seconds *= rng.lognormal_noise(cfg.noise_sigma);
        }
        if cfg.straggler_prob > 0.0 && rng.chance(cfg.straggler_prob) {
            // A straggler delays the last wave; impact shrinks with waves.
            seconds *= 1.0 + cfg.straggler_penalty / waves as f64;
        }
        seconds
    }

    /// Pure phase-time computation: (cpu_s, disk_s, net_s, spilled_mb,
    /// waves).
    #[inline]
    fn stage_phases(
        &self,
        machine: &MachineType,
        n: u32,
        stage: &Stage,
    ) -> (f64, f64, f64, f64, u32) {
        let cfg = &self.config;
        let serial = stage.kind == StageKind::Serial;
        let active_nodes = if serial { 1 } else { n } as f64;
        let slots = if serial {
            1
        } else {
            (n * machine.vcpus).max(1)
        };
        let waves = stage.tasks.div_ceil(slots).max(1);

        // --- memory / spill -------------------------------------------------
        let exec_mem_mb = machine.memory_gib * 1024.0 * cfg.exec_mem_fraction;
        let ws_per_node = stage.mem_working_set_mb / active_nodes;
        let overflow_per_node = (ws_per_node - exec_mem_mb).max(0.0);
        let spilled_mb = overflow_per_node * active_nodes;
        // Spilled data is written once and re-read once.
        let spill_disk_mb = 2.0 * spilled_mb;
        let spill_fraction = if ws_per_node > 0.0 {
            overflow_per_node / ws_per_node
        } else {
            0.0
        };

        // --- phase times ----------------------------------------------------
        let per_task_cpu = stage.cpu_core_s / stage.tasks as f64;
        let cpu_penalty = 1.0 + cfg.spill_cpu_penalty * spill_fraction;
        let cpu_s = waves as f64 * per_task_cpu * cpu_penalty / machine.cpu_perf;

        let disk_mb = stage.disk_read_mb + stage.disk_write_mb + spill_disk_mb;
        let disk_s = disk_mb / (active_nodes * machine.disk_mb_s);

        let net_s = if n > 1 && stage.shuffle_mb > 0.0 && !serial {
            let cross = stage.shuffle_mb * (n as f64 - 1.0) / n as f64;
            cross / (n as f64 * machine.net_mb_s)
        } else {
            0.0
        };

        (cpu_s, disk_s, net_s, spilled_mb, waves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::catalog::aws_like_catalog;

    fn machine(name: &str) -> MachineType {
        aws_like_catalog()
            .into_iter()
            .find(|m| m.name == name)
            .unwrap()
    }

    fn det_sim() -> Simulator {
        Simulator::new(SimConfig::deterministic())
    }

    #[test]
    fn cpu_bound_stage_scales_with_nodes() {
        let sim = det_sim();
        let m = machine("m5.xlarge"); // 4 vcpus
        let stage = Stage::parallel("compute", 512).with_cpu(4096.0);
        let mut rng = Pcg32::new(1);
        let t2 = sim.run(&m, 2, std::slice::from_ref(&stage), &mut rng).runtime_s;
        let t8 = sim.run(&m, 8, std::slice::from_ref(&stage), &mut rng).runtime_s;
        // overheads aside, 4x nodes => ~4x faster compute
        let compute2 = t2 - 12.0 - 0.9 - 0.05 * 2.0;
        let compute8 = t8 - 12.0 - 0.9 - 0.05 * 8.0;
        assert!((compute2 / compute8 - 4.0).abs() < 0.05, "{compute2} / {compute8}");
    }

    #[test]
    fn serial_stage_ignores_cluster_size() {
        let sim = det_sim();
        let m = machine("m5.xlarge");
        let stage = Stage::serial("write").with_disk(0.0, 1600.0);
        let mut rng = Pcg32::new(1);
        let t1 = sim.run(&m, 1, std::slice::from_ref(&stage), &mut rng).runtime_s;
        let t12 = sim.run(&m, 12, std::slice::from_ref(&stage), &mut rng).runtime_s;
        // only the per-node stage overhead differs
        assert!((t12 - t1 - 0.05 * 11.0).abs() < 1e-9, "t1={t1} t12={t12}");
    }

    #[test]
    fn spill_occurs_exactly_when_working_set_exceeds_memory() {
        let sim = det_sim();
        let m = machine("m5.xlarge"); // 16 GiB, exec 0.6 => 9830.4 MB/node
        let mut rng = Pcg32::new(1);
        // 2 nodes: 19660.8 MB capacity
        let fits = Stage::iteration("it", 64)
            .with_cpu(100.0)
            .with_working_set(19_000.0);
        let r = sim.run(&m, 2, std::slice::from_ref(&fits), &mut rng);
        assert!(!r.memory_bottlenecked());
        let spills = Stage::iteration("it", 64)
            .with_cpu(100.0)
            .with_working_set(25_000.0);
        let r = sim.run(&m, 2, std::slice::from_ref(&spills), &mut rng);
        assert!(r.memory_bottlenecked());
        assert!((r.total_spilled_mb - (25_000.0 - 19_660.8)).abs() < 1.0);
        // 4 nodes: fits again
        let r = sim.run(&m, 4, std::slice::from_ref(&spills), &mut rng);
        assert!(!r.memory_bottlenecked());
    }

    #[test]
    fn spill_makes_doubling_superlinear() {
        // The Fig. 6 mechanism: speedup(2 -> 4) > 2 when 2 nodes spill.
        let sim = det_sim();
        let m = machine("m5.xlarge");
        let mut rng = Pcg32::new(1);
        let stages: Vec<Stage> = (0..20)
            .map(|i| {
                Stage::iteration(&format!("iter{i}"), 128)
                    .with_cpu(800.0)
                    .with_working_set(25_000.0)
            })
            .collect();
        let t2 = sim.run(&m, 2, &stages, &mut rng).runtime_s;
        let t4 = sim.run(&m, 4, &stages, &mut rng).runtime_s;
        assert!(t2 / t4 > 2.0, "speedup {}", t2 / t4);
    }

    #[test]
    fn shuffle_time_decreases_with_nodes_but_sublinearly() {
        let sim = det_sim();
        let m = machine("m5.xlarge");
        let mut rng = Pcg32::new(1);
        let stage = Stage::shuffle("x", 256).with_shuffle(32_000.0).with_overlap(0.0);
        let net = |n: u32| {
            let mut rng2 = rng.clone();
            sim.run(&m, n, std::slice::from_ref(&stage), &mut rng2).stages[0].net_s
        };
        let t2 = net(2);
        let t4 = net(4);
        let t8 = net(8);
        assert!(t2 > t4 && t4 > t8);
        // (n-1)/n² scaling: 2 nodes => 0.5/2, 4 nodes => 0.75/4 per MB/s unit
        let expect_ratio = (0.5 / 2.0) / (0.75 / 4.0);
        assert!((t2 / t4 - expect_ratio).abs() < 0.05, "{}", t2 / t4);
    }

    #[test]
    fn single_node_has_no_network_time() {
        let sim = det_sim();
        let m = machine("m5.xlarge");
        let mut rng = Pcg32::new(1);
        let stage = Stage::shuffle("x", 16).with_shuffle(10_000.0);
        let r = sim.run(&m, 1, std::slice::from_ref(&stage), &mut rng);
        assert_eq!(r.stages[0].net_s, 0.0);
    }

    #[test]
    fn faster_cpu_family_wins_cpu_bound() {
        let sim = det_sim();
        let c5 = machine("c5.xlarge");
        let m5 = machine("m5.xlarge");
        let mut rng = Pcg32::new(1);
        let stage = Stage::parallel("compute", 256).with_cpu(2000.0).with_overlap(1.0);
        let tc = sim.run(&c5, 4, std::slice::from_ref(&stage), &mut rng).runtime_s;
        let tm = sim.run(&m5, 4, std::slice::from_ref(&stage), &mut rng).runtime_s;
        assert!(tc < tm, "c5 {tc} should beat m5 {tm}");
    }

    #[test]
    fn wave_quantization() {
        let sim = det_sim();
        let m = machine("m5.xlarge"); // 4 vcpus
        let mut rng = Pcg32::new(1);
        // 4 nodes * 4 vcpus = 16 slots; 17 tasks => 2 waves
        let stage = Stage::parallel("q", 17).with_cpu(17.0).with_overlap(1.0);
        let r = sim.run(&m, 4, std::slice::from_ref(&stage), &mut rng);
        assert_eq!(r.stages[0].waves, 2);
        assert!((r.stages[0].cpu_s - 2.0).abs() < 1e-9); // 2 waves * 1s/task
    }

    #[test]
    fn noise_is_seeded_and_median_stable() {
        let sim = Simulator::new(SimConfig::default());
        let m = machine("m5.xlarge");
        let stage = Stage::parallel("n", 64).with_cpu(640.0);
        let runs: Vec<f64> = (0..5)
            .map(|rep| {
                let mut rng = Pcg32::new(100 + rep);
                sim.run(&m, 4, std::slice::from_ref(&stage), &mut rng).runtime_s
            })
            .collect();
        // same seeds reproduce exactly
        let runs2: Vec<f64> = (0..5)
            .map(|rep| {
                let mut rng = Pcg32::new(100 + rep);
                sim.run(&m, 4, std::slice::from_ref(&stage), &mut rng).runtime_s
            })
            .collect();
        assert_eq!(runs, runs2);
        // and vary across seeds
        assert!(runs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    #[should_panic(expected = "invalid stage")]
    fn invalid_stage_panics() {
        let sim = det_sim();
        let m = machine("m5.xlarge");
        let mut rng = Pcg32::new(1);
        let bad = Stage::parallel("bad", 0);
        sim.run(&m, 1, &[bad], &mut rng);
    }
}

#[cfg(test)]
mod fast_path_tests {
    use super::*;
    use crate::cloud::catalog::aws_like_catalog;
    use crate::workloads::JobSpec;

    #[test]
    fn run_runtime_only_matches_run_exactly() {
        // same RNG draw sequence => bit-identical runtimes
        let sim = Simulator::new(SimConfig::default());
        let machines = aws_like_catalog();
        for spec in [
            JobSpec::sort(15.0),
            JobSpec::grep(12.0, 0.2),
            JobSpec::sgd(30.0, 100),
            JobSpec::kmeans(20.0, 7, 0.001),
            JobSpec::pagerank(330.0, 0.001),
        ] {
            let stages = spec.stages();
            for m in machines.iter().take(3) {
                for n in [2u32, 6, 12] {
                    let mut r1 = Pcg32::new(99);
                    let mut r2 = Pcg32::new(99);
                    let full = sim.run(m, n, &stages, &mut r1).runtime_s;
                    let fast = sim.run_runtime_only(m, n, &stages, &mut r2);
                    assert_eq!(full, fast, "{spec:?} on {} x{n}", m.name);
                }
            }
        }
    }
}
