//! Bench: submissions/second of the sharded multi-worker service vs the
//! single-thread ordered session, at 1, 4, and 8 client threads.
//!
//! The workload interleaves four job kinds so the service's per-kind
//! shards can actually run concurrently; the session baseline serves the
//! identical battery through its strictly-ordered single worker. Both
//! paths are warmed with one submission per kind first so initial model
//! training is paid outside the timed window (retrains inside the window
//! are governed by the same generation-gating policy on both sides).
//!
//! Emits `BENCH_serve_throughput.json` with the measured throughputs and
//! the speedup of the 8-client service over the session baseline.
//! Shrink with `C3O_SERVE_JOBS=24` for smoke runs.

use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::session::Session;
use c3o::coordinator::{CoordinatorService, Organization, ServiceConfig};
use c3o::util::json::Json;
use c3o::workloads::{ExperimentGrid, JobKind};
use std::time::Instant;

const KINDS: [JobKind; 4] = [JobKind::Sort, JobKind::Grep, JobKind::Sgd, JobKind::KMeans];

fn request_for(i: usize) -> JobRequest {
    let gb = 10.0 + (i % 10) as f64;
    match i % KINDS.len() {
        0 => JobRequest::sort(gb),
        1 => JobRequest::grep(gb, 0.1),
        2 => JobRequest::sgd(gb, 60),
        _ => JobRequest::kmeans(gb, 5, 0.001),
    }
}

fn corpus(cloud: &Cloud, seed: u64) -> c3o::workloads::Corpus {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| KINDS.contains(&e.spec.kind()))
            .collect(),
        repetitions: 1,
    }
    .execute(cloud, seed)
}

fn main() {
    let cloud = Cloud::aws_like();
    let total_jobs: usize = std::env::var("C3O_SERVE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);
    let corpus = corpus(&cloud, 42);
    let org = Organization::new("bench");

    // Both sides run the native model engines even when PJRT artifacts
    // are built (nonexistent artifacts dir / pjrt_workers = 0): the
    // speedup must measure the sharded architecture, not a PJRT-vs-native
    // backend difference.
    let no_artifacts = std::path::PathBuf::from("bench-no-artifacts");

    // ---- baseline: the ordered single-worker session --------------------
    let session = Session::spawn(cloud.clone(), no_artifacts.clone(), 7);
    for kind in KINDS {
        session.share(corpus.repo_for(kind)).unwrap();
    }
    for i in 0..KINDS.len() {
        session.submit(&org, request_for(i)).unwrap(); // warm: initial trains
    }
    let t0 = Instant::now();
    for i in 0..total_jobs {
        session.submit(&org, request_for(i)).unwrap();
    }
    let baseline = total_jobs as f64 / t0.elapsed().as_secs_f64();
    session.shutdown();
    println!("session   1 client : {baseline:>8.1} submissions/s  (ordered single worker)");

    // ---- the sharded service at 1, 4, 8 client threads ------------------
    let mut points: Vec<(usize, f64)> = Vec::new();
    for &clients in &[1usize, 4, 8] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(8)
                .with_pjrt_workers(0)
                .with_seed(7),
        );
        for kind in KINDS {
            service.share(corpus.repo_for(kind)).unwrap();
        }
        for i in 0..KINDS.len() {
            service.submit(&org, request_for(i)).unwrap(); // warm: initial trains
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = service.client();
                scope.spawn(move || {
                    let org = Organization::new(&format!("client-{c}"));
                    let mut i = c;
                    while i < total_jobs {
                        client.submit(&org, request_for(i)).unwrap();
                        i += clients;
                    }
                });
            }
        });
        let jobs_per_s = total_jobs as f64 / t0.elapsed().as_secs_f64();
        println!("service  {clients:>2} clients: {jobs_per_s:>8.1} submissions/s");
        points.push((clients, jobs_per_s));
        service.shutdown();
    }

    let best = points.iter().map(|&(_, j)| j).fold(0.0f64, f64::max);
    let speedup = best / baseline;
    println!("speedup (best service vs session): {speedup:.2}x");
    if speedup < 2.0 {
        eprintln!(
            "WARN: speedup {speedup:.2}x below the 2x goal — expected on \
             single-core machines; the sharded path needs real parallelism"
        );
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("serve_throughput".to_string())),
        ("total_jobs", Json::Num(total_jobs as f64)),
        ("baseline_session_jobs_per_s", Json::Num(baseline)),
        (
            "service",
            Json::Arr(
                points
                    .iter()
                    .map(|&(clients, jobs_per_s)| {
                        Json::obj(vec![
                            ("clients", Json::Num(clients as f64)),
                            ("jobs_per_s", Json::Num(jobs_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_vs_session", Json::Num(speedup)),
    ]);
    std::fs::write("BENCH_serve_throughput.json", json.render() + "\n").unwrap();
    println!("wrote BENCH_serve_throughput.json");
}
