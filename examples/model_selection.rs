//! Dynamic model selection under varying data density (paper §V-C).
//!
//! The paper expects the **pessimistic** (similarity-based) model to win
//! when dense training data is available, and the **optimistic**
//! (factorized) model to extrapolate better from sparse data. This
//! example trains both families on progressively thinner samples of the
//! K-Means corpus and on an *extrapolation* split (train on scale-outs
//! 2–8, predict 10–12), printing the CV choice at each point.
//!
//! Run with: `make artifacts && cargo run --release --example model_selection`

use c3o::models::selection::{cv_mape, select_and_train};
use c3o::models::ConfigQuery;
use c3o::prelude::*;
use c3o::repo::sampling::sampled_repo;
use c3o::util::stats;

fn main() -> anyhow::Result<()> {
    let artifacts = c3o::runtime::Runtime::default_dir();
    if !c3o::runtime::Runtime::artifacts_available(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cloud = Cloud::aws_like();

    println!("building the K-Means shared corpus...");
    let grid = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::KMeans)
            .collect(),
        repetitions: 5,
    };
    let full = grid.execute(&cloud, 42).repo_for(JobKind::KMeans);
    let mut predictor = Predictor::new(&artifacts)?;

    // ---- density sweep ---------------------------------------------------
    println!("\n== data density sweep (coverage-sampled subsets) ==");
    println!(
        "{:>8} {:>18} {:>18} {:>12}",
        "records", "pessimistic_mape", "optimistic_mape", "cv_choice"
    );
    for size in [20usize, 40, 80, 120, 180] {
        let repo = if size >= full.len() {
            full.clone()
        } else {
            sampled_repo(&full, &cloud, size)
        };
        let p = cv_mape(&mut predictor, &cloud, &repo, ModelKind::Pessimistic, 4, 1)?;
        let o = cv_mape(&mut predictor, &cloud, &repo, ModelKind::Optimistic, 4, 1)?;
        let (_, report) = select_and_train(&mut predictor, &cloud, &repo, 4, 1)?;
        println!(
            "{:>8} {:>17.1}% {:>17.1}% {:>12}",
            repo.len(),
            p,
            o,
            report.chosen.name()
        );
    }

    // ---- extrapolation split ----------------------------------------------
    println!("\n== extrapolation: train on scale-outs 2–8, predict 10–12 ==");
    let mut train = RuntimeDataRepo::new(JobKind::KMeans);
    let mut test = Vec::new();
    for r in full.records() {
        if r.scaleout <= 8 {
            train.contribute(r.clone()).map_err(anyhow::Error::msg)?;
        } else {
            test.push(r.clone());
        }
    }
    let queries: Vec<ConfigQuery> = test
        .iter()
        .map(|r| ConfigQuery {
            machine: r.machine.clone(),
            scaleout: r.scaleout,
            job_features: r.job_features.clone(),
        })
        .collect();
    let truth: Vec<f64> = test.iter().map(|r| r.runtime_s).collect();
    println!(
        "{:>14} {:>18}",
        "model", "extrapolation_mape"
    );
    for kind in ModelKind::all() {
        let model = predictor.train(&cloud, &train, kind)?;
        let preds = predictor.predict(&model, &cloud, &queries)?;
        println!("{:>14} {:>17.1}%", kind.name(), stats::mape(&preds, &truth));
    }
    println!(
        "\nWhich family wins depends on the regime — density, interpolation vs\n\
         extrapolation, and the job's scale-out shape (paper §V-C). That\n\
         situation-dependence is exactly why C3O selects the model dynamically\n\
         by cross-validation instead of committing to either."
    );
    Ok(())
}
