//! Quickstart: the C3O loop in ~40 lines of user code.
//!
//! 1. Build a simulated cloud and share a (small) corpus of historical
//!    runtime data for a Grep job.
//! 2. Train the runtime prediction models on the shared data (dynamic
//!    cross-validation selection between the pessimistic and optimistic
//!    families — everything executes as AOT-compiled XLA via PJRT).
//! 3. Ask the configurator for the cheapest cluster that greps 15 GB in
//!    under five minutes; run it; contribute the new measurement back.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use c3o::prelude::*;

fn main() -> anyhow::Result<()> {
    let artifacts = c3o::runtime::Runtime::default_dir();
    if !c3o::runtime::Runtime::artifacts_available(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // A simulated public cloud (m5/c5/r5-like catalog, EMR-like delays).
    let cloud = Cloud::aws_like();

    // Historical executions shared by other organizations: here, the
    // Grep slice of the paper's 930-experiment grid.
    println!("generating shared corpus (Grep slice of Table I)...");
    let grid = ExperimentGrid::paper_table1();
    let grep_only = ExperimentGrid {
        experiments: grid
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Grep)
            .collect(),
        repetitions: 5,
    };
    let corpus = grep_only.execute(&cloud, 42);
    let shared = corpus.repo_for(JobKind::Grep);
    println!(
        "  {} records from {} organizations",
        shared.len(),
        shared.organizations().len()
    );

    // The coordinator owns models + repositories + the cloud loop.
    let mut coordinator = Coordinator::new(cloud, &artifacts, 7)?;
    coordinator.share(&shared)?;

    // A brand-new organization configures its very first Grep run.
    let org = Organization::new("quickstart-org");
    let request = JobRequest::grep(15.0, 0.1).with_target_seconds(300.0);
    let outcome = coordinator.submit(&org, &request)?;

    let report = coordinator
        .selection_report(JobKind::Grep)
        .expect("model trained");
    println!("\nmodel selection (4-fold CV):");
    println!(
        "  pessimistic {:.1}%  optimistic {:.1}%  -> chose {}",
        report.mape_of(ModelKind::Pessimistic),
        report.mape_of(ModelKind::Optimistic),
        report.chosen.name()
    );
    println!("\nconfiguration decision:");
    println!("  cluster:   {} x{}", outcome.machine, outcome.scaleout);
    println!("  predicted: {:.1} s", outcome.predicted_runtime_s);
    println!("  actual:    {:.1} s", outcome.actual_runtime_s);
    println!(
        "  error:     {:.1}%  |  met 300 s target: {}",
        outcome.prediction_error_pct(),
        outcome.met_target
    );
    println!("  cost:      ${:.3}", outcome.actual_cost_usd);
    Ok(())
}
