//! Bench: regenerate Fig. 3 (machine types and cost-efficiency at
//! different scale-outs) and verify its claims, then measure the
//! machine-type ranking hot path.

use c3o::cloud::Cloud;
use c3o::configurator::Configurator;
use c3o::figures;
use c3o::models::oracle::SimOracle;
use c3o::util::bench::{black_box, Bench};
use c3o::workloads::{JobKind, JobSpec};

fn main() {
    let cloud = Cloud::aws_like();

    let fig = figures::fig3(&cloud, 42);
    println!("{}", fig.render());
    assert!(fig.all_claims_hold(), "Fig. 3 reproduction failed");

    let mut b = Bench::new("fig3_machine_types");
    let configurator = Configurator::new(&cloud);
    let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
    let spec = JobSpec::sort(15.0);
    b.run("rank_machine_types_sort_n8", || {
        black_box(
            configurator
                .rank_machine_types(&mut oracle, &spec, 8)
                .unwrap()
                .len(),
        )
    });
    b.finish();
}
