//! Fixture: deterministic zone — `hash-iter` and `float-order`.

use std::collections::HashMap;

pub fn keyed_total(xs: &HashMap<String, f64>) -> f64 {
    xs.values().sum::<f64>()
}

// c3o-lint: allow(hash-iter) — fixture: documented single-use map length helper
pub fn map_len(xs: &HashMap<String, f64>) -> usize {
    xs.len()
}

pub fn ordered_total(xs: &[f64]) -> f64 {
    // c3o-lint: allow(float-order) — fixture: sequential in-order slice reduction
    xs.iter().sum::<f64>()
}
