//! Bench: the federation subsystem's two hot paths, in records/second.
//!
//! * **Replay** — how fast a segment store recovers a corpus on
//!   startup, from the WAL (line-by-line op replay) and from a compacted
//!   snapshot (bulk CSV load). This bounds restart time for a durable
//!   coordinator service.
//! * **Sync** — how fast two peers holding disjoint org corpora
//!   converge through a full `Watermarks`/`SyncPull`/`SyncPush`
//!   exchange (both directions, merge-dedup applied). This bounds how
//!   quickly a fresh deployment catches up with the federation.
//!
//! Model training is disabled (cold-start threshold maxed) so the
//! numbers measure persistence and exchange, not model selection.
//!
//! Emits `BENCH_sync_throughput.json`. Shrink with
//! `C3O_SYNC_RECORDS=500` for smoke runs.

use c3o::cloud::Cloud;
use c3o::coordinator::Coordinator;
use c3o::models::Engine;
use c3o::repo::{RuntimeDataRepo, RuntimeRecord};
use c3o::store::{sync_all, JobStore, StoreOp};
use c3o::util::json::Json;
use c3o::workloads::JobKind;
use std::path::PathBuf;
use std::time::Instant;

const MACHINES: [&str; 3] = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];

/// Synthetic sort records with globally-unique configurations.
fn synthetic_records(n: usize) -> Vec<RuntimeRecord> {
    (0..n)
        .map(|i| RuntimeRecord {
            job: JobKind::Sort,
            org: format!("org-{}", i % 7),
            machine: MACHINES[i % MACHINES.len()].to_string(),
            scaleout: 2 + (i % 14) as u32,
            job_features: vec![1.0 + 0.5 * i as f64],
            runtime_s: 50.0 + (i % 997) as f64,
        })
        .collect()
}

fn temp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("c3o_syncbench_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn main() {
    let n: usize = std::env::var("C3O_SYNC_RECORDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4000);
    let records = synthetic_records(n);

    // ---- replay: WAL-only recovery -------------------------------------
    let root = temp_root("replay");
    {
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        for chunk in records.chunks(64) {
            let outcome = repo.merge_records(chunk).unwrap();
            let ops: Vec<StoreOp> =
                outcome.applied.into_iter().map(StoreOp::Merge).collect();
            store.append(&ops, repo.generation()).unwrap();
        }
    }
    let t0 = Instant::now();
    let (mut store, repo) = JobStore::open(&root, JobKind::Sort).unwrap();
    let wal_secs = t0.elapsed().as_secs_f64();
    assert_eq!(repo.len(), n, "replay must recover every record");
    let wal_rate = n as f64 / wal_secs;
    println!("replay   WAL      : {n:>6} records in {wal_secs:.3}s  ({wal_rate:>9.0} records/s)");

    // ---- replay: snapshot recovery -------------------------------------
    store.compact(&repo).unwrap();
    drop(store);
    let t0 = Instant::now();
    let (_store, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
    let snap_secs = t0.elapsed().as_secs_f64();
    assert_eq!(repo2.len(), n);
    let snap_rate = n as f64 / snap_secs;
    println!("replay   snapshot : {n:>6} records in {snap_secs:.3}s  ({snap_rate:>9.0} records/s)");
    let _ = std::fs::remove_dir_all(&root);

    // ---- sync: two peers with disjoint org corpora ---------------------
    let cloud = Cloud::aws_like();
    let half = n / 2;
    let relabel = |rs: &[RuntimeRecord], org: &str| -> Vec<RuntimeRecord> {
        rs.iter().map(|r| r.with_org(org)).collect()
    };
    let mut peer_a = Coordinator::with_engine(cloud.clone(), Engine::native(), 1);
    let mut peer_b = Coordinator::with_engine(cloud, Engine::native(), 2);
    // measure exchange, not model selection
    peer_a.min_records = usize::MAX;
    peer_b.min_records = usize::MAX;
    peer_a
        .share(&RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&records[..half], "alpha"),
        ))
        .unwrap();
    peer_b
        .share(&RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&records[half..], "beta"),
        ))
        .unwrap();

    let t0 = Instant::now();
    let stats = sync_all(&mut peer_a, &mut peer_b, &[JobKind::Sort]).unwrap();
    let sync_secs = t0.elapsed().as_secs_f64();
    let exchanged = stats.records_in + stats.records_out;
    assert_eq!(exchanged as usize, n, "full bidirectional exchange");
    let again = sync_all(&mut peer_a, &mut peer_b, &[JobKind::Sort]).unwrap();
    assert!(again.quiescent(), "second exchange must be a no-op");
    let sync_rate = exchanged as f64 / sync_secs;
    println!(
        "sync     exchange : {exchanged:>6} records in {sync_secs:.3}s  ({sync_rate:>9.0} records/s)"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("sync_throughput".to_string())),
        ("records", Json::Num(n as f64)),
        (
            "replay",
            Json::obj(vec![
                ("wal_records_per_s", Json::Num(wal_rate)),
                ("snapshot_records_per_s", Json::Num(snap_rate)),
            ]),
        ),
        (
            "sync",
            Json::obj(vec![
                ("records_exchanged", Json::Num(exchanged as f64)),
                ("records_per_s", Json::Num(sync_rate)),
                ("pulls", Json::Num(stats.pulls as f64)),
                ("conflicts", Json::Num(stats.conflicts as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_sync_throughput.json", json.render() + "\n").unwrap();
    println!("wrote BENCH_sync_throughput.json");
}
