#!/usr/bin/env python3
"""Diff a fresh BENCH_serve_throughput.json against the committed baseline.

Usage: bench_trend.py BASELINE.json CURRENT.json [EXTRA.json ...]

Prints a throughput comparison table for CI trend reporting. Exits
nonzero only on a gross regression (current < REGRESSION_FLOOR x
baseline) so ordinary CI-runner jitter never blocks a merge; the
uploaded artifact carries the precise numbers.

A baseline with {"placeholder": true} records that no reference numbers
have been committed yet: the script then just prints the current run and
succeeds. Refresh the baseline by copying a representative run's
BENCH_serve_throughput.json over the .baseline.json file.

EXTRA files are additional BENCH_*.json outputs (e.g.
BENCH_sync_throughput.json). If a sibling <name>.baseline.json is
committed next to the script's invocation directory, the extra's rate
metrics are held to the same REGRESSION_FLOOR; otherwise the extra is
summarized report-only. The sync_throughput schema gets a dedicated
table; anything else is pretty-printed.
"""

import json
import os
import sys

REGRESSION_FLOOR = 0.5

FAILURES = []


def compare(label, base_v, cur_v):
    ratio = cur_v / base_v if base_v else float("inf")
    flag = ""
    if ratio < REGRESSION_FLOOR:
        flag = "  << REGRESSION"
        FAILURES.append(label)
    print(f"{label:<42} {base_v:>10.1f} {cur_v:>10.1f} {ratio:>7.2f}x{flag}")


def load_sibling_baseline(path):
    """Return the committed <name>.baseline.json next to an extra, if any."""
    stem = path[:-5] if path.endswith(".json") else path
    baseline_path = stem + ".baseline.json"
    if not os.path.exists(baseline_path):
        return None
    with open(baseline_path) as f:
        base = json.load(f)
    return None if base.get("placeholder") else base


def report_extra(path):
    with open(path) as f:
        doc = json.load(f)
    base = load_sibling_baseline(path)
    if doc.get("bench") == "sync_throughput":
        replay = doc.get("replay", {})
        sync = doc.get("sync", {})
        incremental = doc.get("incremental", {})
        rates = [
            ("sync replay WAL (records/s)", ("replay", "wal_records_per_s")),
            ("sync replay snapshot (records/s)", ("replay", "snapshot_records_per_s")),
            ("sync exchange (records/s)", ("sync", "records_per_s")),
        ]
        if base is not None:
            print(f"\n--- {path} (vs committed baseline) ---")
            print(f"{'metric':<42} {'baseline':>10} {'current':>10} {'ratio':>8}")
            for label, (section, key) in rates:
                base_v = base.get(section, {}).get(key)
                cur_v = doc.get(section, {}).get(key)
                if base_v is not None and cur_v is not None:
                    compare(label, float(base_v), float(cur_v))
        else:
            print(f"\n--- {path} (report-only, no baseline) ---")
        print(f"\n{'metric':<42} {'value':>14}")
        rows = [
            ("records", doc.get("records")),
            ("replay WAL (records/s)", replay.get("wal_records_per_s")),
            ("replay snapshot (records/s)", replay.get("snapshot_records_per_s")),
            ("sync exchange (records/s)", sync.get("records_per_s")),
            ("sync records exchanged", sync.get("records_exchanged")),
            ("sync pulls", sync.get("pulls")),
            ("sync conflicts", sync.get("conflicts")),
            ("1-of-N incremental: v3 records shipped", incremental.get("v3_records_shipped")),
            ("1-of-N incremental: v2 records shipped", incremental.get("v2_records_shipped")),
            ("1-of-N incremental: v2/v3 ship ratio", incremental.get("ship_ratio_v2_over_v3")),
        ]
        for label, value in rows:
            if value is not None:
                print(f"{label:<42} {float(value):>14.1f}")
    elif doc.get("bench") == "perf_hotpath":
        cv = doc.get("cv_retrain_400_rows", {})
        print(f"\n--- {path}: serial vs pooled CV retrain (report-only) ---")
        rows = cv.get("rows")
        serial = cv.get("serial_mean_ns")
        if rows is not None and serial is not None:
            print(
                f"{'serial retrain, ' + str(int(rows)) + ' rows (ms)':<42}"
                f" {float(serial) / 1e6:>10.2f}"
            )
        for p in cv.get("pool", []):
            label = f"pooled retrain, {p.get('threads')} threads (ms)"
            speedup = p.get("speedup_vs_serial")
            extra = f"  {float(speedup):.2f}x vs serial" if speedup is not None else ""
            print(f"{label:<42} {float(p.get('mean_ns', 0.0)) / 1e6:>10.2f}{extra}")
        speedup4 = cv.get("speedup_pool4_vs_serial")
        if speedup4 is not None:
            goal = "meets" if float(speedup4) >= 2.0 else "below"
            print(f"{'speedup, 4-thread pool vs serial':<42} {float(speedup4):>9.2f}x  ({goal} the 2x goal)")
    else:
        print(f"\n--- {path} (report-only, no baseline) ---")
        print(json.dumps(doc, indent=2))


def report_write_mix(doc):
    """Summarize the write-mix serve scenario, report-only (no baseline yet)."""
    wm = doc.get("write_mix")
    if not wm:
        return
    print(f"\n--- write-mix {wm.get('mix', '?')} (report-only, no baseline) ---")
    session = wm.get("baseline_session_req_per_s")
    if session is not None:
        print(f"{'session 1 client (req/s)':<42} {float(session):>10.1f}")
    for p in wm.get("service", []):
        label = f"service {p.get('clients')} clients (req/s)"
        extras = (
            f"  coalesced_write_batches={p.get('coalesced_write_batches')}"
            f"  featurized_rows_reused={p.get('featurized_rows_reused')}"
        )
        print(f"{label:<42} {float(p.get('req_per_s', 0.0)):>10.1f}{extras}")
    speedup = wm.get("speedup_vs_session")
    if speedup is not None:
        print(f"{'speedup vs session':<42} {float(speedup):>9.1f}x")


def report_retrain_heavy(doc):
    """Summarize the retrain-heavy affinity scenario, report-only.

    Steal counters depend on scheduling, so they are never held to a
    floor — the table tracks whether reads keep flowing past retrain
    storms and how much cross-lane stealing that took.
    """
    rh = doc.get("retrain_heavy")
    if not rh:
        return
    print(f"\n--- retrain-heavy {rh.get('mix', '?')} (report-only, no baseline) ---")
    for p in rh.get("service", []):
        label = f"service {p.get('clients')} clients (req/s)"
        extras = (
            f"  retrains={p.get('retrains')}"
            f"  reads_stolen={p.get('reads_stolen')}"
            f"  writes_stolen={p.get('writes_stolen')}"
        )
        print(f"{label:<42} {float(p.get('req_per_s', 0.0)):>10.1f}{extras}")


def report_latency(doc):
    """Summarize the tracing-overhead and latency blocks, report-only.

    Percentiles are environment-dependent (CI runner load), so they are
    never held to a regression floor — the table is for trend eyeballing
    in the job log and the uploaded artifact.
    """
    tracing = doc.get("tracing")
    if tracing:
        print("\n--- tracing overhead (report-only, no baseline) ---")
        for label, key in [
            ("service 8 clients, tracing on (req/s)", "on_req_per_s"),
            ("service 8 clients, tracing off (req/s)", "off_req_per_s"),
        ]:
            value = tracing.get(key)
            if value is not None:
                print(f"{label:<42} {float(value):>10.1f}")
        overhead = tracing.get("overhead_pct")
        if overhead is not None:
            print(f"{'untraced speed advantage':<42} {float(overhead):>9.1f}%")
    latency = doc.get("latency")
    if not isinstance(latency, dict):
        return
    kinds = latency.get("kinds")
    if not kinds:
        return
    print("\n--- request latency by kind, traced run (report-only) ---")
    print(f"{'kind':<12} {'count':>8} {'p50 us':>10} {'p95 us':>10} {'p99 us':>10}")
    for row in kinds:
        total = row.get("total")
        if not total:
            continue
        print(
            f"{row.get('kind', '?'):<12} {int(total.get('count', 0)):>8}"
            f" {float(total.get('p50_us', 0.0)):>10.1f}"
            f" {float(total.get('p95_us', 0.0)):>10.1f}"
            f" {float(total.get('p99_us', 0.0)):>10.1f}"
        )


def service_points(doc, section=None, key="jobs_per_s"):
    node = doc.get(section, {}) if section else doc
    return {int(p["clients"]): float(p[key]) for p in node.get("service", [])}


def finish():
    if FAILURES:
        sys.exit(
            f"gross throughput regression (< {REGRESSION_FLOOR}x baseline): {FAILURES}"
        )
    print("\nno gross regression")


def main():
    if len(sys.argv) < 3:
        sys.exit(__doc__)
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        cur = json.load(f)
    extras = sys.argv[3:]

    if base.get("placeholder"):
        print("baseline is a placeholder — reporting current numbers only")
        print(json.dumps(cur, indent=2))
        print(
            "\nTo start trend-diffing, commit this run as "
            "BENCH_serve_throughput.baseline.json"
        )
        report_write_mix(cur)
        report_retrain_heavy(cur)
        report_latency(cur)
        for path in extras:
            report_extra(path)
        finish()
        return

    print(f"{'metric':<42} {'baseline':>10} {'current':>10} {'ratio':>8}")
    compare(
        "write-heavy session 1 client (jobs/s)",
        float(base["baseline_session_jobs_per_s"]),
        float(cur["baseline_session_jobs_per_s"]),
    )
    base_svc = service_points(base)
    cur_svc = service_points(cur)
    for clients in sorted(base_svc):
        if clients in cur_svc:
            compare(
                f"write-heavy service {clients} clients (jobs/s)",
                base_svc[clients],
                cur_svc[clients],
            )

    if "read_heavy" in base and "read_heavy" in cur:
        compare(
            "read-heavy session 1 client (req/s)",
            float(base["read_heavy"]["baseline_session_req_per_s"]),
            float(cur["read_heavy"]["baseline_session_req_per_s"]),
        )
        base_r = service_points(base, "read_heavy", "req_per_s")
        cur_r = service_points(cur, "read_heavy", "req_per_s")
        for clients in sorted(base_r):
            if clients in cur_r:
                compare(
                    f"read-heavy service {clients} clients (req/s)",
                    base_r[clients],
                    cur_r[clients],
                )

    report_write_mix(cur)
    report_retrain_heavy(cur)
    report_latency(cur)

    for path in extras:
        report_extra(path)

    finish()


if __name__ == "__main__":
    main()
