//! Small deterministic hashing for content digests (FNV-1a).
//!
//! The federation layer identifies "do we hold the same records for this
//! organization?" by an order-independent digest of the record set
//! ([`crate::repo::OrgWatermark`]), and the segment store stamps every
//! WAL line with a checksum so a torn tail write is detected on
//! recovery. Both need a stable, dependency-free 64-bit hash — `std`'s
//! `DefaultHasher` is explicitly not stable across releases, so the
//! classic FNV-1a is implemented here.

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice. Deterministic across platforms and
/// releases; used for WAL line checksums and org watermark digests.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over several byte slices, as if concatenated with a `0xFF`
/// separator (a byte that cannot appear inside UTF-8 text), so
/// `("ab", "c")` and `("a", "bc")` hash differently.
pub fn fnv1a64_parts(parts: &[&[u8]]) -> u64 {
    let mut h = FNV_OFFSET;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h ^= 0xFF;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn parts_are_boundary_sensitive() {
        assert_ne!(
            fnv1a64_parts(&[b"ab", b"c"]),
            fnv1a64_parts(&[b"a", b"bc"])
        );
        assert_eq!(
            fnv1a64_parts(&[b"ab", b"c"]),
            fnv1a64_parts(&[b"ab", b"c"])
        );
    }

    #[test]
    fn different_inputs_differ() {
        assert_ne!(fnv1a64(b"record-1"), fnv1a64(b"record-2"));
    }
}
