"""L2 correctness: the prediction-model graphs that get AOT-exported.

Validates the kNN prediction graph against a NumPy re-implementation,
the optimistic model's training dynamics (loss decreases, recovers known
coefficients), and the masking/padding contracts the Rust runtime relies
on.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _numpy_knn(train_x, train_y, valid, weights, queries, k, eps=1e-6):
    """Independent NumPy re-implementation (no jax) of the kNN predictor."""
    preds = []
    for q in queries:
        d = ((q[None, :] - train_x) ** 2 * weights[None, :]).sum(axis=1)
        d = np.where(valid > 0.5, d, ref.PAD_DISTANCE)
        idx = np.argsort(d)[:k]
        nd, ny = d[idx], train_y[idx]
        w = 1.0 / (nd + eps)
        w = np.where(nd >= ref.PAD_DISTANCE * 0.5, 0.0, w)
        preds.append((w * ny).sum() / max(w.sum(), eps))
    return np.array(preds, np.float32)


def _knn_inputs(rng, n_valid=100):
    tx = rng.normal(size=(model.KNN_T, model.F)).astype(np.float32)
    ty = rng.normal(size=model.KNN_T).astype(np.float32)
    valid = np.zeros(model.KNN_T, np.float32)
    valid[:n_valid] = 1.0
    w = rng.uniform(0.0, 1.0, size=model.F).astype(np.float32)
    q = rng.normal(size=(model.KNN_Q, model.F)).astype(np.float32)
    return tx, ty, valid, w, q


class TestKnnPredict:
    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        tx, ty, valid, w, q = _knn_inputs(rng)
        got = np.asarray(model.knn_predict(tx, ty, valid, w, q))
        want = _numpy_knn(tx, ty, valid, w, q, model.KNN_K)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_matches_ref(self):
        rng = np.random.default_rng(1)
        tx, ty, valid, w, q = _knn_inputs(rng, n_valid=300)
        got = np.asarray(model.knn_predict(tx, ty, valid, w, q))
        want = np.asarray(
            ref.knn_predict_ref(
                jnp.asarray(tx), jnp.asarray(ty), jnp.asarray(valid),
                jnp.asarray(w), jnp.asarray(q), model.KNN_K,
            )
        )
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_exact_match_query_returns_its_runtime(self):
        rng = np.random.default_rng(2)
        tx, ty, valid, w, _ = _knn_inputs(rng)
        w = np.maximum(w, 0.1)
        q = np.tile(tx[3], (model.KNN_Q, 1))
        got = np.asarray(model.knn_predict(tx, ty, valid, w, q))
        # inverse-distance weighting: an exact neighbour dominates
        np.testing.assert_allclose(got, np.full(model.KNN_Q, ty[3]), atol=1e-2)

    def test_padding_rows_never_selected(self):
        rng = np.random.default_rng(3)
        tx, ty, valid, w, q = _knn_inputs(rng, n_valid=10)
        # poison the padded runtimes — must not leak into predictions
        ty2 = ty.copy()
        ty2[10:] = 1e6
        a = np.asarray(model.knn_predict(tx, ty, valid, w, q))
        b = np.asarray(model.knn_predict(tx, ty2, valid, w, q))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_fewer_valid_than_k(self):
        rng = np.random.default_rng(4)
        tx, ty, valid, w, q = _knn_inputs(rng, n_valid=2)
        got = np.asarray(model.knn_predict(tx, ty, valid, w, q))
        want = _numpy_knn(tx, ty, valid, w, q, model.KNN_K)
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_valid=st.integers(6, model.KNN_T))
    def test_hypothesis_sweep(self, seed, n_valid):
        rng = np.random.default_rng(seed)
        tx, ty, valid, w, q = _knn_inputs(rng, n_valid=n_valid)
        got = np.asarray(model.knn_predict(tx, ty, valid, w, q))
        want = _numpy_knn(tx, ty, valid, w, q, model.KNN_K)
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


class TestOptimistic:
    def _batch_from(self, rng, coef, n=model.OPT_BATCH):
        x = rng.uniform(0.0, 1.0, size=(n, model.F)).astype(np.float32)
        basis = np.asarray(ref.optimistic_basis_ref(jnp.asarray(x)))
        y = (basis @ coef[1:] + coef[0]).astype(np.float32)
        return x, y

    def test_predict_matches_manual(self):
        rng = np.random.default_rng(0)
        params = rng.normal(size=model.OPT_PARAMS).astype(np.float32)
        x = rng.uniform(0.0, 1.0, size=(model.OPT_BATCH, model.F)).astype(np.float32)
        got = np.asarray(model.optimistic_predict(params, x))
        lin, log, inv = x, np.log1p(x), 1.0 / (x + 0.1)
        basis = np.concatenate([lin, log, inv], axis=1)
        want = params[0] + basis @ params[1:]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_train_reduces_loss_and_recovers_function(self):
        rng = np.random.default_rng(1)
        coef = np.zeros(model.OPT_PARAMS, np.float32)
        coef[0] = 0.5
        coef[1] = 2.0  # feature 0, linear term
        coef[1 + model.F] = -1.0  # feature 0, log term
        x, y = self._batch_from(rng, coef)
        mask = np.ones(model.OPT_BATCH, np.float32)
        p, m, v = (np.asarray(a) for a in model.optimistic_init())
        losses = []
        for step in range(1, 401):
            p, m, v, loss = model.optimistic_train_step(
                p, m, v, np.float32(step), x, y, mask, np.float32(0.05)
            )
            losses.append(float(loss))
        assert losses[-1] < 0.01 * losses[0], f"{losses[0]} -> {losses[-1]}"
        pred = np.asarray(model.optimistic_predict(p, x))
        mape = np.mean(np.abs(pred - y) / np.maximum(np.abs(y), 1e-3))
        assert mape < 0.1, f"MAPE {mape}"

    def test_mask_excludes_padding(self):
        rng = np.random.default_rng(2)
        coef = rng.normal(size=model.OPT_PARAMS).astype(np.float32) * 0.1
        x, y = self._batch_from(rng, coef)
        mask = np.ones(model.OPT_BATCH, np.float32)
        mask[100:] = 0.0
        y_poison = y.copy()
        y_poison[100:] = 1e6  # must be ignored
        p, m, v = (np.asarray(a) for a in model.optimistic_init())
        p1 = p.copy()
        for step in range(1, 21):
            p1, m, v, _ = model.optimistic_train_step(
                p1, m, v, np.float32(step), x, y_poison, mask, np.float32(0.05)
            )
        p2, m2, v2 = (np.asarray(a) for a in model.optimistic_init())
        for step in range(1, 21):
            p2, m2, v2, _ = model.optimistic_train_step(
                p2, m2, v2, np.float32(step), x, y, mask, np.float32(0.05)
            )
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-5)

    def test_adam_matches_reference_formulas(self):
        rng = np.random.default_rng(3)
        g = rng.normal(size=8).astype(np.float32)
        p = rng.normal(size=8).astype(np.float32)
        m = rng.normal(size=8).astype(np.float32) * 0.1
        v = np.abs(rng.normal(size=8)).astype(np.float32) * 0.1
        p2, m2, v2 = (
            np.asarray(a)
            for a in ref.adam_step_ref(p, m, v, np.float32(3.0), g, 0.01)
        )
        m_want = 0.9 * m + 0.1 * g
        v_want = 0.999 * v + 0.001 * g * g
        mhat = m_want / (1 - 0.9**3)
        vhat = v_want / (1 - 0.999**3)
        p_want = p - 0.01 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(m2, m_want, rtol=1e-5)
        np.testing.assert_allclose(v2, v_want, rtol=1e-5)
        np.testing.assert_allclose(p2, p_want, rtol=1e-5)


class TestShapes:
    def test_example_args_match_functions(self):
        import jax

        # lowering with the example args must succeed — this is exactly
        # what aot.py does, so a failure here catches artifact drift early
        jax.jit(model.knn_predict).lower(*model.knn_example_args())
        jax.jit(model.optimistic_predict).lower(
            *model.optimistic_predict_example_args()
        )
        jax.jit(model.optimistic_train_step).lower(
            *model.optimistic_train_example_args()
        )

    def test_manifest_constants(self):
        from compile import aot

        rows = dict(aot.manifest_rows())
        assert rows["feature_dim"] == model.F
        assert rows["opt_params"] == 1 + 3 * model.F
        assert rows["knn_train_rows"] % 64 == 0  # tile-aligned
        assert rows["knn_query_rows"] % 64 == 0
