//! The fixture corpus under `tests/fixtures/`: every rule has at least
//! one firing case and one suppressed case, and every suppression form
//! (`allow`, `allow-fn`, `holds`, and each malformed variant) behaves
//! exactly as documented in `README.md` — asserted as exact
//! `(file, line, rule)` diagnostics.

use c3o_lint::{scan_tree, Finding, LintConfig};
use std::path::PathBuf;

fn fixture_config() -> LintConfig {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    LintConfig::load(&manifest.join("tests/fixtures/lint.toml")).unwrap()
}

fn tuples(findings: &[Finding]) -> Vec<(String, u32, String)> {
    findings
        .iter()
        .map(|f| (f.file.clone(), f.line, f.rule.clone()))
        .collect()
}

fn at(file: &str, line: u32, rule: &str) -> (String, u32, String) {
    (file.to_string(), line, rule.to_string())
}

#[test]
fn fixture_corpus_fires_exactly() {
    let result = scan_tree(&fixture_config()).unwrap();
    assert_eq!(result.files_scanned, 6);
    assert_eq!(
        tuples(&result.findings),
        vec![
            // no-panic-serving: `.unwrap()`, `unreachable!`, `xs[1]`.
            at("api/mod.rs", 4, "no-panic-serving"),
            at("api/mod.rs", 6, "no-panic-serving"),
            at("api/mod.rs", 8, "no-panic-serving"),
            // bad-suppression: one per malformed variant.
            at("bad.rs", 3, "bad-suppression"),  // unknown rule
            at("bad.rs", 6, "bad-suppression"),  // missing justification
            at("bad.rs", 9, "bad-suppression"),  // unknown directive
            at("bad.rs", 12, "bad-suppression"), // no parentheses
            at("bad.rs", 15, "bad-suppression"), // unknown lock class
            at("bad.rs", 18, "bad-suppression"), // dangling allow-fn
            // lock-discipline: metrics under shard is not in the
            // declared order — via a real outer guard (14) and via a
            // `holds(shard)` annotation (46).
            at("coordinator/mod.rs", 14, "lock-discipline"),
            at("coordinator/mod.rs", 46, "lock-discipline"),
            at("coordinator/mod.rs", 51, "no-anyhow-public"),
            // float-order: `.fold(0.0f32, ..)` and `.sum::<f64>()`.
            at("models/mod.rs", 4, "float-order"),
            at("repo/mod.rs", 3, "hash-iter"),
            at("repo/mod.rs", 5, "hash-iter"),
            at("repo/mod.rs", 6, "float-order"),
        ]
    );
}

#[test]
fn fixture_corpus_suppresses_exactly() {
    let result = scan_tree(&fixture_config()).unwrap();
    assert_eq!(
        tuples(&result.suppressed),
        vec![
            // line-adjacent `allow` inside the fn body
            at("api/mod.rs", 13, "no-panic-serving"),
            at("coordinator/mod.rs", 31, "lock-discipline"),
            // `allow` directly above the pub fn signature
            at("coordinator/mod.rs", 57, "no-anyhow-public"),
            // `allow-fn` covering two findings in one body
            at("models/mod.rs", 9, "float-order"),
            at("models/mod.rs", 10, "float-order"),
            at("repo/mod.rs", 10, "hash-iter"),
            at("repo/mod.rs", 16, "float-order"),
        ]
    );
}

fn message_at<'a>(result: &'a c3o_lint::ScanResult, file: &str, line: u32) -> &'a str {
    &result
        .findings
        .iter()
        .find(|f| f.file == file && f.line == line)
        .unwrap()
        .message
}

#[test]
fn fixture_messages_name_the_invariant() {
    let result = scan_tree(&fixture_config()).unwrap();
    assert!(message_at(&result, "repo/mod.rs", 3).contains("bitwise convergence"));
    assert!(message_at(&result, "api/mod.rs", 4).contains("ApiError"));
    let lock_msg = message_at(&result, "coordinator/mod.rs", 14);
    assert!(lock_msg.contains("not in the declared lock order"));
    let anyhow_msg = message_at(&result, "coordinator/mod.rs", 51);
    assert!(anyhow_msg.contains("typed `ApiError` taxonomy"));
    assert!(message_at(&result, "bad.rs", 6).contains("without a justification"));
}

#[test]
fn allowed_lock_nesting_and_exempt_modules_stay_silent() {
    let result = scan_tree(&fixture_config()).unwrap();
    // shard -> snapshot is in the declared order: nested_allowed (line
    // 21) and publish_under_shard (line 39) must not fire.
    assert!(!result
        .findings
        .iter()
        .chain(result.suppressed.iter())
        .any(|f| f.file == "coordinator/mod.rs" && (f.line == 21 || f.line == 39)));
    // util is anyhow-exempt and boundary-zoned: nothing at all.
    assert!(!result
        .findings
        .iter()
        .chain(result.suppressed.iter())
        .any(|f| f.file == "util/mod.rs"));
    // unwrap inside #[cfg(test)] is out of scope.
    assert!(!result
        .findings
        .iter()
        .chain(result.suppressed.iter())
        .any(|f| f.file == "api/mod.rs" && f.line > 15));
}
