"""L2: the JAX compute graphs of the two runtime prediction models.

Everything here is build-time only. `aot.py` lowers the three public
functions to HLO text artifacts; the Rust coordinator loads and executes
them via PJRT and never imports Python.

Fixed artifact shapes (PJRT executables are shape-specialized; the Rust
side pads to these and masks):

  * ``F = 16``       feature columns (job features + cluster descriptors,
                     zero-padded; padded columns get zero kNN weight and
                     zero basis coefficients, so they are inert)
  * ``KNN_T = 512``  training rows for the pessimistic model (≥ the
                     largest per-job corpus slice, PageRank's 282)
  * ``KNN_Q = 64``   queries per batch (a configurator sweep chunk)
  * ``KNN_K = 5``    neighbours
  * ``OPT_BATCH = 256`` rows per optimistic training/prediction batch
  * ``OPT_PARAMS = 1 + 3·F`` factorized-model parameters
"""

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.knn import weighted_sqdist

F = 16
KNN_T = 512
KNN_Q = 64
KNN_K = 5
OPT_BATCH = 256
OPT_PARAMS = 1 + 3 * F

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# --------------------------------------------------------------------------
# Pessimistic model: similarity-weighted kNN over shared runtime data.
# --------------------------------------------------------------------------
def _smallest_k(d, k):
    """Iterative masked-argmin top-k (ascending).

    `jax.lax.top_k` lowers to the modern `topk(..., largest=true)` HLO op,
    which the xla_extension 0.5.1 text parser (the version the `xla` crate
    binds) rejects. With k static and tiny (5), k rounds of
    argmin + mask-out lower to plain reduce/select/iota ops that parse
    everywhere, at negligible cost next to the distance matrix.

    Args:
      d: [Q, T] distances.
    Returns:
      (vals [Q, k], idx [Q, k]) — the k smallest entries per row.
    """
    q_n, t_n = d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (q_n, t_n), 1)
    vals, idxs = [], []
    cur = d
    for _ in range(k):
        i = jnp.argmin(cur, axis=1)  # [Q]
        v = jnp.min(cur, axis=1)  # [Q]
        vals.append(v)
        idxs.append(i)
        cur = jnp.where(col == i[:, None], jnp.float32(3.0 * ref.PAD_DISTANCE), cur)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)


def knn_predict(train_x, train_y, valid, weights, queries):
    """Inverse-distance-weighted kNN prediction.

    The distance matrix comes from the L1 Pallas kernel; neighbour
    selection and weighting are plain XLA ops that fuse around it.

    Args:
      train_x: [KNN_T, F] standardized features of shared executions
      train_y: [KNN_T]    standardized log-runtimes
      valid:   [KNN_T]    1.0 = real row, 0.0 = padding
      weights: [F]        per-feature relevance (|corr with runtime|)
      queries: [KNN_Q, F] standardized query configurations

    Returns:
      [KNN_Q] predictions (standardized log-runtime space).
    """
    # L1 Pallas kernel; full-shape tiles so the grid is a single instance
    # (see the kernel's docstring — §Perf iteration 2)
    d = weighted_sqdist(queries, train_x, weights, tile_q=KNN_Q, tile_t=KNN_T)
    d = jnp.where(valid[None, :] > 0.5, d, ref.PAD_DISTANCE)
    nd, idx = _smallest_k(d, KNN_K)
    ny = train_y[idx]
    w = 1.0 / (nd + 1e-6)
    w = jnp.where(nd >= ref.PAD_DISTANCE * 0.5, 0.0, w)
    return jnp.sum(w * ny, axis=-1) / jnp.maximum(jnp.sum(w, axis=-1), 1e-6)


# --------------------------------------------------------------------------
# Optimistic model: factorized per-feature basis GLM (paper §V-B).
# --------------------------------------------------------------------------
def optimistic_predict(params, x01):
    """Forward pass; see `ref.optimistic_predict_ref` (identical math —
    the ref version IS the production graph for this model; it is
    exported AOT so the request path stays in Rust).

    Args:
      params: [OPT_PARAMS]
      x01:    [OPT_BATCH, F] min-max-scaled features
    Returns:
      [OPT_BATCH] standardized log-runtime predictions
    """
    return ref.optimistic_predict_ref(params, x01)


def _masked_mse(params, x01, y, mask, l2):
    pred = optimistic_predict(params, x01)
    se = (pred - y) ** 2 * mask
    mse = jnp.sum(se) / jnp.maximum(jnp.sum(mask), 1.0)
    return mse + l2 * jnp.sum(params[1:] ** 2)


def optimistic_train_step(params, m, v, step, x01, y, mask, lr):
    """One Adam step on masked MSE (+ small L2). Exported AOT; the Rust
    coordinator drives the epoch loop and owns convergence/early-stop.

    Args:
      params, m, v: [OPT_PARAMS] parameters and Adam moments
      step:  scalar f32, 1-based step count (for bias correction)
      x01:   [OPT_BATCH, F]
      y:     [OPT_BATCH] standardized log-runtimes
      mask:  [OPT_BATCH] 1.0 = real row, 0.0 = padding
      lr:    scalar f32

    Returns:
      (params', m', v', loss)
    """
    loss, grad = jax.value_and_grad(_masked_mse)(params, x01, y, mask, 1e-4)
    p2, m2, v2 = ref.adam_step_ref(
        params, m, v, step, grad, lr, ADAM_B1, ADAM_B2, ADAM_EPS
    )
    return p2, m2, v2, loss


def optimistic_init():
    """Zero-initialized parameters and Adam moments."""
    z = jnp.zeros((OPT_PARAMS,), jnp.float32)
    return z, z, z


# --------------------------------------------------------------------------
# Example-argument factories for AOT lowering (shapes only, not values).
# --------------------------------------------------------------------------
def knn_example_args():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((KNN_T, F), f32),  # train_x
        jax.ShapeDtypeStruct((KNN_T,), f32),  # train_y
        jax.ShapeDtypeStruct((KNN_T,), f32),  # valid
        jax.ShapeDtypeStruct((F,), f32),  # weights
        jax.ShapeDtypeStruct((KNN_Q, F), f32),  # queries
    )


def optimistic_predict_example_args():
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((OPT_PARAMS,), f32),
        jax.ShapeDtypeStruct((OPT_BATCH, F), f32),
    )


def optimistic_train_example_args():
    f32 = jnp.float32
    p = jax.ShapeDtypeStruct((OPT_PARAMS,), f32)
    return (
        p,  # params
        p,  # m
        p,  # v
        jax.ShapeDtypeStruct((), f32),  # step
        jax.ShapeDtypeStruct((OPT_BATCH, F), f32),  # x01
        jax.ShapeDtypeStruct((OPT_BATCH,), f32),  # y
        jax.ShapeDtypeStruct((OPT_BATCH,), f32),  # mask
        jax.ShapeDtypeStruct((), f32),  # lr
    )
