//! Coverage-preserving sampling of shared runtime data.
//!
//! §III-C of the paper: if the shared dataset grows too large for a quick
//! download or fast training, "have the user only download a preselected
//! sample of the historical runtime data of a specified maximal size,
//! which covers the whole feature space most effectively."
//!
//! We implement that preselection as **farthest-point (k-center greedy)
//! sampling** in the standardized feature space: starting from the point
//! closest to the centroid, repeatedly add the record whose minimum
//! distance to the selected set is largest. The result is a subset whose
//! covering radius is within 2× of optimal (classic k-center guarantee),
//! i.e. no region of the observed feature space is left unrepresented.

use crate::cloud::Cloud;
use crate::repo::featurize::Featurizer;
use crate::repo::RuntimeDataRepo;

/// Select up to `max_records` indices covering the repo's feature space.
///
/// Returns indices into `repo.records()`, in selection order (so prefixes
/// of the result are themselves good smaller samples).
pub fn coverage_sample(repo: &RuntimeDataRepo, cloud: &Cloud, max_records: usize) -> Vec<usize> {
    let n = repo.len();
    if n == 0 || max_records == 0 {
        return Vec::new();
    }
    if max_records >= n {
        return (0..n).collect();
    }
    let featurizer = Featurizer::new(cloud);
    let (_, x, _) = featurizer.fit(repo);
    let d = x.cols;

    // Seed: the record nearest the centroid (standardized space ⇒ origin).
    // c3o-lint: allow(float-order) — sequential in-order row reduction; summation order is fixed
    let norm2 = |row: &[f32]| -> f64 { row.iter().map(|&v| (v as f64).powi(2)).sum() };
    let seed = (0..n)
        .min_by(|&a, &b| {
            norm2(x.row(a))
                .partial_cmp(&norm2(x.row(b)))
                .unwrap()
        })
        .unwrap();

    let dist2 = |a: usize, b: usize| -> f64 {
        let (ra, rb) = (x.row(a), x.row(b));
        (0..d)
            .map(|c| ((ra[c] - rb[c]) as f64).powi(2))
            // c3o-lint: allow(float-order) — sequential in-order column reduction; summation order is fixed
            .sum()
    };

    let mut selected = vec![seed];
    let mut min_d2: Vec<f64> = (0..n).map(|i| dist2(i, seed)).collect();
    while selected.len() < max_records {
        // farthest point from the selected set
        let (far, &far_d2) = min_d2
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if far_d2 == 0.0 {
            break; // everything is a duplicate of a selected point
        }
        selected.push(far);
        for i in 0..n {
            let d2 = dist2(i, far);
            if d2 < min_d2[i] {
                min_d2[i] = d2;
            }
        }
    }
    selected
}

/// Materialize a sampled repository of at most `max_records` records.
pub fn sampled_repo(repo: &RuntimeDataRepo, cloud: &Cloud, max_records: usize) -> RuntimeDataRepo {
    let idx = coverage_sample(repo, cloud, max_records);
    let records = idx.iter().map(|&i| repo.records()[i].clone());
    RuntimeDataRepo::from_records(repo.job(), records)
}

/// The covering radius achieved by a sample: the maximum over all records
/// of the distance to the nearest selected record (standardized space).
/// Used by tests and the sampling ablation bench.
pub fn covering_radius(repo: &RuntimeDataRepo, cloud: &Cloud, sample_idx: &[usize]) -> f64 {
    assert!(!sample_idx.is_empty());
    let featurizer = Featurizer::new(cloud);
    let (_, x, _) = featurizer.fit(repo);
    let d = x.cols;
    let mut worst: f64 = 0.0;
    for i in 0..x.rows {
        let mut best = f64::INFINITY;
        for &s in sample_idx {
            let d2: f64 = (0..d)
                .map(|c| ((x.at(i, c) - x.at(s, c)) as f64).powi(2))
                // c3o-lint: allow(float-order) — sequential in-order column reduction; summation order is fixed
                .sum();
            best = best.min(d2);
        }
        worst = worst.max(best);
    }
    worst.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::RuntimeRecord;
    use crate::util::rng::Pcg32;
    use crate::workloads::JobKind;

    fn synthetic_repo(n: usize, seed: u64) -> RuntimeDataRepo {
        let mut rng = Pcg32::new(seed);
        let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];
        let recs = (0..n).map(|_| RuntimeRecord {
            job: JobKind::Sort,
            org: "o".into(),
            machine: machines[rng.index(3)].into(),
            scaleout: 2 * rng.range_u64(1, 6) as u32,
            job_features: vec![rng.range_f64(10.0, 20.0)],
            runtime_s: rng.range_f64(50.0, 500.0),
        });
        RuntimeDataRepo::from_records(JobKind::Sort, recs)
    }

    #[test]
    fn sample_size_respected() {
        let cloud = Cloud::aws_like();
        let repo = synthetic_repo(100, 1);
        let idx = coverage_sample(&repo, &cloud, 20);
        assert_eq!(idx.len(), 20);
        // distinct indices
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn small_repo_returned_whole() {
        let cloud = Cloud::aws_like();
        let repo = synthetic_repo(10, 2);
        let idx = coverage_sample(&repo, &cloud, 50);
        assert_eq!(idx.len(), 10);
    }

    #[test]
    fn coverage_beats_prefix_sampling() {
        // The greedy sample's covering radius must beat "first k records".
        let cloud = Cloud::aws_like();
        let repo = synthetic_repo(200, 3);
        let greedy = coverage_sample(&repo, &cloud, 15);
        let prefix: Vec<usize> = (0..15).collect();
        let r_greedy = covering_radius(&repo, &cloud, &greedy);
        let r_prefix = covering_radius(&repo, &cloud, &prefix);
        assert!(
            r_greedy < r_prefix,
            "greedy {r_greedy} should beat prefix {r_prefix}"
        );
    }

    #[test]
    fn radius_shrinks_with_sample_size() {
        let cloud = Cloud::aws_like();
        let repo = synthetic_repo(150, 4);
        let r5 = covering_radius(&repo, &cloud, &coverage_sample(&repo, &cloud, 5));
        let r40 = covering_radius(&repo, &cloud, &coverage_sample(&repo, &cloud, 40));
        assert!(r40 < r5, "r40 {r40} < r5 {r5}");
    }

    #[test]
    fn prefix_property_holds() {
        // selection order means a prefix is itself a coverage sample
        let cloud = Cloud::aws_like();
        let repo = synthetic_repo(80, 5);
        let idx20 = coverage_sample(&repo, &cloud, 20);
        let idx10 = coverage_sample(&repo, &cloud, 10);
        assert_eq!(&idx20[..10], &idx10[..]);
    }

    #[test]
    fn sampled_repo_is_valid() {
        let cloud = Cloud::aws_like();
        let repo = synthetic_repo(60, 6);
        let s = sampled_repo(&repo, &cloud, 12);
        assert_eq!(s.len(), 12);
        assert_eq!(s.job(), JobKind::Sort);
    }
}
