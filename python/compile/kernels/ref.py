"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the *specification*: slow, obviously-correct implementations that
the Pallas kernels (and the Rust-side PJRT executions) are validated
against in pytest. Nothing here is ever AOT-exported.
"""

import jax
import jax.numpy as jnp

#: Distance assigned to padded (invalid) training rows.
PAD_DISTANCE = 1e30


def weighted_sqdist_ref(queries, train, weights):
    """Weighted squared Euclidean distance matrix.

    D[q, t] = sum_f weights[f] * (queries[q, f] - train[t, f])**2

    Args:
      queries: [Q, F] float32
      train:   [T, F] float32
      weights: [F]    float32, non-negative feature weights

    Returns:
      [Q, T] float32
    """
    diff = queries[:, None, :] - train[None, :, :]  # [Q, T, F]
    return jnp.sum(weights[None, None, :] * diff * diff, axis=-1)


def knn_predict_ref(train_x, train_y, valid, weights, queries, k, eps=1e-6):
    """Similarity-weighted k-nearest-neighbour prediction (the paper's
    "pessimistic" model): inverse-distance-weighted mean of the k most
    similar historical executions.

    Args:
      train_x: [T, F] standardized training features
      train_y: [T]    standardized log-runtimes
      valid:   [T]    1.0 for real rows, 0.0 for padding
      weights: [F]    per-feature relevance weights (|corr with runtime|)
      queries: [Q, F] standardized query features
      k:       neighbours to use

    Returns:
      [Q] predictions in the same (standardized log) space as train_y.
    """
    d = weighted_sqdist_ref(queries, train_x, weights)  # [Q, T]
    d = jnp.where(valid[None, :] > 0.5, d, PAD_DISTANCE)
    neg_top, idx = jax.lax.top_k(-d, k)  # [Q, k]
    nd = -neg_top  # k smallest distances
    ny = train_y[idx]  # [Q, k]
    w = 1.0 / (nd + eps)
    # if fewer than k valid rows exist, padded picks get zero weight
    w = jnp.where(nd >= PAD_DISTANCE * 0.5, 0.0, w)
    return jnp.sum(w * ny, axis=-1) / jnp.maximum(jnp.sum(w, axis=-1), eps)


def optimistic_basis_ref(x01):
    """Per-feature basis expansion for the factorized "optimistic" model.

    Each feature (min-max scaled to roughly [0, 1]) contributes three
    basis functions: identity, log1p, and a reciprocal term (which lets
    the model express Ernest-style 1/n scale-out laws). The factorization
    assumes pairwise-independent features (paper §V-B), so there are no
    cross terms — parameter count stays linear in F and the model trains
    on sparse collaborative data.

    Args:
      x01: [N, F] features scaled to [0, 1]
    Returns:
      [N, 3F] basis matrix
    """
    lin = x01
    log = jnp.log1p(x01)
    inv = 1.0 / (x01 + 0.1)
    return jnp.concatenate([lin, log, inv], axis=-1)


def optimistic_predict_ref(params, x01):
    """Factorized model forward pass: log-runtime = bias + basis @ theta.

    Args:
      params: [1 + 3F] — bias followed by basis coefficients
      x01:    [N, F]
    Returns:
      [N] standardized log-runtime predictions
    """
    b = params[0]
    theta = params[1:]
    return b + optimistic_basis_ref(x01) @ theta


def adam_step_ref(params, m, v, step, grad, lr, b1=0.9, b2=0.999, eps=1e-8):
    """Reference Adam update (bias-corrected); `step` counts from 1."""
    m2 = b1 * m + (1.0 - b1) * grad
    v2 = b2 * v + (1.0 - b2) * grad * grad
    mhat = m2 / (1.0 - b1**step)
    vhat = v2 / (1.0 - b2**step)
    return params - lr * mhat / (jnp.sqrt(vhat) + eps), m2, v2
