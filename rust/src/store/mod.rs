//! Durable persistence + federation: the subsystem that turns the
//! in-memory collaborative repositories into long-lived, *shared*
//! state — the paper's premise that runtime data outlives any one
//! process and flows between organizations.
//!
//! Both halves replay **one abstraction**: the per-(org, job)
//! sequence-numbered operation log the repository maintains
//! ([`crate::repo`]). Every accepted mutation gets a monotone per-org
//! seqno; a [`crate::repo::OrgWatermark`] is a log position
//! `(seqno, digest)`; deltas are the ops past a position. The store and
//! the sync protocol are two consumers of that log, not two parallel
//! change-tracking mechanisms:
//!
//! * [`segment`] — the **durable segment store**: per-[`JobKind`]
//!   append-only WALs whose lines carry both the generation stamp and
//!   the op's org-log seqno (checksummed, torn-tail tolerant), atomic
//!   snapshots paired with an `oplog-<gen>.csv` sidecar, and segment
//!   compaction. A coordinator or service recovers its full corpus —
//!   bitwise, including record order *and* org-log positions — from
//!   [`JobStore::open`] on startup, then warms its model caches from
//!   the recovered generation. Legacy (PR-3 format) WALs and snapshots
//!   still recover: lines without the seqno field get their numbers
//!   assigned during (deterministic) replay.
//! * [`sync`] — the **record-level peer delta-sync protocol** (API v3):
//!   watermark positions drive `SyncPull`/`SyncPush` exchanges that
//!   ship sequence-numbered [`crate::repo::SyncOp`]s — **O(changed
//!   records)** per exchange on prefix-aligned logs, a digest-checked
//!   whole-org fallback on divergence. Merge-level dedup with
//!   deterministic conflict resolution makes the exchange idempotent
//!   and convergent (any gossip order → bitwise-identical
//!   repositories), and merge-rejected ops are logged as *seen* — the
//!   watermark advances, so blind duplicate contributions transfer once
//!   and are never re-offered. [`SyncDriver`] runs the exchange on a
//!   background thread; [`sync_job_v2`] speaks the legacy org-granular
//!   protocol to pre-op-log deployments.
//!
//! The write path is layered: a [`JobShard`](crate::coordinator::shard)
//! mutates its repo, WAL-frames exactly the logged ops through its
//! attached [`JobStore`] (applied mutations as `C`/`M` lines, seen
//! rejections as generation-neutral `S` lines), and lets
//! [`JobStore::maybe_compact`] fold the WAL into a snapshot + sidecar
//! when it grows. Reads never touch the store.

pub mod segment;
pub mod sync;

pub use segment::{
    FsyncPolicy, JobStore, StoreConfig, StoreOp, DEFAULT_COMPACT_THRESHOLD, DEFAULT_SEGMENT_CAP,
};
pub use sync::{
    fold_orgs, sync_all, sync_all_detailed, sync_job, sync_job_detailed, sync_job_v2,
    OrgExchange, OrgExchangeMap, SyncDriver, SyncStats,
};

use crate::api::ApiError;
use crate::repo::RuntimeDataRepo;
use crate::workloads::JobKind;
use std::path::Path;

/// Open (or create) the per-job stores under `root`, recovering every
/// job's repository — one entry per [`JobKind::all`] kind, in that
/// order.
pub fn open_all(root: &Path) -> Result<Vec<(JobStore, RuntimeDataRepo)>, ApiError> {
    JobKind::all()
        .into_iter()
        .map(|kind| JobStore::open(root, kind))
        .collect()
}
