//! Fixture: every malformed / unjustified / unknown suppression form.

// c3o-lint: allow(no-such-rule) — fixture: the rule name does not exist
pub fn a() {}

// c3o-lint: allow(hash-iter)
pub fn b() {}

// c3o-lint: frobnicate(hash-iter) — fixture: unknown directive name
pub fn c() {}

// c3o-lint: allow hash-iter — fixture: missing parentheses
pub fn d() {}

// c3o-lint: holds(filesystem) — fixture: not a configured lock class
pub fn e() {}

// c3o-lint: allow-fn(float-order) — fixture: dangling, no fn follows
