//! Bench: prediction-model accuracy (paper §V).
//!
//! Reproduces the paper's model-requirements analysis as numbers:
//!
//! * **Interpolation vs extrapolation** — pessimistic (similarity) vs
//!   optimistic (factorized) MAPE on (a) a random held-out split of the
//!   corpus and (b) an extrapolation split (train scale-outs 2–8,
//!   predict 10–12), per job.
//! * **Data density** — MAPE of both models as the training repository
//!   is thinned by coverage sampling.
//! * **Dynamic selection** — the CV-chosen model is never worse than the
//!   worse of the two (and tracks the better one).
//!
//! Claims asserted: pessimistic wins interpolation on dense data;
//! optimistic degrades more gracefully on the extrapolation split;
//! dynamic selection tracks the winner.

use c3o::cloud::Cloud;
use c3o::models::selection::select_and_train;
use c3o::models::{ConfigQuery, ModelKind, Predictor};
use c3o::repo::sampling::sampled_repo;
use c3o::repo::RuntimeDataRepo;
use c3o::runtime::Runtime;
use c3o::util::bench::Bench;
use c3o::util::rng::Pcg32;
use c3o::util::stats;
use c3o::workloads::{ExperimentGrid, JobKind};

fn job_repo(cloud: &Cloud, kind: JobKind, seed: u64) -> RuntimeDataRepo {
    let grid = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == kind)
            .collect(),
        repetitions: 5,
    };
    grid.execute(cloud, seed).repo_for(kind)
}

fn queries_and_truth(records: &[c3o::repo::RuntimeRecord]) -> (Vec<ConfigQuery>, Vec<f64>) {
    (
        records
            .iter()
            .map(|r| ConfigQuery {
                machine: r.machine.clone(),
                scaleout: r.scaleout,
                job_features: r.job_features.clone(),
            })
            .collect(),
        records.iter().map(|r| r.runtime_s).collect(),
    )
}

fn split_random(
    repo: &RuntimeDataRepo,
    frac_test: f64,
    seed: u64,
) -> (RuntimeDataRepo, Vec<c3o::repo::RuntimeRecord>) {
    let mut rng = Pcg32::new(seed);
    let mut train = RuntimeDataRepo::new(repo.job());
    let mut test = Vec::new();
    for r in repo.records() {
        if rng.chance(frac_test) {
            test.push(r.clone());
        } else {
            train.contribute(r.clone()).unwrap();
        }
    }
    (train, test)
}

fn split_extrapolation(
    repo: &RuntimeDataRepo,
) -> (RuntimeDataRepo, Vec<c3o::repo::RuntimeRecord>) {
    let mut train = RuntimeDataRepo::new(repo.job());
    let mut test = Vec::new();
    for r in repo.records() {
        if r.scaleout <= 8 {
            train.contribute(r.clone()).unwrap();
        } else {
            test.push(r.clone());
        }
    }
    (train, test)
}

fn mape_of(
    predictor: &mut Predictor,
    cloud: &Cloud,
    train: &RuntimeDataRepo,
    test: &[c3o::repo::RuntimeRecord],
    kind: ModelKind,
) -> f64 {
    let model = predictor.train(cloud, train, kind).unwrap();
    let (qs, truth) = queries_and_truth(test);
    let preds = predictor.predict(&model, cloud, &qs).unwrap();
    stats::mape(&preds, &truth)
}

fn main() {
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("SKIP model_accuracy: artifacts not built (run `make artifacts`)");
        return;
    }
    let cloud = Cloud::aws_like();
    let mut predictor = Predictor::new(&dir).unwrap();

    // ---- interpolation vs extrapolation, per job -------------------------
    println!("== §V: interpolation vs extrapolation MAPE (%) per job ==\n");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>14}",
        "job", "pess_interp", "opt_interp", "pess_extrap", "opt_extrap"
    );
    // Per-job errors; the §V claims are regime-based:
    //  (a) with the shared corpus, *interpolation* is accurate for both
    //      families (< 35% MAPE everywhere);
    //  (b) on the cleanly-scaling job (sort), the factorized model
    //      extrapolates to unseen scale-outs markedly better (§V-B);
    //  (c) neither family dominates across jobs — "which of these
    //      approaches performs better depends on the particular
    //      situation" (§V-C), the motivation for dynamic selection.
    let mut extrap: Vec<(JobKind, f64, f64)> = Vec::new();
    for kind in JobKind::all() {
        let repo = job_repo(&cloud, kind, 42);
        let (tr_i, te_i) = split_random(&repo, 0.2, 7);
        let (tr_e, te_e) = split_extrapolation(&repo);
        let pi = mape_of(&mut predictor, &cloud, &tr_i, &te_i, ModelKind::Pessimistic);
        let oi = mape_of(&mut predictor, &cloud, &tr_i, &te_i, ModelKind::Optimistic);
        let pe = mape_of(&mut predictor, &cloud, &tr_e, &te_e, ModelKind::Pessimistic);
        let oe = mape_of(&mut predictor, &cloud, &tr_e, &te_e, ModelKind::Optimistic);
        println!(
            "{:<10} {:>11.1} {:>12.1} {:>14.1} {:>14.1}",
            kind.name(),
            pi,
            oi,
            pe,
            oe
        );
        assert!(pi < 35.0 && oi < 35.0, "{kind:?}: interpolation must be accurate");
        extrap.push((kind, pe, oe));
    }
    let (_, sort_pe, sort_oe) = extrap
        .iter()
        .find(|(k, _, _)| *k == JobKind::Sort)
        .copied()
        .unwrap();
    assert!(
        sort_oe < sort_pe,
        "§V-B: factorized model should extrapolate scale-out better on sort \
         (opt {sort_oe:.1}% vs pess {sort_pe:.1}%)"
    );
    let opt_wins_somewhere = extrap.iter().any(|(_, pe, oe)| *pe > 1.2 * *oe);
    let pess_wins_somewhere = extrap.iter().any(|(_, pe, oe)| *oe > 1.2 * *pe);
    println!(
        "\nsituation-dependence: optimistic clearly better somewhere: {opt_wins_somewhere}; \
         pessimistic clearly better somewhere: {pess_wins_somewhere}"
    );
    assert!(
        opt_wins_somewhere && pess_wins_somewhere,
        "§V-C: neither family should dominate — that's why selection is dynamic"
    );

    // ---- data-density sweep (grep) ---------------------------------------
    println!("\n== §V: MAPE vs training-data density (grep) ==\n");
    println!(
        "{:>8} {:>12} {:>12} {:>10}",
        "records", "pessimistic", "optimistic", "cv_choice"
    );
    let repo = job_repo(&cloud, JobKind::Grep, 42);
    let (full_train, test) = split_random(&repo, 0.25, 9);
    for size in [15usize, 30, 60, 120] {
        let train = if size >= full_train.len() {
            full_train.clone()
        } else {
            sampled_repo(&full_train, &cloud, size)
        };
        let p = mape_of(&mut predictor, &cloud, &train, &test, ModelKind::Pessimistic);
        let o = mape_of(&mut predictor, &cloud, &train, &test, ModelKind::Optimistic);
        let (_, report) = select_and_train(&mut predictor, &cloud, &train, 3, 1).unwrap();
        println!(
            "{:>8} {:>11.1} {:>11.1} {:>10}",
            train.len(),
            p,
            o,
            report.chosen.name()
        );
    }

    // ---- dynamic selection tracks the winner ------------------------------
    println!("\n== §V-C: dynamic selection sanity ==");
    let mut tracked = 0;
    for kind in JobKind::all() {
        let repo = job_repo(&cloud, kind, 43);
        let (train, test) = split_random(&repo, 0.2, 11);
        let (model, report) = select_and_train(&mut predictor, &cloud, &train, 4, 2).unwrap();
        let (qs, truth) = queries_and_truth(&test);
        let preds = predictor.predict(&model, &cloud, &qs).unwrap();
        let chosen_mape = stats::mape(&preds, &truth);
        let worse_cv = report
            .cv_mape
            .iter()
            .map(|(_, m)| *m)
            .fold(0.0f64, f64::max);
        println!(
            "{:<10} chose {:<12} held-out MAPE {:>6.1}% (worst CV {:>6.1}%)",
            kind.name(),
            model.kind.name(),
            chosen_mape,
            worse_cv
        );
        if chosen_mape <= worse_cv * 1.5 {
            tracked += 1;
        }
    }
    assert!(tracked >= 4, "dynamic selection should track the better model");

    // ---- timing -----------------------------------------------------------
    let mut b = Bench::new("model_accuracy");
    let repo = job_repo(&cloud, JobKind::Grep, 42);
    let (qs, _) = queries_and_truth(repo.records());
    let model = predictor
        .train(&cloud, &repo, ModelKind::Pessimistic)
        .unwrap();
    b.run("knn_predict_162_queries_pjrt", || {
        predictor.predict(&model, &cloud, &qs).unwrap().len()
    });
    let model_o = predictor
        .train(&cloud, &repo, ModelKind::Optimistic)
        .unwrap();
    b.run("opt_predict_162_queries_pjrt", || {
        predictor.predict(&model_o, &cloud, &qs).unwrap().len()
    });
    b.finish();
}
