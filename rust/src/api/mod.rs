//! The public request/response protocol of the C3O coordination stack.
//!
//! Every deployment shape — the sequential [`Coordinator`], the ordered
//! single-worker [`session`](crate::coordinator::session), and the
//! concurrent multi-worker [`service`](crate::coordinator::service) —
//! speaks the same versioned, typed protocol:
//!
//! * [`Request`] — the closed set of operations a client can ask for.
//!   The paper's collaborative loop has two asymmetric halves, and the
//!   protocol keeps them distinct: **reads** ([`Request::Recommend`],
//!   [`Request::SnapshotInfo`], [`Request::Metrics`],
//!   [`Request::Watermarks`], [`Request::SyncPull`]) never mutate the
//!   shared repositories, while **writes** ([`Request::Submit`],
//!   [`Request::Contribute`], [`Request::Share`],
//!   [`Request::SyncPush`]) both mutate them and refresh the
//!   generation-stamped model the reads are served from. The
//!   federation requests are the peer exchange of [`crate::store`]:
//!   watermark read → delta pull → idempotent push, driven by
//!   [`sync`](crate::store::sync::sync), with a batched cross-job
//!   form ([`Request::SyncPullAll`]/[`Request::SyncPushAll`]) covering
//!   all five job kinds in one round trip, and the mesh-membership
//!   pair ([`Request::MeshHello`]/[`Request::MeshRoster`]) by which
//!   peers discover each other (see [`crate::store::mesh`]). Legacy v2
//!   exchanges are quarantined behind the [`compat`] adapter.
//! * [`Response`] — one typed variant per request, so a protocol-level
//!   mismatch is a bug surfaced as [`ApiError::Protocol`], never a
//!   silently misinterpreted reply.
//! * [`ApiError`] — the structured error taxonomy of the public
//!   boundary. Internal layers (models, simulator, cloud) keep using
//!   `anyhow` context chains; they are folded into
//!   [`ApiError::Internal`] exactly once, at this boundary.
//! * [`Client`] — the deployment-agnostic trait: anything that can
//!   [`Client::call`] the protocol. Examples, benches, the CLI, and the
//!   shared integration suite are written against `dyn Client`, so the
//!   same code drives all three deployments.
//!
//! The split matters operationally: `Recommend` ("which cluster should I
//! buy?") is the hot, read-mostly half — C3O's configurator step — and
//! in the concurrent service it is served from an immutable
//! [`ModelSnapshot`](crate::coordinator::shard::ModelSnapshot) without
//! ever taking a shard lock. `Contribute` ("here is the runtime I
//! observed") is the rare write that closes the collaborative loop, as
//! in the paper's capture-and-share step.

// Serving zone: unwraps are outages. The module-scoped clippy
// promotion mirrors the repo lint's `no-panic-serving` rule
// (see rust/lint).
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod compat;

pub use compat::{SyncDeltaV2, WatermarkSetV2};

use crate::cloud::Cloud;
use crate::configurator::{ClusterChoice, JobRequest};
use crate::coordinator::{JobOutcome, Metrics, Organization};
use crate::models::ModelKind;
use crate::repo::{
    LoggedOp, MergeConflict, OrgSnapshot, OrgWatermark, OrgWatermarkV2, RuntimeDataRepo,
    RuntimeRecord, SyncOp,
};
use crate::util::json::Json;
use crate::workloads::JobKind;
use std::collections::BTreeMap;
use std::fmt;

/// Protocol version. Bump on any breaking change to [`Request`],
/// [`Response`], or [`ApiError`]; servers answer
/// [`Request::SnapshotInfo`] with the version they speak so
/// mixed-version tooling can detect skew.
///
/// The complete version ladder — every wire shape the stack has ever
/// spoken, and where each lives today:
///
/// * v1 — the pre-federation protocol: `Submit`/`Recommend`/
///   `Contribute`/`Share`/`Metrics`/`SnapshotInfo`. All still served
///   unchanged; v1 clients never notice the later rungs.
/// * v2 — federation: `Watermarks`/`SyncPull`/`SyncPush` requests over
///   org-granular *holdings* watermarks ([`OrgWatermarkV2`]), the
///   [`ApiError::Store`] class, structured merge conflicts. The v2
///   exchange shapes survive as `WatermarksV2`/`SyncPullV2`/
///   `SyncPushV2`, quarantined behind the [`compat`] adapter — core
///   serve paths never see them.
/// * v3 — record-level deltas: watermarks are per-org op-log positions
///   (`(seqno, digest)` [`OrgWatermark`]s), `SyncPull`/`SyncPush` ship
///   sequence-numbered [`SyncOp`]s (O(changed records) per exchange),
///   and merge-rejected ops advance the receiver's watermark so blind
///   duplicates are never re-offered.
/// * v4 — mesh federation: peer membership over
///   `MeshHello`/`MeshRoster` ([`MeshHello`] carries roster gossip and
///   post-apply acks), cross-job batched exchange
///   (`WatermarksAll`/`SyncPullAll`/`SyncPushAll` — one round trip for
///   all five job kinds), and acked-floor op-log truncation:
///   [`OrgWatermark`] gains a `floor` (v3 peers decode it as the
///   `Default` 0 = full history), and deltas gain whole-org
///   [`OrgSnapshot`] fallbacks for peers below a responder's floor.
pub const API_VERSION: u32 = 4;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// Structured error taxonomy of the public API boundary.
///
/// Replaces `anyhow` in every public coordinator signature: callers can
/// match on the failure class instead of parsing message strings, and
/// only [`ApiError::Internal`] carries a rendered context chain.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// The request failed validation before touching any shared state
    /// (non-positive runtime target, non-finite job feature, record that
    /// fails repository validation, unknown machine type, ...).
    InvalidRequest(String),
    /// A read-only recommendation is impossible: the job's shared
    /// repository has too little data to train a model, and `Recommend`
    /// — unlike `Submit` — has no overprovisioning fallback to run.
    ColdStart {
        job: JobKind,
        records: usize,
        min_records: usize,
    },
    /// Request/response pairing violated (a deployment answered a
    /// request with the wrong response variant). Always a bug.
    Protocol(String),
    /// The serving deployment has shut down (worker gone, channel
    /// closed). Retryable against a fresh deployment.
    Stopped,
    /// The durable segment store failed (I/O error, corrupt segment,
    /// generation desync). The in-memory state may be ahead of disk;
    /// the deployment keeps serving, but durability is degraded until
    /// the store recovers.
    Store(String),
    /// Internal failure below the API boundary (model training, the
    /// dataflow simulator, catalog lookups). Carries the full `anyhow`
    /// context chain, rendered.
    Internal(String),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            ApiError::ColdStart {
                job,
                records,
                min_records,
            } => write!(
                f,
                "cold start: {} repository has {records} records, {min_records} needed \
                 before recommendations can be served",
                job.name()
            ),
            ApiError::Protocol(msg) => write!(f, "protocol violation: {msg}"),
            ApiError::Stopped => write!(f, "service stopped"),
            ApiError::Store(msg) => write!(f, "store error: {msg}"),
            ApiError::Internal(msg) => write!(f, "internal error: {msg}"),
        }
    }
}

impl std::error::Error for ApiError {}

impl From<anyhow::Error> for ApiError {
    fn from(e: anyhow::Error) -> ApiError {
        ApiError::Internal(format!("{e:#}"))
    }
}

impl ApiError {
    /// Fold an internal `anyhow` error into the taxonomy.
    // c3o-lint: allow(no-anyhow-public) — this IS the designated fold-in point where internal anyhow chains become taxonomy errors
    pub fn internal(e: anyhow::Error) -> ApiError {
        ApiError::from(e)
    }

    /// Fold a segment-store failure into the taxonomy (full chain).
    // c3o-lint: allow(no-anyhow-public) — this IS the designated fold-in point where store anyhow chains become taxonomy errors
    pub fn store(e: anyhow::Error) -> ApiError {
        ApiError::Store(format!("{e:#}"))
    }
}

/// Shared write-path validation: reject records whose machine type is
/// absent from the catalog. Such records can never be featurized, so
/// letting one into a shared repository would poison every later
/// training run. Used identically by all deployments so they reject
/// identically. Accepts any borrowing iterator (a record slice, or the
/// records inside a sync-op delta) so hot paths never clone to
/// validate.
pub fn validate_machines<'a, I>(cloud: &Cloud, records: I) -> Result<(), ApiError>
where
    I: IntoIterator<Item = &'a RuntimeRecord>,
{
    if let Some(bad) = records
        .into_iter()
        .find(|r| cloud.machine(&r.machine).is_none())
    {
        return Err(ApiError::InvalidRequest(format!(
            "unknown machine type {:?}",
            bad.machine
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// mesh membership (v4 wire types)
// ---------------------------------------------------------------------------

/// One mesh participant: a human-readable name plus the deterministic
/// 64-bit ID derived from it ([`crate::store::mesh::peer_id`]). The ID
/// is what membership logic compares — two deployments claiming the
/// same name are the same peer.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MeshPeer {
    pub name: String,
    pub id: u64,
}

/// The one gossip message of the membership layer. A hello carries
/// three things at once: liveness (the sender is alive this round),
/// roster gossip (`known` — every peer the sender believes in, so
/// membership spreads transitively), and acknowledgement (`acked` —
/// the sender's own post-apply watermarks per job, which the receiver
/// records as "this peer holds at least these prefixes", the input to
/// acked-floor truncation). Answered by [`Response::MeshView`] with
/// the receiver's updated roster.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeshHello {
    pub from: MeshPeer,
    /// Every peer the sender currently believes to be a member.
    pub known: Vec<MeshPeer>,
    /// The sender's own per-job watermarks — its acks.
    pub acked: Vec<WatermarkSet>,
}

/// One roster row of a [`MeshView`]: a member plus its liveness state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeshPeerStatus {
    pub peer: MeshPeer,
    /// The responder's local round when this member last helloed
    /// (`0` = known only by gossip, never heard directly).
    pub last_seen_round: u64,
    /// False once the member has missed enough rounds to be considered
    /// stale (it remains listed until eviction removes it).
    pub live: bool,
}

/// A deployment's view of the mesh: its own identity, its local round
/// counter, and the roster in deterministic (name-sorted) order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeshView {
    pub local: MeshPeer,
    /// Local anti-entropy round counter (advanced by self-hellos).
    pub round: u64,
    pub peers: Vec<MeshPeerStatus>,
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

/// One operation against a C3O deployment (protocol [`API_VERSION`]).
#[derive(Debug, Clone)]
pub enum Request {
    /// **Write.** Full submission loop: decide a configuration, provision
    /// and run it on the simulated cloud, contribute the measurement
    /// back. Answered by [`Response::Submitted`].
    Submit {
        org: Organization,
        request: JobRequest,
    },
    /// **Read.** Score every candidate configuration and return the
    /// decision *without* provisioning, running, or contributing —
    /// C3O's configurator step as a standalone query. Answered by
    /// [`Response::Recommendation`].
    Recommend { request: JobRequest },
    /// **Write.** Record one externally-observed run (a job executed
    /// outside this deployment — e.g. a `Recommend`-ed cluster the user
    /// actually ran) into the job's shared repository. Answered by
    /// [`Response::Contributed`].
    Contribute { record: RuntimeRecord },
    /// **Write.** Bulk form of `Contribute`: merge a whole runtime-data
    /// repository (e.g. the public corpus). Answered by
    /// [`Response::Shared`].
    Share { repo: RuntimeDataRepo },
    /// **Read.** Service-wide metrics snapshot. Answered by
    /// [`Response::Metrics`].
    Metrics,
    /// **Read.** Describe the model snapshot currently serving a job's
    /// reads. Answered by [`Response::SnapshotInfo`].
    SnapshotInfo { job: JobKind },
    /// **Read.** The per-organization op-log positions of a job's
    /// shared repository — what a peer sends to ask "what am I
    /// missing?". Answered by [`Response::Watermarks`].
    Watermarks { job: JobKind },
    /// **Read.** Record-level delta extraction: the sequence-numbered
    /// ops past each of the requester's marks — O(changed records) when
    /// the logs are prefix-aligned, a whole-org fallback when they have
    /// diverged. The reply also carries the responder's own marks
    /// (priming the reverse direction of a
    /// [`sync_job`](crate::store::sync::sync_job) exchange). Answered by
    /// [`Response::SyncDelta`].
    SyncPull {
        job: JobKind,
        watermarks: BTreeMap<String, OrgWatermark>,
    },
    /// **Write.** Apply a peer's record-level delta through merge-level
    /// dedup with deterministic conflict resolution, canonicalize the
    /// repo order, and refresh the model. Idempotent — re-pushing a
    /// delta changes nothing, and a merge-rejected op still advances the
    /// receiver's watermark (logged as *seen*), so it is never offered
    /// again. `snapshots` carries whole-org fallbacks for orgs whose
    /// history the sender has truncated below the receiver's position
    /// (empty from v3 senders). Answered by [`Response::SyncApplied`].
    SyncPush {
        job: JobKind,
        ops: Vec<SyncOp>,
        snapshots: Vec<OrgSnapshot>,
    },
    /// **Write.** Mesh membership gossip: liveness + roster + acks in
    /// one message (see [`MeshHello`]). A *self*-hello (`from` naming
    /// the deployment itself) is the local anti-entropy tick: it
    /// advances the round, evicts stale members, and re-evaluates
    /// acked-floor truncation. Answered by [`Response::MeshView`].
    MeshHello { hello: MeshHello },
    /// **Read.** The deployment's current mesh roster, without touching
    /// liveness. Answered by [`Response::MeshView`].
    MeshRoster,
    /// **Read.** Watermarks of every job repository in one round trip —
    /// the read half of the batched cross-job exchange. Answered by
    /// [`Response::WatermarksAll`].
    WatermarksAll,
    /// **Read.** Batched cross-job delta extraction: one round trip
    /// covering every job kind the requester sent marks for. Answered
    /// by [`Response::SyncDeltaAll`].
    SyncPullAll { watermarks: Vec<WatermarkSet> },
    /// **Write.** Batched cross-job delta application; the reply also
    /// carries the receiver's post-apply watermarks, so a mesh peer
    /// learns the ack positions without a second round trip. Answered
    /// by [`Response::SyncAppliedAll`].
    SyncPushAll { deltas: Vec<SyncDelta> },
    /// **Read.** Legacy (v2) holdings watermarks, for peers that
    /// predate the op log. Served only through [`compat::serve`].
    /// Answered by [`Response::WatermarksV2`].
    WatermarksV2 { job: JobKind },
    /// **Read.** Legacy (v2) org-granular delta extraction: every held
    /// record of each org whose holdings watermark differs — O(org
    /// corpus) per changed org. Served only through [`compat::serve`]
    /// ([`crate::repo::RuntimeDataRepo::delta_for_v2`]). Answered by
    /// [`Response::SyncDeltaV2`].
    SyncPullV2 {
        job: JobKind,
        watermarks: BTreeMap<String, OrgWatermarkV2>,
    },
    /// **Write.** Legacy (v2) delta application: bare records without
    /// sequence numbers. Translated onto the op log by appending each
    /// *applied* record with a fresh local seqno (which may mark the
    /// org's log divergent from its home — subsequent v3 exchanges for
    /// that org then fall back to whole-org ships, exactly the v2
    /// cost). Served only through [`compat::serve`]. Answered by
    /// [`Response::SyncApplied`].
    SyncPushV2 {
        job: JobKind,
        records: Vec<RuntimeRecord>,
    },
}

impl Request {
    /// The job kind this request routes to, if it routes at all.
    pub fn job(&self) -> Option<JobKind> {
        match self {
            Request::Submit { request, .. } | Request::Recommend { request } => {
                Some(request.kind())
            }
            Request::Contribute { record } => Some(record.job),
            Request::Share { repo } => Some(repo.job()),
            // mesh membership and the batched exchanges span every job;
            // deployments fan them out rather than routing them
            Request::Metrics
            | Request::MeshHello { .. }
            | Request::MeshRoster
            | Request::WatermarksAll
            | Request::SyncPullAll { .. }
            | Request::SyncPushAll { .. } => None,
            Request::SnapshotInfo { job }
            | Request::Watermarks { job }
            | Request::SyncPull { job, .. }
            | Request::SyncPush { job, .. }
            | Request::WatermarksV2 { job }
            | Request::SyncPullV2 { job, .. }
            | Request::SyncPushV2 { job, .. } => Some(*job),
        }
    }

    /// True for requests that can mutate shared state.
    pub fn is_write(&self) -> bool {
        matches!(
            self,
            Request::Submit { .. }
                | Request::Contribute { .. }
                | Request::Share { .. }
                | Request::SyncPush { .. }
                | Request::SyncPushAll { .. }
                | Request::MeshHello { .. }
                | Request::SyncPushV2 { .. }
        )
    }
}

// ---------------------------------------------------------------------------
// responses
// ---------------------------------------------------------------------------

/// A configuration recommendation: the decision `Submit` would make,
/// served read-only.
#[derive(Debug, Clone)]
pub struct Recommendation {
    pub job: JobKind,
    /// The full decision, including every scored candidate.
    pub choice: ClusterChoice,
    /// Which model family served the decision.
    pub model_used: ModelKind,
    /// Repository generation of the snapshot the decision was served
    /// from.
    pub generation: u64,
    /// Generation the serving model was trained at (`<= generation`:
    /// retraining is threshold-gated).
    pub trained_at_generation: u64,
}

/// Acknowledgement of a contribution/merge into a shared repository.
#[derive(Debug, Clone, PartialEq)]
pub struct Contribution {
    pub job: JobKind,
    /// Records actually added (merges dedup by configuration).
    pub added: usize,
    /// Repository generation after the write.
    pub generation: u64,
}

/// Description of the model snapshot currently serving a job's reads.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotInfo {
    /// Protocol version the server speaks.
    pub api_version: u32,
    pub job: JobKind,
    /// Records in the shared repository.
    pub records: usize,
    /// Current repository generation.
    pub generation: u64,
    /// Generation the cached model was trained at, if one is trained.
    pub trained_at_generation: Option<u64>,
    /// Model family of the cached model, if one is trained.
    pub model: Option<ModelKind>,
    /// Machine types observed in the shared data (the candidate axis
    /// recommendations are restricted to), sorted.
    pub observed_machines: Vec<String>,
}

/// A job repository's per-organization op-log positions, stamped with
/// the generation they describe.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WatermarkSet {
    pub job: JobKind,
    /// Repository generation the marks were read at.
    pub generation: u64,
    pub watermarks: BTreeMap<String, OrgWatermark>,
}

/// A record-level delta computed against a peer's watermarks: the
/// sequence-numbered ops the peer is missing (plus whole-org snapshot
/// fallbacks where truncation makes ops impossible), plus the
/// responder's own marks for the reverse direction.
#[derive(Debug, Clone)]
pub struct SyncDelta {
    pub job: JobKind,
    /// Responder's repository generation at extraction time.
    pub generation: u64,
    /// Ops past each of the requester's marks, per-org in sequence
    /// order.
    pub ops: Vec<SyncOp>,
    /// Whole-org fallbacks for orgs where the requester sits below the
    /// responder's truncation floor (v4; always empty before that).
    pub snapshots: Vec<OrgSnapshot>,
    /// The responder's own watermarks.
    pub watermarks: BTreeMap<String, OrgWatermark>,
}

/// The structured result of applying a sync delta.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncReport {
    pub job: JobKind,
    /// Previously-unknown configurations appended.
    pub added: usize,
    /// Existing records replaced by a deterministically-preferred
    /// incoming record.
    pub replaced: usize,
    /// Ops that changed no holdings: already-seen re-deliveries plus
    /// merge-rejected (seen) ops.
    pub skipped: usize,
    /// Runtime disagreements surfaced (whichever side won).
    pub conflicts: Vec<MergeConflict>,
    /// Holdings mutations per organization (adds + replacements,
    /// keyed by the applied record's org) — the `c3o sync --json`
    /// per-org accounting.
    pub applied_by_org: BTreeMap<String, u64>,
    /// Repository generation after the apply.
    pub generation: u64,
}

impl SyncReport {
    /// Total mutations (adds + replacements).
    pub fn changed(&self) -> usize {
        self.added + self.replaced
    }

    /// Assemble a report from one delta application — the one tally
    /// every deployment's push path uses, so the per-org accounting can
    /// never diverge between them. `offered` is the incoming op/record
    /// count; `logged` the ops the repository appended (the per-org
    /// applied counts come from its applied entries).
    pub fn tally(
        job: JobKind,
        offered: usize,
        added: usize,
        replaced: usize,
        conflicts: Vec<MergeConflict>,
        logged: &[LoggedOp],
        generation: u64,
    ) -> SyncReport {
        let mut applied_by_org: BTreeMap<String, u64> = BTreeMap::new();
        for op in logged.iter().filter(|op| op.applied) {
            *applied_by_org.entry(op.record.org.clone()).or_default() += 1;
        }
        SyncReport {
            job,
            added,
            replaced,
            skipped: offered - (added + replaced),
            conflicts,
            applied_by_org,
            generation,
        }
    }
}

/// The result of one batched cross-job push: per-job apply reports
/// plus the receiver's post-apply watermarks (its acks — what a mesh
/// sender records as "this peer now holds these prefixes").
#[derive(Debug, Clone, PartialEq)]
pub struct SyncReportAll {
    /// One report per job the push carried a delta for, in delta order.
    pub reports: Vec<SyncReport>,
    /// The receiver's watermarks for every job, after applying.
    pub watermarks: Vec<WatermarkSet>,
}

/// One typed reply per [`Request`] variant.
// Variant sizes are dominated by `Submitted(JobOutcome)`; boxing it
// would push an allocation + indirection into every submission reply
// for no measurable win (responses move through channels, not arrays).
#[allow(clippy::large_enum_variant)]
#[derive(Debug, Clone)]
pub enum Response {
    Submitted(JobOutcome),
    Recommendation(Recommendation),
    Contributed(Contribution),
    Shared(Contribution),
    Metrics(Metrics),
    SnapshotInfo(SnapshotInfo),
    Watermarks(WatermarkSet),
    SyncDelta(SyncDelta),
    SyncApplied(SyncReport),
    MeshView(MeshView),
    WatermarksAll(Vec<WatermarkSet>),
    SyncDeltaAll(Vec<SyncDelta>),
    SyncAppliedAll(SyncReportAll),
    WatermarksV2(WatermarkSetV2),
    SyncDeltaV2(SyncDeltaV2),
}

impl Response {
    fn kind_name(&self) -> &'static str {
        match self {
            Response::Submitted(_) => "Submitted",
            Response::Recommendation(_) => "Recommendation",
            Response::Contributed(_) => "Contributed",
            Response::Shared(_) => "Shared",
            Response::Metrics(_) => "Metrics",
            Response::SnapshotInfo(_) => "SnapshotInfo",
            Response::Watermarks(_) => "Watermarks",
            Response::SyncDelta(_) => "SyncDelta",
            Response::SyncApplied(_) => "SyncApplied",
            Response::MeshView(_) => "MeshView",
            Response::WatermarksAll(_) => "WatermarksAll",
            Response::SyncDeltaAll(_) => "SyncDeltaAll",
            Response::SyncAppliedAll(_) => "SyncAppliedAll",
            Response::WatermarksV2(_) => "WatermarksV2",
            Response::SyncDeltaV2(_) => "SyncDeltaV2",
        }
    }

    fn unexpected(self, wanted: &str) -> ApiError {
        ApiError::Protocol(format!(
            "expected {wanted} response, got {}",
            self.kind_name()
        ))
    }
}

// ---------------------------------------------------------------------------
// the deployment-agnostic client
// ---------------------------------------------------------------------------

/// Anything that can serve the C3O protocol: the sequential
/// [`Coordinator`](crate::coordinator::Coordinator), the ordered
/// [`Session`](crate::coordinator::session::Session), and the concurrent
/// [`ServiceClient`](crate::coordinator::service::ServiceClient) all
/// implement it, so user code written against `Client` is
/// deployment-agnostic.
///
/// [`Client::call`] is the one required method; the typed convenience
/// wrappers are default methods that pair each request with its response
/// variant (a mismatch is [`ApiError::Protocol`]).
pub trait Client {
    /// Execute one protocol request.
    fn call(&mut self, request: Request) -> Result<Response, ApiError>;

    /// Full submission loop for one job request.
    fn submit(&mut self, org: &Organization, request: JobRequest) -> Result<JobOutcome, ApiError> {
        match self.call(Request::Submit {
            org: org.clone(),
            request,
        })? {
            Response::Submitted(outcome) => Ok(outcome),
            other => Err(other.unexpected("Submitted")),
        }
    }

    /// Read-only configuration recommendation.
    fn recommend(&mut self, request: JobRequest) -> Result<Recommendation, ApiError> {
        match self.call(Request::Recommend { request })? {
            Response::Recommendation(r) => Ok(r),
            other => Err(other.unexpected("Recommendation")),
        }
    }

    /// Record one externally-observed run.
    fn contribute(&mut self, record: RuntimeRecord) -> Result<Contribution, ApiError> {
        match self.call(Request::Contribute { record })? {
            Response::Contributed(c) => Ok(c),
            other => Err(other.unexpected("Contributed")),
        }
    }

    /// Merge a whole runtime-data repository.
    fn share(&mut self, repo: RuntimeDataRepo) -> Result<Contribution, ApiError> {
        match self.call(Request::Share { repo })? {
            Response::Shared(c) => Ok(c),
            other => Err(other.unexpected("Shared")),
        }
    }

    /// Deployment-wide metrics snapshot.
    fn metrics(&mut self) -> Result<Metrics, ApiError> {
        match self.call(Request::Metrics)? {
            Response::Metrics(m) => Ok(m),
            other => Err(other.unexpected("Metrics")),
        }
    }

    /// Describe the model snapshot serving a job's reads.
    fn snapshot_info(&mut self, job: JobKind) -> Result<SnapshotInfo, ApiError> {
        match self.call(Request::SnapshotInfo { job })? {
            Response::SnapshotInfo(info) => Ok(info),
            other => Err(other.unexpected("SnapshotInfo")),
        }
    }

    /// Read a job repository's per-org op-log watermarks.
    fn watermarks(&mut self, job: JobKind) -> Result<WatermarkSet, ApiError> {
        match self.call(Request::Watermarks { job })? {
            Response::Watermarks(set) => Ok(set),
            other => Err(other.unexpected("Watermarks")),
        }
    }

    /// Extract the record-level delta a peer with `watermarks` is
    /// missing.
    fn sync_pull(
        &mut self,
        job: JobKind,
        watermarks: BTreeMap<String, OrgWatermark>,
    ) -> Result<SyncDelta, ApiError> {
        match self.call(Request::SyncPull { job, watermarks })? {
            Response::SyncDelta(delta) => Ok(delta),
            other => Err(other.unexpected("SyncDelta")),
        }
    }

    /// Apply a peer's record-level delta (idempotent merge + canonical
    /// reorder; rejected ops advance the watermark). Op-only form; a
    /// delta that may carry whole-org snapshot fallbacks goes through
    /// [`Client::sync_push_full`].
    fn sync_push(&mut self, job: JobKind, ops: Vec<SyncOp>) -> Result<SyncReport, ApiError> {
        self.sync_push_full(job, ops, Vec::new())
    }

    /// [`Client::sync_push`] with whole-org snapshot fallbacks for orgs
    /// the sender could not serve ops for (truncated below the
    /// receiver's position).
    fn sync_push_full(
        &mut self,
        job: JobKind,
        ops: Vec<SyncOp>,
        snapshots: Vec<OrgSnapshot>,
    ) -> Result<SyncReport, ApiError> {
        match self.call(Request::SyncPush { job, ops, snapshots })? {
            Response::SyncApplied(report) => Ok(report),
            other => Err(other.unexpected("SyncApplied")),
        }
    }

    /// Send one mesh gossip message (liveness + roster + acks) and get
    /// the receiver's updated mesh view back.
    fn mesh_hello(&mut self, hello: MeshHello) -> Result<MeshView, ApiError> {
        match self.call(Request::MeshHello { hello })? {
            Response::MeshView(view) => Ok(view),
            other => Err(other.unexpected("MeshView")),
        }
    }

    /// Read the deployment's current mesh roster.
    fn mesh_roster(&mut self) -> Result<MeshView, ApiError> {
        match self.call(Request::MeshRoster)? {
            Response::MeshView(view) => Ok(view),
            other => Err(other.unexpected("MeshView")),
        }
    }

    /// Read every job repository's watermarks in one round trip.
    fn watermarks_all(&mut self) -> Result<Vec<WatermarkSet>, ApiError> {
        match self.call(Request::WatermarksAll)? {
            Response::WatermarksAll(sets) => Ok(sets),
            other => Err(other.unexpected("WatermarksAll")),
        }
    }

    /// Extract cross-job deltas against a full set of per-job marks in
    /// one round trip.
    fn sync_pull_all(
        &mut self,
        watermarks: Vec<WatermarkSet>,
    ) -> Result<Vec<SyncDelta>, ApiError> {
        match self.call(Request::SyncPullAll { watermarks })? {
            Response::SyncDeltaAll(deltas) => Ok(deltas),
            other => Err(other.unexpected("SyncDeltaAll")),
        }
    }

    /// Apply cross-job deltas in one round trip; the reply carries the
    /// receiver's post-apply watermarks (its acks).
    fn sync_push_all(&mut self, deltas: Vec<SyncDelta>) -> Result<SyncReportAll, ApiError> {
        match self.call(Request::SyncPushAll { deltas })? {
            Response::SyncAppliedAll(report) => Ok(report),
            other => Err(other.unexpected("SyncAppliedAll")),
        }
    }

    /// Read a job repository's legacy (v2) holdings watermarks.
    fn watermarks_v2(&mut self, job: JobKind) -> Result<WatermarkSetV2, ApiError> {
        match self.call(Request::WatermarksV2 { job })? {
            Response::WatermarksV2(set) => Ok(set),
            other => Err(other.unexpected("WatermarksV2")),
        }
    }

    /// Extract the legacy (v2) org-granular delta a peer is missing.
    fn sync_pull_v2(
        &mut self,
        job: JobKind,
        watermarks: BTreeMap<String, OrgWatermarkV2>,
    ) -> Result<SyncDeltaV2, ApiError> {
        match self.call(Request::SyncPullV2 { job, watermarks })? {
            Response::SyncDeltaV2(delta) => Ok(delta),
            other => Err(other.unexpected("SyncDeltaV2")),
        }
    }

    /// Apply a legacy (v2) delta of bare records.
    fn sync_push_v2(
        &mut self,
        job: JobKind,
        records: Vec<RuntimeRecord>,
    ) -> Result<SyncReport, ApiError> {
        match self.call(Request::SyncPushV2 { job, records })? {
            Response::SyncApplied(report) => Ok(report),
            other => Err(other.unexpected("SyncApplied")),
        }
    }
}

// ---------------------------------------------------------------------------
// JSON projections (the CLI's scriptable output)
// ---------------------------------------------------------------------------

impl Recommendation {
    /// JSON projection (stable key order) for `c3o recommend --json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("api_version", Json::Num(API_VERSION as f64)),
            ("job", Json::Str(self.job.name().to_string())),
            ("model", Json::Str(self.model_used.name().to_string())),
            ("generation", Json::Num(self.generation as f64)),
            (
                "trained_at_generation",
                Json::Num(self.trained_at_generation as f64),
            ),
            ("choice", self.choice.to_json()),
        ])
    }
}

impl SnapshotInfo {
    /// JSON projection (stable key order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("api_version", Json::Num(self.api_version as f64)),
            ("job", Json::Str(self.job.name().to_string())),
            ("records", Json::Num(self.records as f64)),
            ("generation", Json::Num(self.generation as f64)),
            (
                "trained_at_generation",
                self.trained_at_generation
                    .map_or(Json::Null, |g| Json::Num(g as f64)),
            ),
            (
                "model",
                self.model
                    .map_or(Json::Null, |k| Json::Str(k.name().to_string())),
            ),
            ("observed_machines", Json::strs(&self.observed_machines)),
        ])
    }
}

impl Contribution {
    /// JSON projection (stable key order) for `c3o contribute --json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("api_version", Json::Num(API_VERSION as f64)),
            ("job", Json::Str(self.job.name().to_string())),
            ("added", Json::Num(self.added as f64)),
            ("generation", Json::Num(self.generation as f64)),
        ])
    }
}

impl SyncReport {
    /// JSON projection (stable key order) for `c3o sync --json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("api_version", Json::Num(API_VERSION as f64)),
            ("job", Json::Str(self.job.name().to_string())),
            ("added", Json::Num(self.added as f64)),
            ("replaced", Json::Num(self.replaced as f64)),
            ("skipped", Json::Num(self.skipped as f64)),
            ("conflicts", Json::Num(self.conflicts.len() as f64)),
            ("generation", Json::Num(self.generation as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_render_their_class() {
        let e = ApiError::InvalidRequest("target must be positive".into());
        assert!(e.to_string().contains("invalid request"));
        let e = ApiError::ColdStart {
            job: JobKind::Sort,
            records: 3,
            min_records: 12,
        };
        let msg = e.to_string();
        assert!(msg.contains("cold start") && msg.contains('3') && msg.contains("12"), "{msg}");
        assert_eq!(ApiError::Stopped.to_string(), "service stopped");
    }

    #[test]
    fn anyhow_folds_into_internal_with_full_chain() {
        use anyhow::Context as _;
        let inner: anyhow::Result<()> = Err(anyhow::anyhow!("root cause"));
        let err = inner.context("outer step").unwrap_err();
        match ApiError::from(err) {
            ApiError::Internal(msg) => {
                assert!(msg.contains("outer step") && msg.contains("root cause"), "{msg}");
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn requests_classify_reads_and_writes() {
        let req = JobRequest::sort(10.0);
        assert!(Request::Submit {
            org: Organization::new("o"),
            request: req.clone()
        }
        .is_write());
        assert!(!Request::Recommend { request: req.clone() }.is_write());
        assert!(!Request::Metrics.is_write());
        assert_eq!(Request::Metrics.job(), None);
        assert_eq!(
            Request::Recommend { request: req }.job(),
            Some(JobKind::Sort)
        );
        assert_eq!(
            Request::SnapshotInfo { job: JobKind::Grep }.job(),
            Some(JobKind::Grep)
        );
        // federation: pulls are reads, pushes are writes — on both the
        // record-level (v3) and compatibility (v2) paths
        let pull = Request::SyncPull {
            job: JobKind::Sort,
            watermarks: BTreeMap::new(),
        };
        assert!(!pull.is_write());
        assert_eq!(pull.job(), Some(JobKind::Sort));
        assert!(!Request::Watermarks { job: JobKind::Sort }.is_write());
        let push = Request::SyncPush {
            job: JobKind::Grep,
            ops: vec![],
            snapshots: vec![],
        };
        assert!(push.is_write());
        assert_eq!(push.job(), Some(JobKind::Grep));
        // v4: mesh gossip mutates membership state; the batched
        // cross-job exchanges route to no single job
        let hello = Request::MeshHello {
            hello: MeshHello {
                from: MeshPeer {
                    name: "a".into(),
                    id: 1,
                },
                known: vec![],
                acked: vec![],
            },
        };
        assert!(hello.is_write());
        assert_eq!(hello.job(), None);
        assert!(!Request::MeshRoster.is_write());
        assert!(!Request::WatermarksAll.is_write());
        assert!(!Request::SyncPullAll { watermarks: vec![] }.is_write());
        assert!(Request::SyncPushAll { deltas: vec![] }.is_write());
        assert_eq!(Request::SyncPushAll { deltas: vec![] }.job(), None);
        assert!(!Request::WatermarksV2 { job: JobKind::Sort }.is_write());
        let pull_v2 = Request::SyncPullV2 {
            job: JobKind::Sort,
            watermarks: BTreeMap::new(),
        };
        assert!(!pull_v2.is_write());
        assert_eq!(pull_v2.job(), Some(JobKind::Sort));
        let push_v2 = Request::SyncPushV2 {
            job: JobKind::Grep,
            records: vec![],
        };
        assert!(push_v2.is_write());
        assert_eq!(push_v2.job(), Some(JobKind::Grep));
    }

    #[test]
    fn store_errors_render_their_class() {
        let e = ApiError::Store("wal-000001.log: checksum mismatch".into());
        assert!(e.to_string().starts_with("store error"));
    }

    #[test]
    fn sync_report_renders_conflict_count() {
        let report = SyncReport {
            job: JobKind::Sort,
            added: 3,
            replaced: 1,
            skipped: 2,
            conflicts: vec![],
            applied_by_org: BTreeMap::new(),
            generation: 9,
        };
        assert_eq!(report.changed(), 4);
        let s = report.to_json().render();
        assert!(s.contains("\"conflicts\":0"), "{s}");
        assert!(s.contains("\"skipped\":2"), "{s}");
        assert!(s.contains("\"generation\":9"), "{s}");
    }

    #[test]
    fn snapshot_info_renders_null_for_untrained() {
        let info = SnapshotInfo {
            api_version: API_VERSION,
            job: JobKind::Sort,
            records: 0,
            generation: 0,
            trained_at_generation: None,
            model: None,
            observed_machines: vec![],
        };
        let s = info.to_json().render();
        assert!(s.contains("\"model\":null"), "{s}");
        assert!(s.contains("\"observed_machines\":[]"), "{s}");
    }
}
