//! The rule engine: walks the module tree, classifies each file into
//! its invariant zone, runs the token-pattern rules, and applies inline
//! `c3o-lint:` suppression directives.
//!
//! Every rule is a *lexical* check (token patterns + brace matching),
//! so each trigger is documented precisely in `README.md` and the
//! corresponding fixture under `tests/fixtures/` proves both that it
//! fires and that a justified suppression silences it.

use crate::config::{is_known_rule, LintConfig, Zone};
use crate::lexer::{lex, Comment, Tok, TokKind};
use std::collections::BTreeSet;
use std::path::Path;

/// One diagnostic: `file:line: rule: message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub message: String,
}

/// Result of scanning a tree: unsuppressed findings (the failures),
/// suppressed findings (for `--list-suppressed`), and a file count.
#[derive(Debug, Default)]
pub struct ScanResult {
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Finding>,
    pub files_scanned: usize,
}

/// Scan every `.rs` file under `cfg.root`.
pub fn scan_tree(cfg: &LintConfig) -> Result<ScanResult, String> {
    let mut files = Vec::new();
    collect_rs_files(&cfg.root, &mut files)
        .map_err(|e| format!("walking {}: {}", cfg.root.display(), e))?;
    files.sort();
    if files.is_empty() {
        return Err(format!("no .rs files under {}", cfg.root.display()));
    }
    let mut out = ScanResult::default();
    for path in files {
        let rel = path
            .strip_prefix(&cfg.root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {}", path.display(), e))?;
        let (mut findings, mut suppressed) = scan_source(cfg, &rel, &src);
        out.findings.append(&mut findings);
        out.suppressed.append(&mut suppressed);
        out.files_scanned += 1;
    }
    sort_findings(&mut out.findings);
    sort_findings(&mut out.suppressed);
    Ok(out)
}

fn sort_findings(v: &mut [Finding]) {
    v.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Top-level module of a root-relative path: `repo/mod.rs` -> `repo`,
/// `lib.rs` -> `lib`.
fn module_of(rel: &str) -> String {
    match rel.split_once('/') {
        Some((first, _)) => first.to_string(),
        None => rel.trim_end_matches(".rs").to_string(),
    }
}

/// Scan one file's source. Returns (unsuppressed, suppressed) findings.
pub fn scan_source(cfg: &LintConfig, rel: &str, src: &str) -> (Vec<Finding>, Vec<Finding>) {
    let module = module_of(rel);
    let zone = cfg.zone_of(&module);
    let (toks, comments) = lex(src);
    let fns = fn_ranges(&toks);
    let (directives, mut bad) = parse_directives(cfg, rel, &comments, &fns);
    let tests = test_regions(&toks);

    let mut raw: Vec<Finding> = Vec::new();
    if zone == Zone::Deterministic {
        rule_hash_iter(rel, &module, &toks, &mut raw);
    }
    if cfg.float_order_modules.contains(&module) {
        rule_float_order(rel, &toks, &mut raw);
    }
    if zone == Zone::Serving {
        rule_no_panic_serving(rel, &toks, &mut raw);
    }
    if !cfg.anyhow_exempt_modules.contains(&module) {
        rule_no_anyhow_public(rel, &module, &toks, &mut raw);
    }
    rule_lock_discipline(cfg, rel, &toks, &directives, &mut raw);

    // Test code is out of scope for every rule (fixtures and asserts
    // unwrap freely; they run under the harness, not on the serving path).
    raw.retain(|f| !tests.iter().any(|r| r.contains(&f.line)));
    dedupe(&mut raw);

    let mut kept = Vec::new();
    let mut suppressed = Vec::new();
    for f in raw {
        if is_suppressed(&f, &directives) {
            suppressed.push(f);
        } else {
            kept.push(f);
        }
    }
    kept.append(&mut bad); // bad-suppression diagnostics are never suppressible
    (kept, suppressed)
}

fn dedupe(v: &mut Vec<Finding>) {
    let mut seen: BTreeSet<(String, u32, String)> = BTreeSet::new();
    v.retain(|f| seen.insert((f.file.clone(), f.line, f.rule.clone())));
}

// ---------------------------------------------------------------------------
// Suppression directives
// ---------------------------------------------------------------------------

/// A parsed, well-formed `c3o-lint:` directive.
#[derive(Debug, Clone)]
pub struct Directive {
    pub line: u32,
    pub kind: DirectiveKind,
}

#[derive(Debug, Clone)]
pub enum DirectiveKind {
    /// `allow(rule, ...)` — suppresses matching findings on the
    /// directive's own line (trailing form) or the line below it.
    Allow { rules: Vec<String> },
    /// `allow-fn(rule, ...)` — suppresses matching findings anywhere in
    /// the next `fn` item (signature + body).
    AllowFn { rules: Vec<String>, range: LineRange },
    /// `holds(class, ...)` — lock-discipline: the named lock classes
    /// are considered held for the whole body of the next `fn` (the
    /// caller's obligation, checked at every call site by review).
    Holds { classes: Vec<String>, range: LineRange },
}

#[derive(Debug, Clone, Copy)]
pub struct LineRange {
    pub start: u32,
    pub end: u32,
}

impl LineRange {
    fn contains(&self, line: u32) -> bool {
        self.start <= line && line <= self.end
    }
}

/// A `fn` item: the line of the `fn` keyword and the last line of its
/// body (or of the signature, for body-less trait methods).
#[derive(Debug, Clone, Copy)]
struct FnSpan {
    start: u32,
    end: u32,
}

/// Max lines between a fn-scoped directive and the `fn` it governs
/// (doc comments and attributes in between are fine; further away is a
/// dangling directive and reported as such).
const FN_ATTACH_WINDOW: u32 = 20;

fn parse_directives(
    cfg: &LintConfig,
    rel: &str,
    comments: &[Comment],
    fns: &[FnSpan],
) -> (Vec<Directive>, Vec<Finding>) {
    let mut dirs = Vec::new();
    let mut bad = Vec::new();
    let mut report = |line: u32, msg: String| {
        bad.push(Finding {
            file: rel.to_string(),
            line,
            rule: "bad-suppression".to_string(),
            message: msg,
        });
    };
    for c in comments {
        let text = c.text.trim();
        let Some(rest) = text.strip_prefix("c3o-lint:") else {
            continue;
        };
        let rest = rest.trim_start();
        let Some((name, args, justification)) = split_directive(rest) else {
            report(
                c.line,
                "malformed c3o-lint directive — expected `c3o-lint: allow(<rule>) — <justification>`"
                    .to_string(),
            );
            continue;
        };
        if justification.len() < 8 {
            report(
                c.line,
                format!(
                    "c3o-lint `{name}` suppression without a justification — write why the \
                     finding is safe (a short sentence after an em dash)"
                ),
            );
            continue;
        }
        match name.as_str() {
            "allow" | "allow-fn" => {
                if let Some(unknown) = args.iter().find(|r| !is_known_rule(r)) {
                    report(c.line, format!("unknown rule `{unknown}` in c3o-lint allow"));
                    continue;
                }
                if name == "allow" {
                    dirs.push(Directive {
                        line: c.line,
                        kind: DirectiveKind::Allow { rules: args },
                    });
                } else {
                    match attach_to_fn(c.line, fns) {
                        Some(range) => dirs.push(Directive {
                            line: c.line,
                            kind: DirectiveKind::AllowFn { rules: args, range },
                        }),
                        None => report(
                            c.line,
                            "allow-fn directive is not followed by a `fn` item".to_string(),
                        ),
                    }
                }
            }
            "holds" => {
                if let Some(unknown) = args.iter().find(|a| !cfg.lock_classes.contains(a)) {
                    report(
                        c.line,
                        format!("unknown lock class `{unknown}` in c3o-lint holds"),
                    );
                    continue;
                }
                match attach_to_fn(c.line, fns) {
                    Some(range) => dirs.push(Directive {
                        line: c.line,
                        kind: DirectiveKind::Holds {
                            classes: args,
                            range,
                        },
                    }),
                    None => report(
                        c.line,
                        "holds directive is not followed by a `fn` item".to_string(),
                    ),
                }
            }
            other => report(c.line, format!("unknown c3o-lint directive `{other}`")),
        }
    }
    (dirs, bad)
}

/// Split `allow(rule-a, rule-b) — justification` into its parts.
fn split_directive(s: &str) -> Option<(String, Vec<String>, String)> {
    let open = s.find('(')?;
    let close = s.find(')')?;
    if close < open {
        return None;
    }
    let name = s[..open].trim().to_string();
    let args: Vec<String> = s[open + 1..close]
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    if name.is_empty() || args.is_empty() {
        return None;
    }
    // Justification: whatever follows the closing paren, with separator
    // punctuation (dashes / em dashes / colons) stripped.
    let just = s[close + 1..]
        .trim_start_matches(|c: char| c == '-' || c == '—' || c == '–' || c == ':' || c.is_whitespace())
        .trim()
        .to_string();
    Some((name, args, just))
}

/// The `fn` a fn-scoped directive at `line` governs: the first fn
/// starting after `line` within the attachment window.
fn attach_to_fn(line: u32, fns: &[FnSpan]) -> Option<LineRange> {
    fns.iter()
        .filter(|f| f.start > line && f.start - line <= FN_ATTACH_WINDOW)
        .min_by_key(|f| f.start)
        .map(|f| LineRange {
            start: line,
            end: f.end,
        })
}

fn is_suppressed(f: &Finding, directives: &[Directive]) -> bool {
    directives.iter().any(|d| match &d.kind {
        DirectiveKind::Allow { rules } => {
            rules.contains(&f.rule) && (d.line == f.line || d.line + 1 == f.line)
        }
        DirectiveKind::AllowFn { rules, range } => rules.contains(&f.rule) && range.contains(f.line),
        DirectiveKind::Holds { .. } => false,
    })
}

// ---------------------------------------------------------------------------
// Structure passes: fn items, #[cfg(test)] regions
// ---------------------------------------------------------------------------

/// Token positions where an *item* `fn` keyword appears (`fn` in type
/// position — `f: fn(u32) -> u32` — is excluded by its preceding token).
fn is_item_fn(toks: &[Tok], i: usize) -> bool {
    if !toks[i].is_ident("fn") {
        return false;
    }
    match i.checked_sub(1).map(|j| &toks[j]) {
        Some(prev) if prev.kind == TokKind::Punct => {
            !matches!(prev.text.as_str(), "(" | "," | ":" | "<" | "=" | "->" | "&" | "|")
        }
        _ => true,
    }
}

fn fn_ranges(toks: &[Tok]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for i in 0..toks.len() {
        if !is_item_fn(toks, i) {
            continue;
        }
        let start = toks[i].line;
        // Scan to the body `{` (or `;` for body-less trait methods).
        let mut j = i + 1;
        while j < toks.len() && !toks[j].is_punct("{") && !toks[j].is_punct(";") {
            j += 1;
        }
        let end = if j < toks.len() && toks[j].is_punct("{") {
            matching_brace(toks, j).map_or(toks[j].line, |k| toks[k].line)
        } else if j < toks.len() {
            toks[j].line
        } else {
            start
        };
        spans.push(FnSpan { start, end });
    }
    spans
}

/// Index of the `}` matching the `{` at `open`.
fn matching_brace(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("{") {
            depth += 1;
        } else if t.is_punct("}") {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Line ranges covered by `#[cfg(test)]` / `#[test]` items.
fn test_regions(toks: &[Tok]) -> Vec<LineRange> {
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if !(toks[i].is_punct("#") && toks[i + 1].is_punct("[")) {
            i += 1;
            continue;
        }
        let Some(close) = matching_bracket(toks, i + 1) else {
            break;
        };
        let idents: Vec<&str> = toks[i + 1..close]
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        let is_test_attr = idents.contains(&"test")
            && !idents.contains(&"not") // #[cfg(not(test))] is NON-test code
            && matches!(idents.first(), Some(&"cfg") | Some(&"test"));
        if !is_test_attr {
            i = close + 1;
            continue;
        }
        let start_line = toks[i].line;
        // Skip any further attributes, then span the item itself.
        let mut j = close + 1;
        while j + 1 < toks.len() && toks[j].is_punct("#") && toks[j + 1].is_punct("[") {
            match matching_bracket(toks, j + 1) {
                Some(c) => j = c + 1,
                None => break,
            }
        }
        while j < toks.len() && !toks[j].is_punct("{") && !toks[j].is_punct(";") {
            j += 1;
        }
        let (end_line, next) = if j < toks.len() && toks[j].is_punct("{") {
            match matching_brace(toks, j) {
                Some(k) => (toks[k].line, k + 1),
                None => (toks[toks.len() - 1].line, toks.len()),
            }
        } else if j < toks.len() {
            (toks[j].line, j + 1)
        } else {
            (toks[toks.len() - 1].line, toks.len())
        };
        regions.push(LineRange {
            start: start_line,
            end: end_line,
        });
        i = next;
    }
    regions
}

/// Index of the `]` matching the `[` at `open`.
fn matching_bracket(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("[") {
            depth += 1;
        } else if t.is_punct("]") {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

// ---------------------------------------------------------------------------
// Rule 1: hash-iter
// ---------------------------------------------------------------------------

fn rule_hash_iter(rel: &str, module: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for t in toks {
        if t.is_ident("HashMap") || t.is_ident("HashSet") {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "hash-iter".to_string(),
                message: format!(
                    "`{}` in deterministic-path module `{module}` — iteration order breaks \
                     bitwise convergence; use `BTreeMap`/`BTreeSet` or a sorted `Vec`",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Rule 2: float-order
// ---------------------------------------------------------------------------

/// Tokens scanned backwards from a `.sum()`/`.product()` call for float
/// evidence when there is no turbofish to decide the element type.
const FLOAT_EVIDENCE_BACK: usize = 60;
const FLOAT_EVIDENCE_FWD: usize = 12;

fn is_float_evidence(t: &Tok) -> bool {
    t.kind == TokKind::Float || t.is_ident("f32") || t.is_ident("f64")
}

fn rule_float_order(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        let t = &toks[i];
        let after_dot = i > 0 && toks[i - 1].is_punct(".");
        if !after_dot || t.kind != TokKind::Ident {
            continue;
        }
        let flagged = match t.text.as_str() {
            "sum" | "product" => reduction_is_float(toks, i),
            "fold" => fold_init_is_float(toks, i),
            _ => false,
        };
        if flagged {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "float-order".to_string(),
                message: format!(
                    "unannotated float reduction `.{}(...)` — summation order changes bits on \
                     the deterministic path; keep a fixed-order loop or suppress with the \
                     ordering argument written out",
                    t.text
                ),
            });
        }
    }
}

/// `.sum::<f64>()` is float; `.sum::<usize>()` is not; `.sum()` falls
/// back to a token window scan for float evidence.
fn reduction_is_float(toks: &[Tok], i: usize) -> bool {
    if i + 2 < toks.len() && toks[i + 1].is_punct("::") && toks[i + 2].is_punct("<") {
        let mut depth = 1i64;
        let mut j = i + 3;
        while j < toks.len() && depth > 0 {
            if toks[j].is_punct("<") {
                depth += 1;
            } else if toks[j].is_punct(">") {
                depth -= 1;
            } else if depth > 0 && (toks[j].is_ident("f32") || toks[j].is_ident("f64")) {
                return true;
            }
            j += 1;
        }
        return false;
    }
    let lo = i.saturating_sub(FLOAT_EVIDENCE_BACK);
    let hi = (i + FLOAT_EVIDENCE_FWD).min(toks.len());
    toks[lo..hi].iter().any(is_float_evidence)
}

/// `.fold(init, f)` — float iff the init expression (first argument)
/// contains a float literal or an `f32`/`f64` token.
fn fold_init_is_float(toks: &[Tok], i: usize) -> bool {
    if i + 1 >= toks.len() || !toks[i + 1].is_punct("(") {
        return false;
    }
    let mut depth = 1i64;
    let mut j = i + 2;
    while j < toks.len() && depth > 0 {
        let t = &toks[j];
        if t.is_punct("(") || t.is_punct("[") || t.is_punct("{") {
            depth += 1;
        } else if t.is_punct(")") || t.is_punct("]") || t.is_punct("}") {
            depth -= 1;
        } else if depth == 1 && t.is_punct(",") {
            return false; // end of the init argument, no float evidence
        } else if is_float_evidence(t) {
            return true;
        }
        j += 1;
    }
    false
}

// ---------------------------------------------------------------------------
// Rule 3: no-panic-serving
// ---------------------------------------------------------------------------

const PANIC_METHODS: &[&str] = &["unwrap", "expect", "unwrap_err", "expect_err"];
const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented", "unreachable"];

fn rule_no_panic_serving(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    let mut push = |line: u32, message: String| {
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: "no-panic-serving".to_string(),
            message,
        });
    };
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && PANIC_METHODS.contains(&t.text.as_str())
            && i > 0
            && toks[i - 1].is_punct(".")
            && i + 1 < toks.len()
            && toks[i + 1].is_punct("(")
        {
            push(
                t.line,
                format!(
                    "`.{}()` in serving-path non-test code — the typed `ApiError` taxonomy is \
                     the only failure channel; return an error instead",
                    t.text
                ),
            );
            continue;
        }
        if t.kind == TokKind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && i + 1 < toks.len()
            && toks[i + 1].is_punct("!")
        {
            push(
                t.line,
                format!(
                    "`{}!` in serving-path non-test code — a panic here is an outage; return a \
                     typed `ApiError` instead",
                    t.text
                ),
            );
            continue;
        }
        // Index expression: `x[i]`, `x()[i]`, `x?[i]` — but not
        // attributes `#[...]`, macro brackets `vec![...]`, or array
        // literals/types (whose `[` follows punctuation).
        if t.is_punct("[") && i > 0 {
            let prev = &toks[i - 1];
            let indexing = prev.kind == TokKind::Ident && !is_keyword_before_bracket(&prev.text)
                || prev.is_punct(")")
                || prev.is_punct("]")
                || prev.is_punct("?");
            if indexing {
                push(
                    t.line,
                    "slice/map index in serving-path non-test code — indexing panics out of \
                     bounds; use `.get()`/`.get_mut()` or document the in-bounds invariant \
                     with a suppression"
                        .to_string(),
                );
            }
        }
    }
}

/// Keywords that can directly precede `[` without forming an index
/// expression (`return [..]`, `in [..]`, `else [..]` etc. are array
/// literals / iterator sources, not indexing).
fn is_keyword_before_bracket(word: &str) -> bool {
    matches!(
        word,
        "return" | "in" | "else" | "match" | "if" | "break" | "mut" | "dyn" | "as" | "impl"
    )
}

// ---------------------------------------------------------------------------
// Rule 4: no-anyhow-public
// ---------------------------------------------------------------------------

fn rule_no_anyhow_public(rel: &str, module: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    let imports_anyhow_result = imports_anyhow_result(toks);
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is_ident("pub") {
            i += 1;
            continue;
        }
        // `pub(crate)` / `pub(super)` surfaces are internal — skip.
        if i + 1 < toks.len() && toks[i + 1].is_punct("(") {
            i += 1;
            continue;
        }
        // Allow qualifiers between `pub` and `fn`.
        let mut j = i + 1;
        while j < toks.len()
            && (toks[j].is_ident("unsafe")
                || toks[j].is_ident("const")
                || toks[j].is_ident("async")
                || toks[j].is_ident("extern")
                || toks[j].kind == TokKind::Str)
        {
            j += 1;
        }
        if j >= toks.len() || !toks[j].is_ident("fn") {
            i += 1;
            continue;
        }
        // Signature: everything to the body `{` or the trailing `;`.
        let mut end = j + 1;
        while end < toks.len() && !toks[end].is_punct("{") && !toks[end].is_punct(";") {
            end += 1;
        }
        let sig = &toks[j..end];
        if let Some(line) = anyhow_in_signature(sig, imports_anyhow_result) {
            out.push(Finding {
                file: rel.to_string(),
                line,
                rule: "no-anyhow-public".to_string(),
                message: format!(
                    "`anyhow` in a `pub fn` signature in module `{module}` — public failures \
                     must speak the typed `ApiError` taxonomy (fold internal errors in via \
                     `ApiError::internal`/`ApiError::store` at the boundary)"
                ),
            });
        }
        i = end;
    }
}

/// Does any `use` statement bring `anyhow`'s `Result` alias into scope?
fn imports_anyhow_result(toks: &[Tok]) -> bool {
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is_ident("use") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        let mut saw_anyhow = false;
        let mut saw_result = false;
        while j < toks.len() && !toks[j].is_punct(";") {
            saw_anyhow |= toks[j].is_ident("anyhow");
            saw_result |= toks[j].is_ident("Result");
            j += 1;
        }
        if saw_anyhow && saw_result {
            return true;
        }
        i = j + 1;
    }
    false
}

/// Line of the first anyhow occurrence in a `pub fn` signature:
/// an explicit `anyhow` path segment, or — when the file imports
/// `anyhow::Result` — an unqualified single-generic `Result<T>`
/// (the alias form; `Result<T, E>` with an explicit error is fine).
fn anyhow_in_signature(sig: &[Tok], imports_anyhow_result: bool) -> Option<u32> {
    for (k, t) in sig.iter().enumerate() {
        if t.is_ident("anyhow") {
            return Some(t.line);
        }
        if imports_anyhow_result
            && t.is_ident("Result")
            && !(k > 0 && sig[k - 1].is_punct("::"))
            && k + 1 < sig.len()
            && sig[k + 1].is_punct("<")
            && generic_arg_count(&sig[k + 1..]) == 1
        {
            return Some(t.line);
        }
    }
    None
}

/// Number of top-level generic arguments in `<...>` starting at the `<`.
fn generic_arg_count(toks: &[Tok]) -> usize {
    let mut angle = 0i64;
    let mut group = 0i64; // (), [] nesting
    let mut args = 0usize;
    let mut saw_any = false;
    for t in toks {
        if t.is_punct("<") {
            angle += 1;
            continue;
        }
        if t.is_punct(">") {
            angle -= 1;
            if angle == 0 {
                return if saw_any { args + 1 } else { 0 };
            }
            continue;
        }
        if t.is_punct("(") || t.is_punct("[") {
            group += 1;
        } else if t.is_punct(")") || t.is_punct("]") {
            group -= 1;
        } else if t.is_punct(",") && angle == 1 && group == 0 {
            args += 1;
            continue;
        }
        if angle >= 1 {
            saw_any = true;
        }
    }
    if saw_any {
        args + 1
    } else {
        0
    }
}

// ---------------------------------------------------------------------------
// Rule 5: lock-discipline
// ---------------------------------------------------------------------------

/// One lexically-held guard.
#[derive(Debug)]
struct HeldGuard {
    class: String,
    /// `let`-binding name, when bound (released by `drop(name)` too).
    name: Option<String>,
    /// Brace depth at acquisition; released when that block closes.
    depth: i64,
    /// Paren/bracket nesting at acquisition — a temporary dies at the
    /// next `,` no deeper than this (match arms end in `,`, not `;`).
    group: i64,
    /// Temporary guard (no `let`): released at the next `;` or
    /// arm-terminating `,`.
    stmt: bool,
}

fn rule_lock_discipline(
    cfg: &LintConfig,
    rel: &str,
    toks: &[Tok],
    directives: &[Directive],
    out: &mut Vec<Finding>,
) {
    if cfg.lock_classes.is_empty() {
        return;
    }
    let holds: Vec<(&Vec<String>, LineRange)> = directives
        .iter()
        .filter_map(|d| match &d.kind {
            DirectiveKind::Holds { classes, range } => Some((classes, *range)),
            _ => None,
        })
        .collect();
    let mut held: Vec<HeldGuard> = Vec::new();
    let mut depth = 0i64;
    let mut group = 0i64;
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if t.is_punct("{") {
            depth += 1;
        } else if t.is_punct("}") {
            held.retain(|g| g.depth < depth);
            depth -= 1;
        } else if t.is_punct("(") || t.is_punct("[") {
            group += 1;
        } else if t.is_punct(")") || t.is_punct("]") {
            group -= 1;
        } else if t.is_punct(";") {
            held.retain(|g| !(g.stmt && g.depth >= depth));
        } else if t.is_punct(",") {
            // End of a match arm (or of the expression holding the
            // temporary): a `,` at or above the guard's nesting level
            // ends its statement even without a `;`.
            held.retain(|g| !(g.stmt && g.depth >= depth && g.group >= group));
        } else if t.is_ident("drop") && i + 2 < toks.len() && toks[i + 1].is_punct("(") {
            let name = toks[i + 2].text.clone();
            held.retain(|g| g.name.as_deref() != Some(name.as_str()));
        } else if is_lock_acquisition(toks, i) {
            if let Some(class) = classify_receiver(cfg, toks, i) {
                let line = t.line;
                // Classes asserted held for this whole fn by `holds()`.
                let annotated: Vec<&String> = holds
                    .iter()
                    .filter(|(_, r)| r.contains(line))
                    .flat_map(|(cs, _)| cs.iter())
                    .collect();
                let outer = held
                    .iter()
                    .map(|g| g.class.as_str())
                    .chain(annotated.iter().map(|c| c.as_str()));
                let mut violation = None;
                for h in outer {
                    if h == class {
                        violation = Some(format!(
                            "lock class `{class}` acquired while a `{class}` guard is already \
                             held — self-deadlock"
                        ));
                        break;
                    }
                    let allowed = cfg
                        .lock_order
                        .iter()
                        .any(|(o, inn)| o == h && *inn == class);
                    if !allowed {
                        violation = Some(format!(
                            "lock class `{class}` acquired while holding `{h}` — the pair is \
                             not in the declared lock order (lint.toml \
                             [rules.lock-discipline] order)"
                        ));
                        break;
                    }
                }
                if let Some(message) = violation {
                    out.push(Finding {
                        file: rel.to_string(),
                        line,
                        rule: "lock-discipline".to_string(),
                        message,
                    });
                }
                let (stmt, name) = binding_of(toks, i);
                held.push(HeldGuard {
                    class: class.to_string(),
                    name,
                    depth,
                    group,
                    stmt,
                });
            }
        }
        i += 1;
    }
}

/// `.lock()`, `.read()`, `.write()` and the poison-recovering
/// `*_unpoisoned()` extension methods from `util::sync` —
/// zero-argument calls only, so `file.write(buf)` (io) never matches.
fn is_lock_acquisition(toks: &[Tok], i: usize) -> bool {
    let t = &toks[i];
    t.kind == TokKind::Ident
        && matches!(
            t.text.as_str(),
            "lock" | "read" | "write" | "lock_unpoisoned" | "read_unpoisoned" | "write_unpoisoned"
        )
        && i > 0
        && toks[i - 1].is_punct(".")
        && i + 2 < toks.len()
        && toks[i + 1].is_punct("(")
        && toks[i + 2].is_punct(")")
}

/// Classify the receiver chain of an acquisition at token `i` (the
/// `lock`/`read`/`write` ident). Walks backwards, skipping one
/// `[...]`/`(...)` group at a time, and classifies by the *nearest*
/// chain identifier matching a configured class substring — so
/// `self.snapshots[&shard.job()].write()` classifies as `snapshot`
/// (the `shard` inside the index key is not the receiver).
fn classify_receiver<'a>(cfg: &'a LintConfig, toks: &[Tok], i: usize) -> Option<&'a str> {
    let mut j = i.checked_sub(2)?; // skip the `.` before lock/read/write
    loop {
        // Skip a trailing index/call group: `...[k]` or `...(x)`.
        while toks[j].is_punct("]") || toks[j].is_punct(")") {
            let open = if toks[j].is_punct("]") { "[" } else { "(" };
            let close = &toks[j].text;
            let mut d = 1i64;
            loop {
                j = j.checked_sub(1)?;
                if toks[j].kind == TokKind::Punct && toks[j].text == *close {
                    d += 1;
                } else if toks[j].is_punct(open) {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
            }
            j = j.checked_sub(1)?;
        }
        if toks[j].kind != TokKind::Ident {
            return None;
        }
        let ident = toks[j].text.to_lowercase();
        if let Some(class) = cfg
            .lock_classes
            .iter()
            .find(|c| ident.contains(c.as_str()))
        {
            return Some(class);
        }
        // Continue down the chain (`self.shared.metrics` — keep walking
        // past `shared`/`self` until something classifies).
        let prev = j.checked_sub(1)?;
        if toks[prev].is_punct(".") || toks[prev].is_punct("::") {
            j = prev.checked_sub(1)?;
        } else {
            return None;
        }
    }
}

/// Is the acquisition at token `i` part of a `let` statement (a
/// block-held guard), and if so what is the binding name?
fn binding_of(toks: &[Tok], i: usize) -> (bool, Option<String>) {
    let mut s = i;
    while s > 0 {
        let t = &toks[s - 1];
        if t.is_punct(";") || t.is_punct("{") || t.is_punct("}") {
            break;
        }
        s -= 1;
    }
    if s < toks.len() && toks[s].is_ident("let") {
        let mut k = s + 1;
        if k < toks.len() && toks[k].is_ident("mut") {
            k += 1;
        }
        let name = toks.get(k).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.clone());
        (false, name)
    } else {
        (true, None)
    }
}
