//! # C3O — Collaborative Cluster Configuration Optimization
//!
//! A reproduction of *"Towards Collaborative Optimization of Cluster
//! Configurations for Distributed Dataflow Jobs"* (Will, Bader, Thamsen —
//! IEEE BigData 2020) as a three-layer Rust + JAX + Pallas system.
//!
//! The library lets many *organizations* share historical runtime data of
//! distributed dataflow jobs (Sort, Grep, SGD, K-Means, PageRank on a
//! simulated Spark/EMR substrate), trains black-box runtime prediction
//! models on the shared corpus (a similarity-weighted kNN "pessimistic"
//! model and a factorized "optimistic" model, both executed as AOT-compiled
//! XLA artifacts via PJRT), and uses them to pick the cheapest cluster
//! configuration (machine type × scale-out) that meets a runtime target —
//! without any profiling runs.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the coordination system: simulated cloud
//!   ([`cloud`]), dataflow simulator ([`sim`]), workloads ([`workloads`]),
//!   runtime-data repository ([`repo`]), prediction models ([`models`]),
//!   cluster configurator ([`configurator`]), search/model baselines
//!   ([`baselines`]), and the multi-org collaboration runtime
//!   ([`coordinator`]).
//! * **L2 (python/compile/model.py)** — JAX graphs for the prediction
//!   models, AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/knn.py)** — Pallas kernel for the
//!   weighted distance matrix at the core of the pessimistic model.
//!
//! The [`runtime`] module loads the HLO artifacts via the PJRT C API and is
//! the only bridge between L3 and L2/L1; Python never runs on the request
//! path.

pub mod baselines;
pub mod cloud;
pub mod configurator;
pub mod coordinator;
pub mod figures;
pub mod models;
pub mod repo;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workloads;

/// Convenience re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::cloud::{Cloud, MachineType};
    pub use crate::configurator::{ClusterChoice, Configurator, JobRequest};
    pub use crate::coordinator::{Coordinator, JobOutcome, Organization};
    pub use crate::models::{ConfigQuery, ModelKind, Predictor, RuntimeModel, TrainedModel};
    pub use crate::repo::{RuntimeDataRepo, RuntimeRecord};
    pub use crate::sim::SimulationResult;
    pub use crate::util::rng::Pcg32;
    pub use crate::workloads::{ExperimentGrid, JobKind, JobSpec};
}
