//! Per-[`JobKind`] shard: the unit of state ownership in the
//! coordination stack.
//!
//! A shard owns everything one job kind needs — its shared runtime-data
//! repository, its generation-cached trained model, and its RNG stream —
//! and nothing else, so distinct kinds never contend. Every deployment
//! shape drives the same shard code: the sequential [`super::Coordinator`]
//! holds plain shards, the multi-worker [`super::service`] wraps each in
//! a mutex and lets any worker thread serve any shard with its own model
//! engine.
//!
//! **Write-maintained models, read-only serving.** The protocol's
//! read/write split ([`crate::api`]) is realized here:
//!
//! * **Writes** ([`JobShard::submit`], [`JobShard::share`],
//!   [`JobShard::contribute_record`]) mutate the repository and then
//!   [`JobShard::refresh_model`] — retraining via dynamic selection
//!   (§V-C) only when the repo
//!   [`generation`](crate::repo::RuntimeDataRepo::generation) advanced
//!   past the retrain threshold since the cached model was trained.
//!   Merging already-known data does not move the generation, so
//!   redundant sharing can never trigger redundant training (observable
//!   through [`Metrics::retrains`]).
//! * **Reads** ([`JobShard::recommend`], [`JobShard::snapshot`]) never
//!   train and never mutate: they serve the model the last write left
//!   behind. `Submit` decides through the *same* cached model (counted
//!   in [`Metrics::cache_hits`]), which is what makes a read-only
//!   `Recommend` decision-bitwise-equal to the decision inside `Submit`.
//!
//! [`ModelSnapshot`] is the immutable export of a shard's read state:
//! the concurrent service publishes one `Arc<ModelSnapshot>` per shard
//! after every write and serves `Recommend`/`SnapshotInfo`/`Watermarks`
//! from it without touching the shard mutex.
//!
//! **Durability.** A shard built with [`JobShard::recover`] owns a
//! [`JobStore`](crate::store::JobStore): every write logs exactly the
//! records it applied (contribute ops, merge ops, canonical reorders)
//! through the store's WAL, and the store folds the log into an atomic
//! snapshot when it grows. Reads never touch the store.

// Serving zone: unwraps are outages. The module-scoped clippy
// promotion mirrors the repo lint's `no-panic-serving` rule
// (see rust/lint).
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use crate::api::{ApiError, Contribution, Recommendation, SnapshotInfo, API_VERSION};
use crate::baselines::{ConfigSearch, NaiveMax};
use crate::cloud::Cloud;
use crate::compute::ComputePool;
use crate::configurator::{ClusterChoice, Configurator, JobRequest};
use crate::coordinator::{JobOutcome, Metrics, Organization};
use crate::models::oracle::SimOracle;
use crate::models::selection::{select_and_train_pooled, SelectionReport};
use crate::models::{EngineBound, ModelKind, ModelTrainer, QueryBatch, TrainedModel};
use crate::obs::{Stage, StageScratch};
use crate::repo::sampling::coverage_sample;
use crate::repo::{
    FeatureMatrixCache, Featurizer, LoggedOp, MergeOutcome, OrgSnapshot, OrgWatermark,
    RuntimeDataRepo, RuntimeRecord, SyncOp, SyncOutcome,
};
use crate::store::{JobStore, StoreOp};
use crate::util::rng::Pcg32;
use crate::workloads::JobKind;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Retrain/cold-start policy knobs shared by every shard of a deployment.
#[derive(Debug, Clone)]
pub struct ShardPolicy {
    /// Retrain when the repo generation advanced this far since the last
    /// training.
    pub retrain_every: u64,
    /// Minimum records before the model path activates (cold-start
    /// threshold).
    pub min_records: usize,
    /// CV folds for dynamic selection.
    pub cv_folds: usize,
}

impl Default for ShardPolicy {
    fn default() -> Self {
        ShardPolicy {
            retrain_every: 12,
            min_records: 12,
            cv_folds: 4,
        }
    }
}

/// A trained model tagged with the repo generation it was trained at.
/// Shards hold it behind an `Arc` so publishing a snapshot is a
/// reference-count bump, not a copy of the padded training matrices.
#[derive(Debug, Clone)]
pub struct CachedModel {
    pub trained_at_gen: u64,
    pub model: TrainedModel,
    pub report: SelectionReport,
}

/// Immutable export of a shard's read state: everything `Recommend` and
/// `SnapshotInfo` need, detached from the shard itself. The concurrent
/// service publishes one `Arc<ModelSnapshot>` per shard after every
/// write; reads clone the `Arc` and never take the shard mutex.
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    pub job: JobKind,
    /// Records in the shared repository at publish time.
    pub records: usize,
    /// Repository generation at publish time (the snapshot's "stamp").
    pub generation: u64,
    /// The cached model, if the write path has trained one (shared
    /// with the owning shard — never copied on publish).
    pub model: Option<Arc<CachedModel>>,
    /// Machine types observed in the shared data, sorted — the candidate
    /// axis recommendations are restricted to (black-box models
    /// interpolate; they don't leap across unmeasured memory
    /// configurations).
    pub observed_machines: Vec<String>,
    /// Per-org high-water marks at publish time, so the `Watermarks`
    /// federation read is served lock-free like every other read.
    pub watermarks: BTreeMap<String, OrgWatermark>,
}

impl ModelSnapshot {
    /// An empty snapshot for a cold shard.
    pub fn empty(job: JobKind) -> ModelSnapshot {
        ModelSnapshot {
            job,
            records: 0,
            generation: 0,
            model: None,
            observed_machines: Vec::new(),
            watermarks: BTreeMap::new(),
        }
    }

    /// Protocol description of this snapshot.
    pub fn info(&self) -> SnapshotInfo {
        SnapshotInfo {
            api_version: API_VERSION,
            job: self.job,
            records: self.records,
            generation: self.generation,
            trained_at_generation: self.model.as_ref().map(|m| m.trained_at_gen),
            model: self.model.as_ref().map(|m| m.model.kind),
            observed_machines: self.observed_machines.clone(),
        }
    }

    /// Serve one read-only recommendation from this snapshot.
    pub fn recommend(
        &self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        request: &JobRequest,
    ) -> Result<Recommendation, ApiError> {
        let mut out = self.recommend_batch(engine, cloud, policy, std::slice::from_ref(request));
        out.pop().unwrap_or_else(|| {
            Err(ApiError::Internal(
                "recommend_batch returned no result for a one-request batch".to_string(),
            ))
        })
    }

    /// Serve several same-kind read-only recommendations from this
    /// snapshot, scoring **all candidates of all requests as one
    /// coalesced predict batch**. Each request's decision goes through
    /// [`Configurator::choose`], so results are bitwise-identical to
    /// serving the requests one by one (both production engines score
    /// candidate rows independently).
    pub fn recommend_batch(
        &self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        requests: &[JobRequest],
    ) -> Vec<Result<Recommendation, ApiError>> {
        let Some(cached) = &self.model else {
            return requests
                .iter()
                .map(|_| {
                    Err(ApiError::ColdStart {
                        job: self.job,
                        records: self.records,
                        min_records: policy.min_records,
                    })
                })
                .collect();
        };
        let configurator =
            Configurator::new(cloud).with_machines(self.observed_machines.clone());
        let pairs = configurator.enumerate();
        if pairs.is_empty() {
            let err = ApiError::Internal("empty candidate catalog".to_string());
            return requests.iter().map(|_| Err(err.clone())).collect();
        }
        let batches: Vec<QueryBatch> = requests
            .iter()
            .map(|r| QueryBatch::from_candidates(cloud, &pairs, &r.spec.job_features()))
            .collect();
        let combined = QueryBatch::concat(&batches);
        let runtimes = match engine.predict_batch(&cached.model, cloud, &combined) {
            Ok(r) => r,
            Err(e) => {
                let err = ApiError::internal(e);
                return requests.iter().map(|_| Err(err.clone())).collect();
            }
        };
        requests
            .iter()
            .enumerate()
            .map(|(i, request)| {
                // c3o-lint: allow(no-panic-serving) — `predict_batch` returns one runtime per concatenated candidate row, so chunk bounds hold by construction
                let chunk = &runtimes[i * pairs.len()..(i + 1) * pairs.len()];
                let choice = configurator
                    .choose(request, &pairs, chunk)
                    .ok_or_else(|| ApiError::Internal("empty candidate catalog".to_string()))?;
                Ok(Recommendation {
                    job: self.job,
                    choice,
                    model_used: cached.model.kind,
                    generation: self.generation,
                    trained_at_generation: cached.trained_at_gen,
                })
            })
            .collect()
    }
}

/// Score every candidate with a trained model and decide — the one
/// decision path shared by `Submit` (inside the shard lock) and
/// `Recommend` (from an immutable snapshot), so the two are
/// decision-bitwise-equal by construction.
pub(crate) fn decide_with_model(
    engine: &mut dyn ModelTrainer,
    cloud: &Cloud,
    model: &TrainedModel,
    observed_machines: &[String],
    request: &JobRequest,
) -> Result<ClusterChoice> {
    let mut bound = EngineBound {
        engine,
        model: model.clone(),
    };
    let configurator = Configurator::new(cloud).with_machines(observed_machines.to_vec());
    configurator
        .configure(&mut bound, request)?
        .context("empty catalog")
}

/// Persistent mirror of the latest coverage sample for over-capacity
/// retrains (§III-C). When consecutive samples have the same size, the
/// mirror is *rebased* record-by-record ([`RuntimeDataRepo`]'s
/// `rebase_records`), so its delta journal carries only the churn and
/// the feature cache re-featurizes only the slots that actually moved.
struct SampledCache {
    repo: RuntimeDataRepo,
    feat: FeatureMatrixCache,
}

/// Per-job-kind state: repository + generation-cached model + RNG
/// stream, plus (when the deployment is durable) the segment store the
/// shard's writes persist through.
pub struct JobShard {
    job: JobKind,
    repo: RuntimeDataRepo,
    model: Option<Arc<CachedModel>>,
    rng: Pcg32,
    /// Durable write-through log; `None` for in-memory deployments.
    store: Option<JobStore>,
    /// Incremental feature-matrix mirror of `repo`: retrains replay the
    /// repo's delta journal instead of refeaturizing the corpus.
    feat_cache: FeatureMatrixCache,
    /// Coverage-sample mirror + feature cache for retrains where the
    /// corpus exceeds the engine's kNN capacity; built lazily on the
    /// first over-capacity retrain.
    sampled_cache: Option<SampledCache>,
    /// Shared compute pool: retrains fan their CV folds across it and
    /// stay bitwise-identical to serial training (see [`crate::compute`]).
    /// `None` trains serially.
    pool: Option<Arc<ComputePool>>,
    /// Per-stage wall-time the shard's internals accumulated (retrain
    /// split, WAL I/O). Observability only — never read by decisions.
    scratch: StageScratch,
}

impl JobShard {
    /// Fresh shard for one job kind (in-memory; no persistence).
    pub fn new(job: JobKind, seed: u64) -> JobShard {
        JobShard {
            job,
            repo: RuntimeDataRepo::new(job),
            model: None,
            rng: Pcg32::new(seed),
            store: None,
            feat_cache: FeatureMatrixCache::new(),
            sampled_cache: None,
            pool: None,
            scratch: StageScratch::default(),
        }
    }

    /// Shard recovered from a segment store: adopts the replayed
    /// repository and keeps persisting writes through `store`. The
    /// caller follows up with [`JobShard::refresh_model`] to warm the
    /// model cache from the recovered corpus.
    pub fn recover(job: JobKind, seed: u64, store: JobStore, repo: RuntimeDataRepo) -> JobShard {
        debug_assert_eq!(repo.job(), job, "store recovered a foreign repo");
        debug_assert_eq!(store.generation(), repo.generation(), "store/repo desync");
        JobShard {
            job,
            repo,
            model: None,
            rng: Pcg32::new(seed),
            store: Some(store),
            feat_cache: FeatureMatrixCache::new(),
            sampled_cache: None,
            pool: None,
            scratch: StageScratch::default(),
        }
    }

    /// Install a shared compute pool: retrains fan their CV folds
    /// across it when the engine can fork a `Send`-able native clone.
    /// Decisions are unaffected — pooled training is bitwise-identical
    /// to serial (see [`crate::compute`]).
    pub fn set_compute_pool(&mut self, pool: Arc<ComputePool>) {
        self.pool = Some(pool);
    }

    /// Whether writes are durably persisted.
    pub fn is_durable(&self) -> bool {
        self.store.is_some()
    }

    /// Durably log `ops` (no-op for in-memory shards), then fold the
    /// WAL into a snapshot if it crossed the compaction threshold.
    /// Persistence failures are [`ApiError::Store`] on every write path
    /// — submit included — so callers can match on the failure class.
    fn persist(&mut self, ops: &[StoreOp]) -> Result<(), ApiError> {
        if let Some(store) = &mut self.store {
            store.append(ops, self.repo.generation())?;
            store.maybe_compact(&self.repo)?;
            let (append_ns, fsync_ns) = store.take_io_nanos();
            self.scratch.add(Stage::WalAppend, append_ns);
            self.scratch.add(Stage::Fsync, fsync_ns);
        }
        Ok(())
    }

    /// WAL frames for the ops a merge applied (always holdings
    /// mutations).
    fn merge_store_ops(applied: &[LoggedOp]) -> Vec<StoreOp> {
        applied
            .iter()
            .map(|op| StoreOp::Merge {
                seqno: op.seqno,
                record: op.record.clone(),
            })
            .collect()
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    /// The shard's shared repository.
    pub fn repo(&self) -> &RuntimeDataRepo {
        &self.repo
    }

    /// Current repo generation (the model-cache key).
    pub fn generation(&self) -> u64 {
        self.repo.generation()
    }

    /// The generation the cached model was trained at, if any.
    pub fn trained_at_generation(&self) -> Option<u64> {
        self.model.as_ref().map(|m| m.trained_at_gen)
    }

    /// Latest selection report, if a model is cached.
    pub fn selection_report(&self) -> Option<&SelectionReport> {
        self.model.as_ref().map(|m| &m.report)
    }

    /// The cached model (shared `Arc`), if the write path has trained
    /// one. Write-side coalescing captures it to pre-score a submit
    /// group and re-checks pointer identity before honouring a
    /// pre-decided choice.
    pub(crate) fn cached_model(&self) -> Option<&Arc<CachedModel>> {
        self.model.as_ref()
    }

    /// Machine types observed in the shared data, sorted — served from
    /// the repository's incremental refcount cache (O(machines), not
    /// O(records), so frequent snapshot publishes stay cheap).
    pub fn observed_machines(&self) -> Vec<String> {
        self.repo.observed_machines()
    }

    /// Protocol description of the shard's read state (metadata only).
    pub fn snapshot_info(&self) -> SnapshotInfo {
        SnapshotInfo {
            api_version: API_VERSION,
            job: self.job,
            records: self.repo.len(),
            generation: self.repo.generation(),
            trained_at_generation: self.trained_at_generation(),
            model: self.model.as_ref().map(|m| m.model.kind),
            observed_machines: self.observed_machines(),
        }
    }

    /// Immutable export of the read state (see [`ModelSnapshot`]).
    pub fn snapshot(&self) -> ModelSnapshot {
        ModelSnapshot {
            job: self.job,
            records: self.repo.len(),
            generation: self.repo.generation(),
            model: self.model.clone(),
            observed_machines: self.observed_machines(),
            watermarks: self.repo.watermarks(),
        }
    }

    /// Merge shared runtime data into the shard's repository,
    /// persisting the applied records. Merge rejections (foreign-job or
    /// invalid records) are [`ApiError::InvalidRequest`]; persistence
    /// failures are [`ApiError::Store`], the same classification the
    /// contribute and sync paths use. Write path: the caller follows
    /// up with [`JobShard::refresh_model`].
    pub fn share(&mut self, other: &RuntimeDataRepo) -> Result<MergeOutcome, ApiError> {
        let outcome = self.repo.merge(other).map_err(ApiError::InvalidRequest)?;
        if !outcome.applied.is_empty() {
            self.persist(&Self::merge_store_ops(&outcome.applied))?;
        }
        Ok(outcome)
    }

    /// Apply a peer's record-level sync delta: merge with deterministic
    /// conflict resolution, advance the org logs (seen ops included),
    /// then canonicalize the record order so converged peers hold
    /// bitwise-identical repositories (and train bitwise-identical
    /// models). Every log append — applied *or* seen — is WAL-framed,
    /// so a restarted shard never re-pulls ops it already saw. Write
    /// path: the caller follows up with [`JobShard::refresh_model`].
    pub fn apply_sync_ops(&mut self, ops: &[SyncOp]) -> Result<SyncOutcome, ApiError> {
        let outcome = self
            .repo
            .apply_sync_ops(ops)
            .map_err(ApiError::InvalidRequest)?;
        if !outcome.logged.is_empty() {
            if outcome.changed() > 0 {
                self.repo.canonicalize();
            }
            let mut store_ops: Vec<StoreOp> = outcome
                .logged
                .iter()
                .map(|op| {
                    if op.applied {
                        StoreOp::Merge {
                            seqno: op.seqno,
                            record: op.record.clone(),
                        }
                    } else {
                        StoreOp::Seen {
                            seqno: op.seqno,
                            record: op.record.clone(),
                        }
                    }
                })
                .collect();
            if outcome.changed() > 0 {
                store_ops.push(StoreOp::Canonicalize);
            }
            self.persist(&store_ops)?;
        }
        Ok(outcome)
    }

    /// Apply a legacy (v2) delta of bare records — the `SyncPushV2`
    /// compatibility translation: merge, then append the applied
    /// records to their org logs with fresh local seqnos. Write path:
    /// the caller follows up with [`JobShard::refresh_model`].
    pub fn apply_sync_records(
        &mut self,
        records: &[RuntimeRecord],
    ) -> Result<MergeOutcome, ApiError> {
        let outcome = self
            .repo
            .merge_records(records)
            .map_err(ApiError::InvalidRequest)?;
        if outcome.changed() > 0 {
            self.repo.canonicalize();
            let mut ops = Self::merge_store_ops(&outcome.applied);
            ops.push(StoreOp::Canonicalize);
            self.persist(&ops)?;
        }
        Ok(outcome)
    }

    /// Apply whole-org snapshot fallbacks from a v4 delta (orgs where
    /// this repo sat below the sender's truncation floor). Position
    /// adoptions and truncation floors mutate log state outside the
    /// WAL's op vocabulary, so durability is a **rebased compaction**:
    /// the store rewrites its base snapshot (plus floor sidecar) from
    /// the adopted repo. Returns the folded merge outcome plus each
    /// snapshot org's applied-record count (adopted records are covered
    /// by the folded prefix and appear in no [`LoggedOp`], so per-org
    /// accounting cannot come from `logged`). Write path: the caller
    /// follows up with [`JobShard::refresh_model`].
    pub fn apply_org_snapshots(
        &mut self,
        snapshots: &[OrgSnapshot],
    ) -> Result<(SyncOutcome, BTreeMap<String, u64>), ApiError> {
        let mut total = SyncOutcome::default();
        let mut applied_by_org: BTreeMap<String, u64> = BTreeMap::new();
        let mut mutated = false;
        for snap in snapshots {
            let (outcome, adopted) = self
                .repo
                .adopt_org_snapshot(snap)
                .map_err(ApiError::InvalidRequest)?;
            mutated = mutated || adopted || !outcome.logged.is_empty();
            if outcome.changed() > 0 {
                *applied_by_org.entry(snap.org.clone()).or_default() += outcome.changed() as u64;
            }
            total.added += outcome.added;
            total.replaced += outcome.replaced;
            total.skipped += outcome.skipped;
            total.conflicts.extend(outcome.conflicts);
            total.logged.extend(outcome.logged);
        }
        if mutated {
            if total.changed() > 0 {
                self.repo.canonicalize();
            }
            self.compact_rebased()?;
        }
        Ok((total, applied_by_org))
    }

    /// Fold the fully-acked history below `floors` into each org's base
    /// state (acked-floor truncation,
    /// [`RuntimeDataRepo::truncate_org_log`]) and durably rewrite the
    /// store snapshot. Returns how many op-log entries were dropped.
    pub fn truncate_to_floors(
        &mut self,
        floors: &BTreeMap<String, u64>,
    ) -> Result<u64, ApiError> {
        let mut truncated = 0;
        for (org, floor) in floors {
            truncated += self.repo.truncate_org_log(org, *floor);
        }
        if truncated > 0 {
            self.compact_rebased()?;
        }
        Ok(truncated)
    }

    /// Rewrite the store's base snapshot from the current repo state —
    /// the durability step for mutations the WAL cannot frame (snapshot
    /// adoption, floor truncation). No-op for in-memory shards.
    fn compact_rebased(&mut self) -> Result<(), ApiError> {
        if let Some(store) = &mut self.store {
            store.compact_rebased(&self.repo)?;
            let (append_ns, fsync_ns) = store.take_io_nanos();
            self.scratch.add(Stage::WalAppend, append_ns);
            self.scratch.add(Stage::Fsync, fsync_ns);
        }
        Ok(())
    }

    /// Record one externally-observed run. Write path: the caller
    /// follows up with [`JobShard::refresh_model`].
    pub fn contribute_record(&mut self, record: RuntimeRecord) -> Result<Contribution, ApiError> {
        if record.job != self.job {
            return Err(ApiError::InvalidRequest(format!(
                "{} record routed to {} shard",
                record.job.name(),
                self.job.name()
            )));
        }
        let op = self.store.is_some().then(|| record.clone());
        let seqno = self
            .repo
            .contribute(record)
            .map_err(ApiError::InvalidRequest)?;
        if let Some(rec) = op {
            self.persist(&[StoreOp::Contribute { seqno, record: rec }])?;
        }
        Ok(Contribution {
            job: self.job,
            added: 1,
            generation: self.repo.generation(),
        })
    }

    /// Write-path model maintenance: retrain via dynamic selection when
    /// the repo generation advanced by `retrain_every` since the cached
    /// model was trained (or no model exists yet and the cold-start
    /// threshold is met). Returns the active model kind, or `None` below
    /// the threshold. Reads never call this — they serve whatever model
    /// the last write left behind.
    pub fn refresh_model(
        &mut self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        metrics: &mut Metrics,
    ) -> Result<Option<ModelKind>, ApiError> {
        if self.repo.len() < policy.min_records {
            return Ok(None);
        }
        let gen = self.repo.generation();
        let stale = match &self.model {
            None => true,
            Some(m) => gen.saturating_sub(m.trained_at_gen) >= policy.retrain_every,
        };
        if stale {
            let started = std::time::Instant::now();
            // cap training set at the backend's kNN capacity via
            // coverage sampling (§III-C)
            let cap = engine.knn_capacity();
            let (model, report) = if self.repo.len() > cap {
                // sampled retrain: mirror the coverage sample into a
                // persistent sub-repo so a stable sample re-featurizes
                // only the slots that churned between retrains
                let job = self.job;
                let idx = coverage_sample(&self.repo, cloud, cap);
                let sample: Vec<RuntimeRecord> = idx
                    .iter()
                    // c3o-lint: allow(no-panic-serving) — `coverage_sample` returns indices into `repo.records()` by contract
                    .map(|&i| self.repo.records()[i].clone())
                    .collect();
                let feat_started = std::time::Instant::now();
                let sc = self.sampled_cache.get_or_insert_with(|| SampledCache {
                    repo: RuntimeDataRepo::new(job),
                    feat: FeatureMatrixCache::new(),
                });
                if sc.repo.len() == sample.len() {
                    sc.repo.rebase_records(&sample);
                } else {
                    // sample size moved (corpus growth, capacity change):
                    // the slot mapping is meaningless — rebuild the mirror
                    sc.repo = RuntimeDataRepo::from_records(job, sample);
                    sc.feat = FeatureMatrixCache::new();
                }
                let reused = sc.feat.refresh(&Featurizer::new(cloud), &sc.repo);
                sc.repo.note_refresh();
                self.scratch
                    .add(Stage::Featurize, feat_started.elapsed().as_nanos() as u64);
                metrics.featurized_rows_reused += reused as u64;
                select_and_train_pooled(
                    engine,
                    cloud,
                    &sc.repo,
                    policy.cv_folds,
                    gen,
                    Some(&mut sc.feat),
                    self.pool.as_deref(),
                )
                .map_err(ApiError::internal)?
            } else {
                let feat_started = std::time::Instant::now();
                let reused = self.feat_cache.refresh(&Featurizer::new(cloud), &self.repo);
                self.repo.note_refresh();
                self.scratch
                    .add(Stage::Featurize, feat_started.elapsed().as_nanos() as u64);
                metrics.featurized_rows_reused += reused as u64;
                select_and_train_pooled(
                    engine,
                    cloud,
                    &self.repo,
                    policy.cv_folds,
                    gen,
                    Some(&mut self.feat_cache),
                    self.pool.as_deref(),
                )
                .map_err(ApiError::internal)?
            };
            self.scratch.add(Stage::CrossValidate, report.cv_nanos);
            self.scratch.add(Stage::WinnerFit, report.fit_nanos);
            self.scratch.add(Stage::PoolWait, report.pool_wait_nanos);
            self.model = Some(Arc::new(CachedModel {
                trained_at_gen: gen,
                model,
                report,
            }));
            metrics.retrains += 1;
            metrics.retrain_nanos_total += started.elapsed().as_nanos() as u64;
        }
        Ok(self.model.as_ref().map(|m| m.model.kind))
    }

    /// Drain the per-stage durations the shard's internals accumulated
    /// since the last drain (the featurize/CV/winner-fit retrain split,
    /// WAL append + fsync), indexed by [`Stage::index`]. The concurrent
    /// service calls this while still holding the shard lock and turns
    /// the durations into trace spans; the sequential deployments never
    /// drain, which is harmless — the scratch is a fixed array.
    pub fn take_stage_nanos(&mut self) -> [u64; Stage::COUNT] {
        self.scratch.take()
    }

    /// Read-only recommendation straight off the shard (the sequential
    /// deployments' path; the service uses [`ModelSnapshot::recommend`]
    /// on the published snapshot — same decision code either way).
    pub fn recommend(
        &self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        request: &JobRequest,
    ) -> Result<Recommendation, ApiError> {
        let Some(cached) = &self.model else {
            return Err(ApiError::ColdStart {
                job: self.job,
                records: self.repo.len(),
                min_records: policy.min_records,
            });
        };
        let choice = decide_with_model(
            engine,
            cloud,
            &cached.model,
            &self.observed_machines(),
            request,
        )
        .map_err(ApiError::internal)?;
        Ok(Recommendation {
            job: self.job,
            choice,
            model_used: cached.model.kind,
            generation: self.repo.generation(),
            trained_at_generation: cached.trained_at_gen,
        })
    }

    /// Full submission loop for one job request: decide a configuration
    /// from the cached model (or the cold-start fallback) → provision +
    /// run → contribute the measurement → refresh the model → account
    /// metrics. Speaks the typed error taxonomy end to end: model and
    /// simulator failures surface as [`ApiError::Internal`], persistence
    /// failures as [`ApiError::Store`] — the same classification the
    /// contribute/share/sync write paths use.
    pub fn submit(
        &mut self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        metrics: &mut Metrics,
        org: &Organization,
        request: &JobRequest,
    ) -> Result<JobOutcome, ApiError> {
        self.submit_predecided(engine, cloud, policy, metrics, org, request, None)
    }

    /// [`submit`](Self::submit) with an optional pre-decided
    /// configuration. Write-side coalescing pre-scores a same-kind
    /// group of submits against one snapshot of the cached model as a
    /// single [`QueryBatch`] predict; each group member then runs its
    /// serialized contribute step here with the decision already in
    /// hand. A pre-decided choice is only honoured while a model is
    /// cached — if the shard went cold it is discarded and the regular
    /// fallback path runs.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn submit_predecided(
        &mut self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        metrics: &mut Metrics,
        org: &Organization,
        request: &JobRequest,
        predecided: Option<ClusterChoice>,
    ) -> Result<JobOutcome, ApiError> {
        debug_assert_eq!(request.kind(), self.job, "request routed to wrong shard");

        // 1) decide a configuration — from the write-maintained cached
        //    model, exactly as a read-only `Recommend` would
        let (machine, scaleout, predicted, choice, model_used) = match (&self.model, predecided) {
            (Some(cached), Some(choice)) => {
                // decision pre-scored by the coalesced group pass
                metrics.cache_hits += 1;
                (
                    choice.machine_type.clone(),
                    choice.node_count,
                    choice.predicted_runtime_s,
                    Some(choice),
                    Some(cached.model.kind),
                )
            }
            (Some(cached), None) => {
                let choice = decide_with_model(
                    &mut *engine,
                    cloud,
                    &cached.model,
                    &self.observed_machines(),
                    request,
                )
                .map_err(ApiError::internal)?;
                metrics.cache_hits += 1;
                (
                    choice.machine_type.clone(),
                    choice.node_count,
                    choice.predicted_runtime_s,
                    Some(choice),
                    Some(cached.model.kind),
                )
            }
            (None, _) => {
                // cold start: conservative overprovisioning
                let mut oracle = SimOracle::new(self.job, self.rng.next_u64());
                let out = NaiveMax::default()
                    .search(cloud, &mut oracle, request)
                    .map_err(ApiError::internal)?;
                metrics.fallbacks += 1;
                (out.machine, out.scaleout, f64::NAN, None, None)
            }
        };

        // 2) provision + run (the cloud access manager step)
        let mut cluster = cloud.provision(&machine, scaleout, &mut self.rng);
        cluster.mark_running();
        let spec_stages = request.spec.stages();
        let mt = cloud
            .machine(&machine)
            .ok_or_else(|| ApiError::Internal(format!("machine `{machine}` missing from catalog")))?;
        let sim = crate::sim::Simulator::default();
        let mut run_rng = self.rng.fork(0xEC);
        let actual = sim.run(mt, scaleout, &spec_stages, &mut run_rng).runtime_s;
        cluster.record_busy(actual);
        let held = cluster.terminate();
        let cost = cloud.cost_usd(&machine, scaleout, held);

        // 3) contribute the new record to the shared repository
        let record = RuntimeRecord {
            job: self.job,
            org: org.name.clone(),
            machine: machine.clone(),
            scaleout,
            job_features: request.spec.job_features(),
            runtime_s: actual,
        };
        // duplicate configs are fine at contribution time; merge-level
        // dedup happens when repos are exchanged between parties
        let op = self.store.is_some().then(|| record.clone());
        let seqno = self
            .repo
            .contribute(record)
            .map_err(|e| ApiError::Internal(format!("contributing submit record: {e}")))?;
        if let Some(rec) = op {
            self.persist(&[StoreOp::Contribute { seqno, record: rec }])?;
        }

        // 4) the write maintains the model the reads are served from
        self.refresh_model(engine, cloud, policy, metrics)?;

        // 5) metrics
        let met_target = request.target_s.map_or(true, |t| actual <= t);
        metrics.submissions += 1;
        metrics.total_cost_usd += cost;
        if request.target_s.is_some() {
            metrics.targets_given += 1;
            if met_target {
                metrics.targets_met += 1;
            }
        }
        let outcome = JobOutcome {
            org: org.name.clone(),
            job: self.job,
            choice,
            machine,
            scaleout,
            model_used,
            predicted_runtime_s: predicted,
            actual_runtime_s: actual,
            actual_cost_usd: cost,
            provisioning_s: cluster.provisioning_delay_s(),
            target_s: request.target_s,
            met_target,
        };
        if !outcome.prediction_error_pct().is_nan() {
            metrics.ape_sum += outcome.prediction_error_pct();
            metrics.ape_count += 1;
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Engine;
    use crate::workloads::ExperimentGrid;

    #[test]
    fn cold_shard_has_no_model_and_no_report() {
        let shard = JobShard::new(JobKind::Sort, 1);
        assert_eq!(shard.generation(), 0);
        assert!(shard.trained_at_generation().is_none());
        assert!(shard.selection_report().is_none());
        assert!(shard.repo().is_empty());
        let snap = shard.snapshot();
        assert_eq!(snap.records, 0);
        assert!(snap.model.is_none());
        assert!(snap.observed_machines.is_empty());
    }

    #[test]
    fn refresh_model_respects_cold_start_threshold() {
        let cloud = Cloud::aws_like();
        let mut shard = JobShard::new(JobKind::Sort, 2);
        let mut engine = Engine::native();
        let mut metrics = Metrics::default();
        let policy = ShardPolicy::default();
        let kind = shard
            .refresh_model(&mut engine, &cloud, &policy, &mut metrics)
            .unwrap();
        assert!(kind.is_none(), "empty shard must not train");
        assert_eq!(metrics.retrains, 0);
        assert_eq!(metrics.cache_hits, 0);
    }

    #[test]
    fn cold_recommend_is_a_typed_error_not_a_fallback() {
        let cloud = Cloud::aws_like();
        let shard = JobShard::new(JobKind::Sort, 3);
        let mut engine = Engine::native();
        let policy = ShardPolicy::default();
        let err = shard
            .recommend(&mut engine, &cloud, &policy, &JobRequest::sort(10.0))
            .unwrap_err();
        match err {
            ApiError::ColdStart {
                job,
                records,
                min_records,
            } => {
                assert_eq!(job, JobKind::Sort);
                assert_eq!(records, 0);
                assert_eq!(min_records, policy.min_records);
            }
            other => panic!("expected ColdStart, got {other:?}"),
        }
    }

    #[test]
    fn snapshot_recommend_matches_shard_recommend_bitwise() {
        let cloud = Cloud::aws_like();
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 1,
        };
        let repo = grid.execute(&cloud, 5).repo_for(JobKind::Sort);
        let mut shard = JobShard::new(JobKind::Sort, 4);
        let mut engine = Engine::native();
        let mut metrics = Metrics::default();
        let policy = ShardPolicy::default();
        shard.share(&repo).unwrap();
        shard
            .refresh_model(&mut engine, &cloud, &policy, &mut metrics)
            .unwrap()
            .expect("corpus exceeds cold-start threshold");

        let request = JobRequest::sort(14.5).with_target_seconds(700.0);
        let direct = shard
            .recommend(&mut engine, &cloud, &policy, &request)
            .unwrap();
        let snap = shard.snapshot();
        let via_snapshot = snap
            .recommend(&mut engine, &cloud, &policy, &request)
            .unwrap();
        assert_eq!(direct.choice.machine_type, via_snapshot.choice.machine_type);
        assert_eq!(direct.choice.node_count, via_snapshot.choice.node_count);
        assert_eq!(
            direct.choice.predicted_runtime_s.to_bits(),
            via_snapshot.choice.predicted_runtime_s.to_bits()
        );
        assert_eq!(direct.generation, via_snapshot.generation);
        assert_eq!(
            direct.trained_at_generation,
            via_snapshot.trained_at_generation
        );

        // coalescing several requests into one predict batch must not
        // change any individual decision
        let requests = [
            request.clone(),
            JobRequest::sort(11.0),
            JobRequest::sort(19.0).with_target_seconds(300.0),
        ];
        let coalesced = snap.recommend_batch(&mut engine, &cloud, &policy, &requests);
        let first = coalesced[0].as_ref().unwrap();
        assert_eq!(
            first.choice.predicted_runtime_s.to_bits(),
            via_snapshot.choice.predicted_runtime_s.to_bits()
        );
        for (req, result) in requests.iter().zip(&coalesced) {
            let one = snap.recommend(&mut engine, &cloud, &policy, req).unwrap();
            let many = result.as_ref().unwrap();
            assert_eq!(one.choice.machine_type, many.choice.machine_type);
            assert_eq!(one.choice.node_count, many.choice.node_count);
            assert_eq!(
                one.choice.predicted_runtime_s.to_bits(),
                many.choice.predicted_runtime_s.to_bits()
            );
        }
    }

    #[test]
    fn sampled_retrain_reuses_stable_sample_rows() {
        use crate::models::native::NativeEngine;
        let cloud = Cloud::aws_like();
        let mut shard = JobShard::new(JobKind::Sort, 7);
        let mut engine = Engine::Native(NativeEngine {
            knn_rows: 12,
            ..NativeEngine::default()
        });
        let mut metrics = Metrics::default();
        // retrain_every 0: every refresh retrains, so we can retrain
        // twice over an unchanged corpus and observe the cache replay
        let policy = ShardPolicy {
            retrain_every: 0,
            min_records: 4,
            cv_folds: 3,
        };
        let machines = ["m5.xlarge", "c5.xlarge", "r5.xlarge"];
        for i in 0..30u32 {
            shard
                .contribute_record(RuntimeRecord {
                    job: JobKind::Sort,
                    org: "o".into(),
                    machine: machines[(i as usize) % 3].into(),
                    scaleout: 2 + (i % 8),
                    job_features: vec![10.0 + f64::from(i)],
                    runtime_s: 100.0 + f64::from(i),
                })
                .unwrap();
        }
        shard
            .refresh_model(&mut engine, &cloud, &policy, &mut metrics)
            .unwrap()
            .expect("over-capacity corpus trains");
        assert_eq!(metrics.retrains, 1);
        let sc = shard.sampled_cache.as_ref().expect("sampled cache built");
        assert_eq!(sc.repo.len(), 12, "mirror holds the coverage sample");
        let after_first = metrics.featurized_rows_reused;
        // identical corpus → identical sample → rebase swaps nothing →
        // every sampled row replays from the cache
        shard
            .refresh_model(&mut engine, &cloud, &policy, &mut metrics)
            .unwrap()
            .expect("second retrain");
        assert_eq!(metrics.retrains, 2);
        assert_eq!(metrics.featurized_rows_reused - after_first, 12);
    }

    #[test]
    fn contribute_record_rejects_cross_kind_and_invalid() {
        let mut shard = JobShard::new(JobKind::Sort, 6);
        let grep = RuntimeRecord {
            job: JobKind::Grep,
            org: "o".into(),
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![10.0, 0.1],
            runtime_s: 100.0,
        };
        assert!(matches!(
            shard.contribute_record(grep),
            Err(ApiError::InvalidRequest(_))
        ));
        let bad_runtime = RuntimeRecord {
            job: JobKind::Sort,
            org: "o".into(),
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![10.0],
            runtime_s: -1.0,
        };
        assert!(matches!(
            shard.contribute_record(bad_runtime),
            Err(ApiError::InvalidRequest(_))
        ));
        let good = RuntimeRecord {
            job: JobKind::Sort,
            org: "o".into(),
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![10.0],
            runtime_s: 100.0,
        };
        let c = shard.contribute_record(good).unwrap();
        assert_eq!(c.added, 1);
        assert_eq!(c.generation, 1);
        assert_eq!(shard.repo().len(), 1);
    }
}
