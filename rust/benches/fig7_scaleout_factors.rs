//! Bench: regenerate Fig. 7 (Grep scale-out behavior vs dataset size and
//! keyword ratio — shape invariance/variance).

use c3o::cloud::Cloud;
use c3o::figures;
use c3o::util::bench::{black_box, Bench};

fn main() {
    let cloud = Cloud::aws_like();

    let fig = figures::fig7(&cloud, 42);
    println!("{}", fig.render());
    assert!(fig.all_claims_hold(), "Fig. 7 reproduction failed");

    let mut b = Bench::new("fig7_scaleout_factors");
    b.run("full_fig7_sweep", || {
        black_box(figures::fig7(&cloud, 42).table.rows.len())
    });
    b.finish();
}
