//! The real tree must lint clean: this is the same gate CI runs
//! (`cargo run -p c3o-lint -- --json`), wired into `cargo test` so a
//! violation fails the suite even without the dedicated CI job.

use c3o_lint::{scan_tree, LintConfig};
use std::path::PathBuf;

#[test]
fn lint_self_clean() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&manifest.join("lint.toml")).unwrap();
    let result = scan_tree(&cfg).unwrap();
    let rendered: Vec<String> = result
        .findings
        .iter()
        .map(|f| format!("{}:{}: {}: {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        result.findings.is_empty(),
        "c3o-lint found unsuppressed violations in rust/src \
         (fix them or add a justified `c3o-lint: allow`):\n{}",
        rendered.join("\n")
    );
    assert!(
        result.files_scanned > 30,
        "walker found only {} files — wrong root?",
        result.files_scanned
    );
    assert!(
        !result.suppressed.is_empty(),
        "the real tree carries justified suppressions; zero means the \
         directive parser regressed"
    );
}
