//! The collaboration coordinator — the C3O system runtime (paper Fig. 1/2).
//!
//! Every deployment shape serves the same **typed protocol**
//! ([`crate::api`]): a versioned [`Request`](crate::api::Request) /
//! [`Response`](crate::api::Response) pair with a structured
//! [`ApiError`] taxonomy, behind the deployment-agnostic
//! [`Client`](crate::api::Client) trait. The protocol splits the
//! paper's collaborative loop into its two asymmetric halves:
//!
//! * **Reads** — `Recommend` (score every `machine × scaleout`
//!   candidate and return the decision without provisioning or
//!   running), `SnapshotInfo`, `Metrics`. Reads never train and never
//!   mutate; they are served from the model state the last write left
//!   behind.
//! * **Writes** — `Submit` (the full loop: decide → provision + run →
//!   contribute), `Contribute` (record an externally-observed run),
//!   `Share` (bulk-merge a repository), `SyncPush` (apply a federated
//!   peer's delta). Writes mutate the shared repository — persisting
//!   through the shard's segment store in durable deployments — and
//!   then **refresh the model** the reads are served from (retraining
//!   is gated on the repo's generation counter).
//!
//! Deployments built with [`Coordinator::open_with_store`] /
//! [`service::ServiceConfig::with_store_dir`] are **durable**: the
//! corpus is recovered from the [`crate::store`] segment store on
//! startup (model caches warmed from the recovered generation), and the
//! `Watermarks`/`SyncPull`/`SyncPush` requests let independent
//! deployments exchange deltas until they hold bitwise-identical
//! repositories (see [`crate::store::sync`]).
//!
//! The stack is **sharded by job kind** and layered:
//!
//! * [`shard`] — a [`JobShard`](shard::JobShard) per [`JobKind`] owns
//!   that kind's shared runtime-data repository, its RNG stream, and its
//!   generation-cached model (dynamic model selection §V-C; coverage
//!   sampling §III-C past the kNN capacity). Shards export immutable
//!   [`ModelSnapshot`](shard::ModelSnapshot)s — everything a read needs,
//!   detached from the shard.
//! * [`Coordinator`] (this module) — the sequential deployment: one
//!   engine, plain shards, no threads.
//! * [`session`] — the ordered single-worker deployment: one thread owns
//!   a whole coordinator behind a strictly-ordered request/reply channel
//!   pair. Kept as the throughput baseline.
//! * [`service`] — the concurrent deployment: shards behind mutexes
//!   taken **only by writes**; reads are served lock-free from published
//!   `Arc<ModelSnapshot>`s by `N` worker threads, with per-request reply
//!   channels, pipelined `submit_nowait` tickets, and cross-request
//!   coalescing of same-kind `Recommend` *and* `Submit` batches (write
//!   groups are pre-scored as one predict batch before their serialized
//!   contribute steps).
//!
//! One submission flows: route to the kind's shard → decide from the
//! write-maintained model (all candidates scored as one featurized
//! batch; cheapest configuration meeting the target) → provision (paying
//! the EMR-like delay) and run on the dataflow simulator → contribute
//! the measurement back → refresh the model, closing the collaborative
//! loop. Cold-start submissions fall back to conservative
//! overprovisioning — and the run they contribute shrinks that window
//! for everyone.
//!
//! Model execution is backend-agnostic ([`crate::models::ModelTrainer`]):
//! PJRT-compiled artifacts when available, bit-compatible pure-Rust
//! engines otherwise, so the whole stack works on a bare `cargo test`.

// Serving zone: unwraps are outages. The module-scoped clippy
// promotion mirrors the repo lint's `no-panic-serving` rule
// (see rust/lint).
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

pub mod service;
pub mod session;
pub mod shard;

pub use service::{CoordinatorService, ServiceClient, ServiceConfig, SubmitTicket};
pub use shard::{JobShard, ModelSnapshot, ShardPolicy};

use crate::api::compat::{self, V2Host};
use crate::api::{
    ApiError, Client, Contribution, MeshHello, MeshView, Recommendation, Request, Response,
    SnapshotInfo, SyncDelta, SyncDeltaV2, SyncReport, SyncReportAll, WatermarkSet, WatermarkSetV2,
};
use crate::cloud::Cloud;
use crate::configurator::{ClusterChoice, JobRequest};
use crate::models::selection::SelectionReport;
use crate::models::{Engine, ModelKind, ModelTrainer};
use crate::repo::{
    OrgSnapshot, OrgWatermark, OrgWatermarkV2, RuntimeDataRepo, RuntimeRecord, SyncOp,
};
use crate::store::mesh::MeshState;
use crate::store::JobStore;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::workloads::JobKind;
use std::collections::{BTreeMap, HashMap};
use std::path::Path;

/// A participating organization (provenance + its usual submission niche).
#[derive(Debug, Clone, PartialEq)]
pub struct Organization {
    pub name: String,
}

impl Organization {
    pub fn new(name: &str) -> Self {
        Organization {
            name: name.to_string(),
        }
    }
}

/// The outcome of one submitted job, end to end.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub org: String,
    pub job: JobKind,
    /// The configuration decision (None when the cold-start fallback ran).
    pub choice: Option<ClusterChoice>,
    pub machine: String,
    pub scaleout: u32,
    pub model_used: Option<ModelKind>,
    pub predicted_runtime_s: f64,
    pub actual_runtime_s: f64,
    /// Cluster cost of the actual run (incl. provisioning).
    pub actual_cost_usd: f64,
    pub provisioning_s: f64,
    pub target_s: Option<f64>,
    pub met_target: bool,
}

impl JobOutcome {
    /// Absolute percentage error of the runtime prediction (NaN for
    /// fallback runs without a prediction).
    pub fn prediction_error_pct(&self) -> f64 {
        if self.predicted_runtime_s.is_nan() {
            f64::NAN
        } else {
            100.0 * ((self.predicted_runtime_s - self.actual_runtime_s) / self.actual_runtime_s).abs()
        }
    }

    /// JSON projection (stable key order) for `c3o configure --json`.
    /// Candidate details live in `choice`; NaN predictions render as
    /// `null` per JSON rules.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("org", Json::Str(self.org.clone())),
            ("job", Json::Str(self.job.name().to_string())),
            ("machine", Json::Str(self.machine.clone())),
            ("scaleout", Json::Num(self.scaleout as f64)),
            (
                "model",
                self.model_used
                    .map_or(Json::Null, |k| Json::Str(k.name().to_string())),
            ),
            ("predicted_runtime_s", Json::Num(self.predicted_runtime_s)),
            ("actual_runtime_s", Json::Num(self.actual_runtime_s)),
            ("prediction_error_pct", Json::Num(self.prediction_error_pct())),
            ("actual_cost_usd", Json::Num(self.actual_cost_usd)),
            ("provisioning_s", Json::Num(self.provisioning_s)),
            ("target_s", self.target_s.map_or(Json::Null, Json::Num)),
            ("met_target", Json::Bool(self.met_target)),
        ])
    }
}

/// Aggregate coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub submissions: u64,
    pub fallbacks: u64,
    /// Model (re)trainings actually performed (always on the write path).
    pub retrains: u64,
    /// Model-served `Submit` decisions — the cached model answered
    /// without retraining (the observable complement of `retrains`).
    pub cache_hits: u64,
    /// Read-only `Recommend` requests served.
    pub recommends: u64,
    /// Externally-observed runs recorded via `Contribute` (bulk `Share`
    /// merges are not counted here).
    pub contributions: u64,
    /// `Recommend` groups the service scored as one coalesced predict
    /// batch (each group covers ≥ 2 requests).
    pub coalesced_batches: u64,
    /// `Submit` groups whose decisions the service pre-scored as one
    /// coalesced predict batch (each group covers ≥ 2 submits).
    pub coalesced_write_batches: u64,
    /// Wall-clock nanoseconds spent in model refreshes (CV + winner
    /// train), summed over all retrains.
    pub retrain_nanos_total: u64,
    /// Already-featurized rows the incremental feature cache reused
    /// across retrains (rows NOT re-run through the featurizer).
    pub featurized_rows_reused: u64,
    /// Peer deltas applied via `SyncPush` (including no-op re-pushes).
    pub sync_pushes: u64,
    /// Records a `SyncPush` actually added or replaced.
    pub sync_records_applied: u64,
    /// Runtime disagreements surfaced while applying peer deltas.
    pub sync_conflicts: u64,
    /// Mesh gossip hellos observed (self-ticks included).
    pub mesh_hellos: u64,
    /// Roster members evicted for staleness.
    pub mesh_evictions: u64,
    /// Op-log entries folded into base snapshots by acked-floor
    /// truncation.
    pub ops_truncated: u64,
    pub targets_given: u64,
    pub targets_met: u64,
    pub total_cost_usd: f64,
    /// Sum + count of absolute percentage errors (model-served runs).
    pub ape_sum: f64,
    pub ape_count: u64,
}

impl Metrics {
    pub fn mean_prediction_error_pct(&self) -> f64 {
        if self.ape_count == 0 {
            f64::NAN
        } else {
            self.ape_sum / self.ape_count as f64
        }
    }

    pub fn target_hit_rate(&self) -> f64 {
        if self.targets_given == 0 {
            f64::NAN
        } else {
            self.targets_met as f64 / self.targets_given as f64
        }
    }

    /// JSON rendering of every counter (the `c3o serve --json` surface,
    /// so the incremental-training and coalescing effects are observable
    /// in production, not just in benches).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submissions", Json::Num(self.submissions as f64)),
            ("fallbacks", Json::Num(self.fallbacks as f64)),
            ("retrains", Json::Num(self.retrains as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("recommends", Json::Num(self.recommends as f64)),
            ("contributions", Json::Num(self.contributions as f64)),
            ("coalesced_batches", Json::Num(self.coalesced_batches as f64)),
            (
                "coalesced_write_batches",
                Json::Num(self.coalesced_write_batches as f64),
            ),
            ("retrain_nanos_total", Json::Num(self.retrain_nanos_total as f64)),
            (
                "featurized_rows_reused",
                Json::Num(self.featurized_rows_reused as f64),
            ),
            ("sync_pushes", Json::Num(self.sync_pushes as f64)),
            (
                "sync_records_applied",
                Json::Num(self.sync_records_applied as f64),
            ),
            ("sync_conflicts", Json::Num(self.sync_conflicts as f64)),
            ("mesh_hellos", Json::Num(self.mesh_hellos as f64)),
            ("mesh_evictions", Json::Num(self.mesh_evictions as f64)),
            ("ops_truncated", Json::Num(self.ops_truncated as f64)),
            ("targets_given", Json::Num(self.targets_given as f64)),
            ("targets_met", Json::Num(self.targets_met as f64)),
            ("target_hit_rate", Json::Num(self.target_hit_rate())),
            ("total_cost_usd", Json::Num(self.total_cost_usd)),
            (
                "mean_prediction_error_pct",
                Json::Num(self.mean_prediction_error_pct()),
            ),
        ])
    }

    /// Fold another metrics block into this one (the service workers
    /// stage per-request metrics locally and fold them in afterwards).
    pub fn fold(&mut self, other: &Metrics) {
        self.submissions += other.submissions;
        self.fallbacks += other.fallbacks;
        self.retrains += other.retrains;
        self.cache_hits += other.cache_hits;
        self.recommends += other.recommends;
        self.contributions += other.contributions;
        self.coalesced_batches += other.coalesced_batches;
        self.coalesced_write_batches += other.coalesced_write_batches;
        self.retrain_nanos_total += other.retrain_nanos_total;
        self.featurized_rows_reused += other.featurized_rows_reused;
        self.sync_pushes += other.sync_pushes;
        self.sync_records_applied += other.sync_records_applied;
        self.sync_conflicts += other.sync_conflicts;
        self.mesh_hellos += other.mesh_hellos;
        self.mesh_evictions += other.mesh_evictions;
        self.ops_truncated += other.ops_truncated;
        self.targets_given += other.targets_given;
        self.targets_met += other.targets_met;
        self.total_cost_usd += other.total_cost_usd;
        self.ape_sum += other.ape_sum;
        self.ape_count += other.ape_count;
    }
}

/// The sequential C3O coordinator: one model engine over per-job-kind
/// shards. The concurrent deployment of the same pipeline is
/// [`service::CoordinatorService`]; all deployments speak the
/// [`crate::api`] protocol through [`crate::api::Client`].
pub struct Coordinator {
    cloud: Cloud,
    engine: Engine,
    shards: HashMap<JobKind, JobShard>,
    /// Retrain when the repo generation advanced this far since the last
    /// training.
    pub retrain_every: u64,
    /// Minimum records before the model path activates (cold-start
    /// threshold).
    pub min_records: usize,
    /// CV folds for dynamic selection.
    pub cv_folds: usize,
    metrics: Metrics,
    seed_rng: Pcg32,
    /// Mesh membership: who this deployment is in the federation and
    /// which peers it currently believes in (see [`crate::store::mesh`]).
    mesh: MeshState,
}

impl Coordinator {
    /// Build a coordinator over a cloud and an artifacts directory. Uses
    /// the PJRT backend when the artifacts load, the native engines
    /// otherwise — construction itself cannot fail on a missing runtime.
    pub fn new(cloud: Cloud, artifacts_dir: &Path, seed: u64) -> Result<Coordinator, ApiError> {
        Ok(Coordinator::with_engine(
            cloud,
            Engine::auto(artifacts_dir),
            seed,
        ))
    }

    /// Build a **durable** coordinator over a segment store: every
    /// job's repository is recovered from `store_root` (newest snapshot
    /// + WAL replay), models are warmed from the recovered corpora, and
    /// all subsequent writes persist through the store. A fresh (empty)
    /// directory yields an empty-but-durable coordinator.
    pub fn open_with_store(
        cloud: Cloud,
        artifacts_dir: &Path,
        seed: u64,
        store_root: &Path,
    ) -> Result<Coordinator, ApiError> {
        let mut coord = Coordinator::new(cloud, artifacts_dir, seed)?;
        let policy = coord.policy();
        for kind in JobKind::all() {
            let (store, repo) = JobStore::open(store_root, kind)?;
            let shard_seed = coord.seed_rng.next_u64();
            let mut shard = JobShard::recover(kind, shard_seed, store, repo);
            // warm the model cache so recovered reads are served
            // without waiting for the next write
            shard.refresh_model(&mut coord.engine, &coord.cloud, &policy, &mut coord.metrics)?;
            coord.shards.insert(kind, shard);
        }
        Ok(coord)
    }

    /// Build over an explicit model engine.
    pub fn with_engine(cloud: Cloud, engine: Engine, seed: u64) -> Coordinator {
        let policy = ShardPolicy::default();
        Coordinator {
            cloud,
            engine,
            shards: HashMap::new(),
            retrain_every: policy.retrain_every,
            min_records: policy.min_records,
            cv_folds: policy.cv_folds,
            metrics: Metrics::default(),
            seed_rng: Pcg32::new(seed),
            mesh: MeshState::new("c3o"),
        }
    }

    /// Rename this deployment's mesh identity (resets membership —
    /// meant for wiring, before the first hello).
    pub fn set_mesh_name(&mut self, name: &str) {
        self.mesh = MeshState::new(name);
    }

    /// The deployment's mesh membership state.
    pub fn mesh(&self) -> &MeshState {
        &self.mesh
    }

    pub fn cloud(&self) -> &Cloud {
        &self.cloud
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Which model backend serves this coordinator (`"pjrt"`/`"native"`).
    pub fn backend(&self) -> &'static str {
        self.engine.backend()
    }

    /// The shared repository for a job (empty if nothing shared yet).
    pub fn repo(&self, job: JobKind) -> Option<&RuntimeDataRepo> {
        self.shards.get(&job).map(|s| s.repo())
    }

    /// Current repo generation for a job (0 if nothing shared yet).
    pub fn generation(&self, job: JobKind) -> u64 {
        self.shards.get(&job).map_or(0, |s| s.generation())
    }

    /// Latest selection report for a job's model, if trained.
    pub fn selection_report(&self, job: JobKind) -> Option<&SelectionReport> {
        self.shards.get(&job).and_then(|s| s.selection_report())
    }

    fn policy(&self) -> ShardPolicy {
        ShardPolicy {
            retrain_every: self.retrain_every,
            min_records: self.min_records,
            cv_folds: self.cv_folds,
        }
    }

    /// Ensure a shard exists for `job` (writes allocate shards; reads
    /// never do — a missing shard is simply cold). Takes the two
    /// fields it touches instead of `&mut self` so the returned shard
    /// borrow stays disjoint from `engine`/`metrics` at call sites.
    fn ensure_shard<'a>(
        shards: &'a mut HashMap<JobKind, JobShard>,
        seed_rng: &mut Pcg32,
        job: JobKind,
    ) -> &'a mut JobShard {
        shards
            .entry(job)
            .or_insert_with(|| JobShard::new(job, seed_rng.next_u64()))
    }

    /// **Write.** Merge externally shared data (e.g. the public corpus)
    /// into the job's repository — "users can contribute their generated
    /// runtime data" (§III-A) — then refresh the model reads are served
    /// from.
    pub fn share(&mut self, repo: &RuntimeDataRepo) -> Result<Contribution, ApiError> {
        crate::api::validate_machines(&self.cloud, repo.records())?;
        let policy = self.policy();
        let job = repo.job();
        let shard = Self::ensure_shard(&mut self.shards, &mut self.seed_rng, job);
        let outcome = shard.share(repo)?;
        shard.refresh_model(&mut self.engine, &self.cloud, &policy, &mut self.metrics)?;
        Ok(Contribution {
            job,
            added: outcome.added,
            generation: shard.generation(),
        })
    }

    /// **Write.** Full submission loop for one job request.
    pub fn submit(
        &mut self,
        org: &Organization,
        request: &JobRequest,
    ) -> Result<JobOutcome, ApiError> {
        request.validate()?;
        let policy = self.policy();
        let job = request.kind();
        let shard = Self::ensure_shard(&mut self.shards, &mut self.seed_rng, job);
        shard.submit(
            &mut self.engine,
            &self.cloud,
            &policy,
            &mut self.metrics,
            org,
            request,
        )
    }

    /// **Read.** Score every candidate configuration and return the
    /// decision `Submit` would make — without provisioning, running, or
    /// contributing. Errors with [`ApiError::ColdStart`] when the job's
    /// shared repository is below the cold-start threshold.
    pub fn recommend(&mut self, request: &JobRequest) -> Result<Recommendation, ApiError> {
        request.validate()?;
        let policy = self.policy();
        let job = request.kind();
        match self.shards.get(&job) {
            None => Err(ApiError::ColdStart {
                job,
                records: 0,
                min_records: policy.min_records,
            }),
            Some(shard) => {
                let rec = shard.recommend(&mut self.engine, &self.cloud, &policy, request)?;
                self.metrics.recommends += 1;
                Ok(rec)
            }
        }
    }

    /// **Write.** Record one externally-observed run (e.g. a
    /// `Recommend`-ed cluster the user actually ran) into the job's
    /// shared repository, then refresh the model.
    pub fn contribute(&mut self, record: RuntimeRecord) -> Result<Contribution, ApiError> {
        crate::api::validate_machines(&self.cloud, std::slice::from_ref(&record))?;
        let policy = self.policy();
        let job = record.job;
        let shard = Self::ensure_shard(&mut self.shards, &mut self.seed_rng, job);
        let contribution = shard.contribute_record(record)?;
        shard.refresh_model(&mut self.engine, &self.cloud, &policy, &mut self.metrics)?;
        self.metrics.contributions += 1;
        Ok(contribution)
    }

    /// **Read.** Describe the model state currently serving a job's
    /// reads (a missing shard is reported as cold, not allocated).
    pub fn snapshot_info(&self, job: JobKind) -> SnapshotInfo {
        match self.shards.get(&job) {
            Some(shard) => shard.snapshot_info(),
            None => ModelSnapshot::empty(job).info(),
        }
    }

    /// **Read.** Per-org op-log watermarks of a job's repository (empty
    /// for a cold job — reads never allocate shards).
    pub fn watermarks(&self, job: JobKind) -> WatermarkSet {
        match self.shards.get(&job) {
            Some(shard) => WatermarkSet {
                job,
                generation: shard.generation(),
                watermarks: shard.repo().watermarks(),
            },
            None => WatermarkSet {
                job,
                generation: 0,
                watermarks: BTreeMap::new(),
            },
        }
    }

    /// **Read.** Legacy (v2) holdings watermarks of a job's repository.
    pub fn watermarks_v2(&self, job: JobKind) -> WatermarkSetV2 {
        match self.shards.get(&job) {
            Some(shard) => WatermarkSetV2 {
                job,
                generation: shard.generation(),
                watermarks: shard.repo().watermarks_v2(),
            },
            None => WatermarkSetV2 {
                job,
                generation: 0,
                watermarks: BTreeMap::new(),
            },
        }
    }

    /// **Read.** Record-level delta extraction against a peer's op-log
    /// watermarks: per-op suffixes where the logs are prefix-aligned
    /// above the truncation floor, whole-org snapshot fallbacks where
    /// the peer sits below it.
    pub fn sync_pull(
        &self,
        job: JobKind,
        theirs: &BTreeMap<String, OrgWatermark>,
    ) -> SyncDelta {
        match self.shards.get(&job) {
            Some(shard) => {
                let plan = shard.repo().delta_plan(theirs);
                SyncDelta {
                    job,
                    generation: shard.generation(),
                    ops: plan.ops,
                    snapshots: plan.snapshots,
                    watermarks: shard.repo().watermarks(),
                }
            }
            None => SyncDelta {
                job,
                generation: 0,
                ops: Vec::new(),
                snapshots: Vec::new(),
                watermarks: BTreeMap::new(),
            },
        }
    }

    /// **Read.** Every job repository's watermarks, in [`JobKind::all`]
    /// order — the batched (v4) replacement for five `Watermarks` round
    /// trips.
    pub fn watermarks_all(&self) -> Vec<WatermarkSet> {
        JobKind::all().into_iter().map(|job| self.watermarks(job)).collect()
    }

    /// **Read.** Cross-job delta extraction: one [`Coordinator::sync_pull`]
    /// per supplied watermark set, in the supplied order.
    pub fn sync_pull_all(&self, theirs: &[WatermarkSet]) -> Vec<SyncDelta> {
        theirs
            .iter()
            .map(|set| self.sync_pull(set.job, &set.watermarks))
            .collect()
    }

    /// **Read.** Legacy (v2) org-granular delta extraction.
    pub fn sync_pull_v2(
        &self,
        job: JobKind,
        theirs: &BTreeMap<String, OrgWatermarkV2>,
    ) -> SyncDeltaV2 {
        match self.shards.get(&job) {
            Some(shard) => SyncDeltaV2 {
                job,
                generation: shard.generation(),
                records: shard.repo().delta_for_v2(theirs),
                watermarks: shard.repo().watermarks_v2(),
            },
            None => SyncDeltaV2 {
                job,
                generation: 0,
                records: Vec::new(),
                watermarks: BTreeMap::new(),
            },
        }
    }

    /// **Write.** Apply a peer's record-level delta: merge with
    /// deterministic conflict resolution, advance the org logs (seen
    /// ops included), adopt whole-org snapshot fallbacks, canonicalize
    /// the record order, refresh the model. Idempotent.
    pub fn sync_push(
        &mut self,
        job: JobKind,
        ops: &[SyncOp],
        snapshots: &[OrgSnapshot],
    ) -> Result<SyncReport, ApiError> {
        crate::api::validate_machines(&self.cloud, ops.iter().map(|op| &op.record))?;
        for snap in snapshots {
            crate::api::validate_machines(&self.cloud, &snap.records)?;
        }
        let policy = self.policy();
        let shard = Self::ensure_shard(&mut self.shards, &mut self.seed_rng, job);
        let offered = ops.len() + snapshots.iter().map(|s| s.records.len()).sum::<usize>();
        let mut outcome = shard.apply_sync_ops(ops)?;
        let (snap_outcome, snap_applied) = shard.apply_org_snapshots(snapshots)?;
        outcome.added += snap_outcome.added;
        outcome.replaced += snap_outcome.replaced;
        outcome.skipped += snap_outcome.skipped;
        outcome.conflicts.extend(snap_outcome.conflicts);
        outcome.logged.extend(snap_outcome.logged);
        shard.refresh_model(&mut self.engine, &self.cloud, &policy, &mut self.metrics)?;
        self.metrics.sync_pushes += 1;
        self.metrics.sync_records_applied += outcome.changed() as u64;
        self.metrics.sync_conflicts += outcome.conflicts.len() as u64;
        let mut report = SyncReport::tally(
            job,
            offered,
            outcome.added,
            outcome.replaced,
            outcome.conflicts,
            &outcome.logged,
            shard.generation(),
        );
        // adopted snapshot records fold into the prefix without logged
        // ops, so their per-org applied counts are added explicitly
        for (org, applied) in snap_applied {
            *report.applied_by_org.entry(org).or_default() += applied;
        }
        Ok(report)
    }

    /// **Write.** Apply a batched cross-job push and reply with
    /// post-apply watermarks for every job — the acks a mesh sender
    /// records for this deployment.
    pub fn sync_push_all(&mut self, deltas: Vec<SyncDelta>) -> Result<SyncReportAll, ApiError> {
        let mut reports = Vec::with_capacity(deltas.len());
        for delta in deltas {
            reports.push(self.sync_push(delta.job, &delta.ops, &delta.snapshots)?);
        }
        Ok(SyncReportAll {
            reports,
            watermarks: self.watermarks_all(),
        })
    }

    /// **Write.** Observe one mesh gossip hello. A *self*-hello is the
    /// anti-entropy tick: it advances the round, evicts stale members,
    /// and re-evaluates **acked-floor truncation** — for every job, the
    /// log prefix every live member has acked is folded into the base
    /// snapshot (durably, via a rebased compaction), bounding op-log
    /// memory by the unacked suffix. Any other hello marks the sender
    /// live and records its acks.
    pub fn observe_mesh_hello(&mut self, hello: &MeshHello) -> Result<MeshView, ApiError> {
        let tick = hello.from.id == self.mesh.local().id;
        let evicted = self
            .mesh
            .observe_hello(hello)
            .map_err(ApiError::InvalidRequest)?;
        self.metrics.mesh_hellos += 1;
        self.metrics.mesh_evictions += evicted;
        if tick {
            for kind in JobKind::all() {
                let floors = self.mesh.acked_floors(kind);
                if floors.is_empty() {
                    continue;
                }
                if let Some(shard) = self.shards.get_mut(&kind) {
                    self.metrics.ops_truncated += shard.truncate_to_floors(&floors)?;
                }
            }
        }
        Ok(self.mesh.view())
    }

    /// **Write.** Legacy (v2) delta application — the compatibility
    /// translation onto the op log (applied records get fresh local
    /// seqnos). Idempotent.
    pub fn sync_push_v2(
        &mut self,
        job: JobKind,
        records: &[RuntimeRecord],
    ) -> Result<SyncReport, ApiError> {
        crate::api::validate_machines(&self.cloud, records)?;
        let policy = self.policy();
        let shard = Self::ensure_shard(&mut self.shards, &mut self.seed_rng, job);
        let outcome = shard.apply_sync_records(records)?;
        shard.refresh_model(&mut self.engine, &self.cloud, &policy, &mut self.metrics)?;
        self.metrics.sync_pushes += 1;
        self.metrics.sync_records_applied += outcome.changed() as u64;
        self.metrics.sync_conflicts += outcome.conflicts.len() as u64;
        Ok(SyncReport::tally(
            job,
            records.len(),
            outcome.added,
            outcome.replaced,
            outcome.conflicts,
            &outcome.applied,
            shard.generation(),
        ))
    }
}

// The legacy (v2) surface: the sequential coordinator hands the compat
// adapter its three primitives; everything protocol-shaped stays in
// `api::compat`.
impl V2Host for Coordinator {
    fn v2_watermarks(&mut self, job: JobKind) -> Result<WatermarkSetV2, ApiError> {
        Ok(self.watermarks_v2(job))
    }

    fn v2_delta(
        &mut self,
        job: JobKind,
        theirs: &BTreeMap<String, OrgWatermarkV2>,
    ) -> Result<SyncDeltaV2, ApiError> {
        Ok(self.sync_pull_v2(job, theirs))
    }

    fn v2_apply(
        &mut self,
        job: JobKind,
        records: Vec<RuntimeRecord>,
    ) -> Result<SyncReport, ApiError> {
        self.sync_push_v2(job, &records)
    }
}

impl Client for Coordinator {
    fn call(&mut self, request: Request) -> Result<Response, ApiError> {
        match request {
            Request::Submit { org, request } => {
                self.submit(&org, &request).map(Response::Submitted)
            }
            Request::Recommend { request } => {
                self.recommend(&request).map(Response::Recommendation)
            }
            Request::Contribute { record } => self.contribute(record).map(Response::Contributed),
            Request::Share { repo } => self.share(&repo).map(Response::Shared),
            Request::Metrics => Ok(Response::Metrics(self.metrics.clone())),
            Request::SnapshotInfo { job } => Ok(Response::SnapshotInfo(self.snapshot_info(job))),
            Request::Watermarks { job } => Ok(Response::Watermarks(self.watermarks(job))),
            Request::SyncPull { job, watermarks } => {
                Ok(Response::SyncDelta(self.sync_pull(job, &watermarks)))
            }
            Request::SyncPush {
                job,
                ops,
                snapshots,
            } => self
                .sync_push(job, &ops, &snapshots)
                .map(Response::SyncApplied),
            Request::MeshHello { hello } => {
                self.observe_mesh_hello(&hello).map(Response::MeshView)
            }
            Request::MeshRoster => Ok(Response::MeshView(self.mesh.view())),
            Request::WatermarksAll => Ok(Response::WatermarksAll(self.watermarks_all())),
            Request::SyncPullAll { watermarks } => {
                Ok(Response::SyncDeltaAll(self.sync_pull_all(&watermarks)))
            }
            Request::SyncPushAll { deltas } => {
                self.sync_push_all(deltas).map(Response::SyncAppliedAll)
            }
            v2 @ (Request::WatermarksV2 { .. }
            | Request::SyncPullV2 { .. }
            | Request::SyncPushV2 { .. }) => compat::serve(self, v2),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::workloads::ExperimentGrid;

    fn corpus_repo(cloud: &Cloud, kind: JobKind) -> RuntimeDataRepo {
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == kind)
                .collect(),
            repetitions: 3,
        };
        grid.execute(cloud, 21).repo_for(kind)
    }

    // No artifacts gate: Engine::auto falls back to the native models, so
    // the full coordinator loop runs on a bare `cargo test`.
    fn coordinator(cloud: Cloud, seed: u64) -> Coordinator {
        Coordinator::new(cloud, &Runtime::default_dir(), seed).unwrap()
    }

    #[test]
    fn cold_start_falls_back_then_model_takes_over() {
        let cloud = Cloud::aws_like();
        let mut coord = coordinator(cloud, 1);
        coord.min_records = 5;
        coord.retrain_every = 5;
        let org = Organization::new("lab-a");
        // no shared data yet: fallback
        let o1 = coord.submit(&org, &JobRequest::sort(12.0)).unwrap();
        assert!(o1.model_used.is_none());
        assert_eq!(coord.metrics().fallbacks, 1);
        // a few more submissions build up the repo
        for gb in [10.0, 14.0, 16.0, 18.0] {
            coord.submit(&org, &JobRequest::sort(gb)).unwrap();
        }
        // now the model path must engage
        let o = coord.submit(&org, &JobRequest::sort(15.0)).unwrap();
        assert!(o.model_used.is_some(), "model should be trained now");
        assert!(coord.metrics().retrains >= 1);
        assert!(o.predicted_runtime_s > 0.0);
    }

    #[test]
    fn shared_corpus_enables_first_submission_model() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Grep);
        let mut coord = coordinator(cloud, 2);
        let shared = coord.share(&repo).unwrap();
        assert_eq!(shared.added, 162);
        assert_eq!(shared.generation, 162);
        let org = Organization::new("new-org");
        let req = JobRequest::grep(15.0, 0.1).with_target_seconds(500.0);
        let o = coord.submit(&org, &req).unwrap();
        // the very first submission is model-served — the paper's pitch
        assert!(o.model_used.is_some());
        assert!(o.prediction_error_pct() < 60.0, "err {}", o.prediction_error_pct());
        // and the new org's run landed in the shared repo
        let repo_after = coord.repo(JobKind::Grep).unwrap();
        assert_eq!(repo_after.len(), 163);
        assert!(repo_after.organizations().contains("new-org"));
    }

    #[test]
    fn retrain_cadence_respected() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 3);
        coord.retrain_every = 4;
        coord.share(&repo).unwrap();
        let org = Organization::new("o");
        for i in 0..9 {
            coord
                .submit(&org, &JobRequest::sort(10.0 + i as f64))
                .unwrap();
        }
        // share-time training + retrains every 4 contributions: 1 + 2
        assert_eq!(coord.metrics().retrains, 3, "{:?}", coord.metrics());
    }

    #[test]
    fn retraining_is_gated_by_repo_generation() {
        // The model cache is keyed by the repo generation: with no new
        // shared data past the threshold, repeated submissions must
        // trigger zero retrains — only cache hits.
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 5);
        coord.retrain_every = 1000; // far beyond this test's contributions
        coord.share(&repo).unwrap();
        assert_eq!(coord.metrics().retrains, 1, "the share trains the model");
        coord.submit(&Organization::new("steady"), &JobRequest::sort(12.0)).unwrap();
        assert_eq!(coord.metrics().retrains, 1, "submission served from cache");

        // re-sharing the identical corpus adds nothing and must not move
        // the generation
        let gen = coord.generation(JobKind::Sort);
        assert_eq!(coord.share(&repo).unwrap().added, 0);
        assert_eq!(coord.generation(JobKind::Sort), gen);

        let org = Organization::new("steady");
        for i in 0..6 {
            let o = coord
                .submit(&org, &JobRequest::sort(11.0 + i as f64))
                .unwrap();
            assert!(o.model_used.is_some());
        }
        let m = coord.metrics();
        assert_eq!(m.retrains, 1, "no retrain without new shared data: {m:?}");
        assert_eq!(m.cache_hits, 7, "every submission decides from the cache");
    }

    #[test]
    fn metrics_accumulate() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 4);
        coord.share(&repo).unwrap();
        let org = Organization::new("o");
        let req = JobRequest::sort(15.0).with_target_seconds(2000.0);
        let o = coord.submit(&org, &req).unwrap();
        assert!(o.met_target, "loose target should be met");
        let m = coord.metrics();
        assert_eq!(m.submissions, 1);
        assert_eq!(m.targets_given, 1);
        assert_eq!(m.targets_met, 1);
        assert!(m.total_cost_usd > 0.0);
        assert!(m.mean_prediction_error_pct().is_finite());
    }

    #[test]
    fn recommend_matches_submit_decision_bitwise() {
        // Two identically-seeded coordinators over the same shared
        // corpus: the read-only recommendation must equal the decision
        // inside a full submission, bit for bit.
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut a = coordinator(cloud.clone(), 6);
        let mut b = coordinator(cloud, 6);
        a.share(&repo).unwrap();
        b.share(&repo).unwrap();
        let req = JobRequest::sort(13.5).with_target_seconds(600.0);
        let outcome = a.submit(&Organization::new("o"), &req).unwrap();
        let rec = b.recommend(&req).unwrap();
        let choice = outcome.choice.expect("model-served");
        assert_eq!(choice.machine_type, rec.choice.machine_type);
        assert_eq!(choice.node_count, rec.choice.node_count);
        assert_eq!(
            choice.predicted_runtime_s.to_bits(),
            rec.choice.predicted_runtime_s.to_bits()
        );
        assert_eq!(
            choice.expected_cost_usd.to_bits(),
            rec.choice.expected_cost_usd.to_bits()
        );
        // the read mutated nothing
        assert_eq!(b.generation(JobKind::Sort), repo.len() as u64);
        assert_eq!(b.metrics().submissions, 0);
        assert_eq!(b.metrics().recommends, 1);
    }

    #[test]
    fn contribute_records_external_run_and_advances_generation() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 7);
        coord.share(&repo).unwrap();
        let gen = coord.generation(JobKind::Sort);
        let record = RuntimeRecord {
            job: JobKind::Sort,
            org: "external".into(),
            machine: "m5.xlarge".into(),
            scaleout: 6,
            job_features: vec![13.7],
            runtime_s: 312.5,
        };
        let c = coord.contribute(record).unwrap();
        assert_eq!(c.added, 1);
        assert_eq!(c.generation, gen + 1);
        assert_eq!(coord.metrics().contributions, 1);
        assert!(coord
            .repo(JobKind::Sort)
            .unwrap()
            .organizations()
            .contains("external"));
    }

    #[test]
    fn invalid_requests_are_rejected_at_the_boundary() {
        let cloud = Cloud::aws_like();
        let mut coord = coordinator(cloud, 8);
        let org = Organization::new("o");
        let bad = JobRequest::sort(10.0).with_target_seconds(-3.0);
        assert!(matches!(
            coord.submit(&org, &bad),
            Err(ApiError::InvalidRequest(_))
        ));
        assert!(matches!(
            coord.recommend(&bad),
            Err(ApiError::InvalidRequest(_))
        ));
        // nothing was allocated or recorded for the invalid request
        assert_eq!(coord.metrics().submissions, 0);
        assert_eq!(coord.generation(JobKind::Sort), 0);
    }

    #[test]
    fn cold_recommend_reports_cold_start_without_allocating() {
        let cloud = Cloud::aws_like();
        let mut coord = coordinator(cloud, 9);
        match coord.recommend(&JobRequest::sort(10.0)) {
            Err(ApiError::ColdStart { job, records, .. }) => {
                assert_eq!(job, JobKind::Sort);
                assert_eq!(records, 0);
            }
            other => panic!("expected ColdStart, got {other:?}"),
        }
        let info = coord.snapshot_info(JobKind::Sort);
        assert_eq!(info.records, 0);
        assert!(info.model.is_none());
    }

    #[test]
    fn client_trait_round_trips_the_protocol() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 10);
        let client: &mut dyn Client = &mut coord;
        let shared = client.share(repo.clone()).unwrap();
        assert_eq!(shared.added, repo.len());
        let info = client.snapshot_info(JobKind::Sort).unwrap();
        assert!(info.model.is_some(), "share trains the model");
        assert_eq!(info.records, repo.len());
        let rec = client.recommend(JobRequest::sort(12.0)).unwrap();
        assert!(rec.choice.predicted_runtime_s > 0.0);
        let outcome = client
            .submit(&Organization::new("o"), JobRequest::sort(12.0))
            .unwrap();
        assert!(outcome.model_used.is_some());
        let m = client.metrics().unwrap();
        assert_eq!(m.submissions, 1);
        assert_eq!(m.recommends, 1);
    }
}
