//! Durable persistence + federation: the subsystem that turns the
//! in-memory collaborative repositories into long-lived, *shared*
//! state — the paper's premise that runtime data outlives any one
//! process and flows between organizations.
//!
//! Two halves:
//!
//! * [`segment`] — the **durable segment store**: per-[`JobKind`]
//!   append-only WALs with generation-stamped, checksummed ops, atomic
//!   snapshots, and segment compaction. A coordinator or service
//!   recovers its full corpus (bitwise, including record order) from
//!   [`JobStore::open`] on startup, then warms its model caches from
//!   the recovered generation.
//! * [`sync`] — the **peer delta-sync protocol**: per-(org, job)
//!   high-water marks ([`crate::repo::OrgWatermark`]) drive
//!   `SyncPull`/`SyncPush` exchanges that ship only missing records.
//!   Merge-level dedup with deterministic conflict resolution makes the
//!   exchange idempotent and convergent: any gossip order drives peers
//!   to bitwise-identical repositories. [`SyncDriver`] runs the
//!   exchange on a background thread.
//!
//! The write path is layered: a [`JobShard`](crate::coordinator::shard)
//! mutates its repo, logs exactly the applied ops through its attached
//! [`JobStore`], and lets [`JobStore::maybe_compact`] fold the WAL into
//! a snapshot when it grows. Reads never touch the store.

pub mod segment;
pub mod sync;

pub use segment::{JobStore, StoreOp, DEFAULT_COMPACT_THRESHOLD, DEFAULT_SEGMENT_CAP};
pub use sync::{sync_all, sync_job, SyncDriver, SyncStats};

use crate::repo::RuntimeDataRepo;
use crate::workloads::JobKind;
use std::path::Path;

/// Open (or create) the per-job stores under `root`, recovering every
/// job's repository — one entry per [`JobKind::all`] kind, in that
/// order.
pub fn open_all(root: &Path) -> anyhow::Result<Vec<(JobStore, RuntimeDataRepo)>> {
    JobKind::all()
        .into_iter()
        .map(|kind| JobStore::open(root, kind))
        .collect()
}
