//! The deployment-agnostic protocol suite: one scenario written against
//! `&mut dyn Client`, run verbatim against all three deployments — the
//! sequential `Coordinator`, the ordered `Session`, and the concurrent
//! `CoordinatorService` — which must produce **identical decisions (and
//! identical simulated runs) on a fixed seed**. All three are forced
//! onto the native model engines so the comparison is
//! artifact-independent.

use c3o::api::{ApiError, Client};
use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::session::Session;
use c3o::coordinator::{Coordinator, CoordinatorService, Organization, ServiceConfig};
use c3o::models::Engine;
use c3o::repo::RuntimeRecord;
use c3o::workloads::{Corpus, ExperimentGrid, JobKind};
use std::path::PathBuf;

const SEED: u64 = 7;

fn corpus(cloud: &Cloud) -> Corpus {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| matches!(e.spec.kind(), JobKind::Sort | JobKind::Grep))
            .collect(),
        repetitions: 1,
    }
    .execute(cloud, 11)
}

/// Everything decision-relevant one scenario step produced, bit-exact.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    step: &'static str,
    machine: String,
    scaleout: u32,
    predicted_bits: u64,
    /// Simulated runtime of the actual run (0 for read-only steps).
    actual_bits: u64,
}

fn external_record() -> RuntimeRecord {
    RuntimeRecord {
        job: JobKind::Sort,
        org: "external".into(),
        machine: "m5.xlarge".into(),
        scaleout: 6,
        job_features: vec![13.25],
        runtime_s: 287.5,
    }
}

/// The scenario: cold read → shares (writes train) → read → write →
/// contribute → second-kind write → metrics. Returns the bit-exact
/// decision trace.
fn scenario(client: &mut dyn Client, corpus: &Corpus) -> Vec<Fingerprint> {
    let org = Organization::new("suite-org");
    let mut trace = Vec::new();

    // cold read: a typed ColdStart, never a fallback and never an alloc
    match client.recommend(JobRequest::sort(12.0)) {
        Err(ApiError::ColdStart {
            job: JobKind::Sort,
            records: 0,
            ..
        }) => {}
        other => panic!("cold recommend must be ColdStart, got {other:?}"),
    }

    // invalid requests are rejected at the boundary with the typed error
    match client.submit(&org, JobRequest::sort(10.0).with_target_seconds(-1.0)) {
        Err(ApiError::InvalidRequest(_)) => {}
        other => panic!("invalid target must be InvalidRequest, got {other:?}"),
    }

    // writes: share both corpora (Table-I order keeps the per-kind RNG
    // stream assignment identical across deployments)
    let sort_shared = client.share(corpus.repo_for(JobKind::Sort)).unwrap();
    assert!(sort_shared.added > 0);
    let grep_shared = client.share(corpus.repo_for(JobKind::Grep)).unwrap();
    assert!(grep_shared.added > 0);

    // the share trained the model: visible in the snapshot
    let info = client.snapshot_info(JobKind::Sort).unwrap();
    assert_eq!(info.records, sort_shared.added);
    assert_eq!(info.generation, sort_shared.generation);
    assert!(info.model.is_some(), "writes maintain the model");
    assert!(!info.observed_machines.is_empty());

    // read: recommend
    let request = JobRequest::sort(14.0).with_target_seconds(600.0);
    let rec = client.recommend(request.clone()).unwrap();
    trace.push(Fingerprint {
        step: "recommend-sort",
        machine: rec.choice.machine_type.clone(),
        scaleout: rec.choice.node_count,
        predicted_bits: rec.choice.predicted_runtime_s.to_bits(),
        actual_bits: 0,
    });

    // write: submit the same request — must decide exactly as the read
    let outcome = client.submit(&org, request).unwrap();
    assert_eq!(outcome.machine, rec.choice.machine_type);
    assert_eq!(outcome.scaleout, rec.choice.node_count);
    assert_eq!(
        outcome.predicted_runtime_s.to_bits(),
        rec.choice.predicted_runtime_s.to_bits(),
        "submit must decide exactly what recommend promised"
    );
    trace.push(Fingerprint {
        step: "submit-sort",
        machine: outcome.machine.clone(),
        scaleout: outcome.scaleout,
        predicted_bits: outcome.predicted_runtime_s.to_bits(),
        actual_bits: outcome.actual_runtime_s.to_bits(),
    });

    // write: record an externally-observed run
    let contribution = client.contribute(external_record()).unwrap();
    assert_eq!(contribution.added, 1);
    assert_eq!(contribution.generation, info.generation + 2, "submit + contribute");

    // write on the second shard
    let grep_req = JobRequest::grep(15.0, 0.1).with_target_seconds(500.0);
    let grep_outcome = client.submit(&org, grep_req).unwrap();
    assert!(grep_outcome.model_used.is_some());
    trace.push(Fingerprint {
        step: "submit-grep",
        machine: grep_outcome.machine.clone(),
        scaleout: grep_outcome.scaleout,
        predicted_bits: grep_outcome.predicted_runtime_s.to_bits(),
        actual_bits: grep_outcome.actual_runtime_s.to_bits(),
    });

    // federation reads: the op-log watermarks cover every contributing
    // org, and their seqnos sum to the repository size (every op here
    // was applied — no rejects, no replacements)
    let marks = client.watermarks(JobKind::Sort).unwrap();
    assert!(marks.watermarks.contains_key("external"));
    assert_eq!(
        marks.watermarks.values().map(|m| m.seqno).sum::<u64>(),
        (info.records + 2) as u64,
        "corpus + submitted run + external contribution"
    );
    // the legacy (v2) holdings view agrees record-for-record
    let marks_v2 = client.watermarks_v2(JobKind::Sort).unwrap();
    assert_eq!(
        marks_v2.watermarks.values().map(|m| m.count).sum::<u64>(),
        (info.records + 2) as u64
    );
    assert_eq!(marks_v2.watermarks["external"].count, 1);
    // a fresh peer (empty marks) pulls the whole op log as its delta
    let delta = client.sync_pull(JobKind::Sort, Default::default()).unwrap();
    assert_eq!(delta.ops.len(), info.records + 2);
    assert_eq!(delta.generation, marks.generation);
    assert_eq!(delta.watermarks, marks.watermarks);
    // ...and through the v2 compatibility translation too
    let delta_v2 = client
        .sync_pull_v2(JobKind::Sort, Default::default())
        .unwrap();
    assert_eq!(delta_v2.records.len(), info.records + 2);
    assert_eq!(delta_v2.watermarks, marks_v2.watermarks);
    // re-pushing an already-seen op is a no-op: the exchange is
    // idempotent and must not move the generation
    let external_op = delta
        .ops
        .iter()
        .find(|op| op.org == "external")
        .expect("external org in the delta")
        .clone();
    let report = client.sync_push(JobKind::Sort, vec![external_op]).unwrap();
    assert_eq!(report.changed(), 0);
    assert_eq!(report.skipped, 1, "a seen op is skipped, not re-applied");
    assert!(report.conflicts.is_empty());
    assert_eq!(report.generation, marks.generation);
    // the v2 push translation dedups identically
    let report_v2 = client
        .sync_push_v2(JobKind::Sort, vec![external_record()])
        .unwrap();
    assert_eq!(report_v2.changed(), 0);
    assert_eq!(report_v2.skipped, 1);
    assert_eq!(report_v2.generation, marks.generation);
    // neither push disturbed the watermarks
    assert_eq!(
        client.watermarks(JobKind::Sort).unwrap().watermarks,
        marks.watermarks
    );

    // metrics agree across deployments
    let m = client.metrics().unwrap();
    assert_eq!(m.submissions, 2);
    assert_eq!(m.recommends, 1);
    assert_eq!(m.contributions, 1);
    assert_eq!(m.retrains, 2, "one training per shared corpus");
    assert_eq!(m.cache_hits, 2, "both submissions decided from the cache");
    assert_eq!(m.fallbacks, 0);
    assert_eq!(m.sync_pushes, 2, "one v3 push, one v2-compat push");
    assert_eq!(m.sync_records_applied, 0, "the re-pushes applied nothing");

    trace
}

#[test]
fn pipelined_submit_bursts_match_sequential_deployments_bitwise() {
    // Write-side coalescing across deployment shapes: the same burst of
    // same-kind submits, served strictly sequentially by the
    // coordinator and the ordered session, and as a pre-scored coalesced
    // group by the concurrent service (the whole burst is pipelined
    // while the shard lock is held, so the single worker drains it into
    // one batch). All three traces must agree bit for bit — decisions
    // AND simulated runs.
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud);
    let no_artifacts = PathBuf::from("/nonexistent-artifacts");
    let org = Organization::new("burst-org");
    let requests: Vec<JobRequest> = (0..6)
        .map(|i| JobRequest::sort(10.0 + i as f64).with_target_seconds(900.0))
        .collect();
    let fingerprint = |o: &c3o::coordinator::JobOutcome| Fingerprint {
        step: "burst-sort",
        machine: o.machine.clone(),
        scaleout: o.scaleout,
        predicted_bits: o.predicted_runtime_s.to_bits(),
        actual_bits: o.actual_runtime_s.to_bits(),
    };

    // 1) the sequential coordinator
    let mut coordinator = Coordinator::with_engine(cloud.clone(), Engine::native(), SEED);
    Client::share(&mut coordinator, corpus.repo_for(JobKind::Sort)).unwrap();
    let coordinator_trace: Vec<Fingerprint> = requests
        .iter()
        .map(|r| {
            let o = Client::submit(&mut coordinator, &org, r.clone()).unwrap();
            assert!(o.model_used.is_some(), "burst must be model-served");
            fingerprint(&o)
        })
        .collect();

    // 2) the ordered single-worker session
    let session = Session::spawn(cloud.clone(), no_artifacts.clone(), SEED);
    let mut session_ref = &session;
    Client::share(&mut session_ref, corpus.repo_for(JobKind::Sort)).unwrap();
    let session_trace: Vec<Fingerprint> = requests
        .iter()
        .map(|r| fingerprint(&Client::submit(&mut session_ref, &org, r.clone()).unwrap()))
        .collect();
    session.shutdown();

    // 3) the concurrent service, burst pipelined into a coalesced group
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default()
            .with_workers(1)
            .with_pjrt_workers(0)
            .with_artifacts_dir(no_artifacts)
            .with_seed(SEED),
    );
    service.share(corpus.repo_for(JobKind::Sort)).unwrap();
    let guard = service.hold_shard_for_tests(JobKind::Sort);
    let client = service.client();
    let tickets: Vec<_> = requests
        .iter()
        .map(|r| client.submit_nowait(&org, r.clone()).unwrap())
        .collect();
    drop(guard);
    let service_trace: Vec<Fingerprint> = tickets
        .into_iter()
        .map(|t| fingerprint(&t.wait().unwrap()))
        .collect();
    assert!(
        service.metrics().unwrap().coalesced_write_batches >= 1,
        "the pipelined burst must have been pre-scored as one batch"
    );
    service.shutdown();

    assert_eq!(
        coordinator_trace, session_trace,
        "session burst must match the sequential coordinator bit for bit"
    );
    assert_eq!(
        coordinator_trace, service_trace,
        "coalesced service burst must match the sequential coordinator bit for bit"
    );
}

#[test]
fn all_three_deployments_serve_identical_decisions() {
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud);
    let no_artifacts = PathBuf::from("/nonexistent-artifacts");

    // 1) the sequential coordinator
    let mut coordinator = Coordinator::with_engine(cloud.clone(), Engine::native(), SEED);
    let coordinator_trace = scenario(&mut coordinator, &corpus);

    // 2) the ordered single-worker session (native: bogus artifacts dir)
    let session = Session::spawn(cloud.clone(), no_artifacts.clone(), SEED);
    let mut session_ref = &session;
    let session_trace = scenario(&mut session_ref, &corpus);
    session.shutdown();

    // 3) the concurrent service (native workers)
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default()
            .with_workers(2)
            .with_pjrt_workers(0)
            .with_artifacts_dir(no_artifacts)
            .with_seed(SEED),
    );
    let mut client = service.client();
    let service_trace = scenario(&mut client, &corpus);
    service.shutdown();

    assert_eq!(
        coordinator_trace, session_trace,
        "session must match the sequential coordinator bit for bit"
    );
    assert_eq!(
        coordinator_trace, service_trace,
        "service must match the sequential coordinator bit for bit"
    );
}

#[test]
fn tracing_is_behaviorally_inert_across_deployments() {
    // The observability layer must never leak into decisions: the full
    // protocol scenario served with span tracing enabled and disabled
    // produces bitwise-identical traces, both equal to the sequential
    // coordinator's. Only the side channel differs — the traced service
    // has captured spans, the untraced one has captured nothing.
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud);
    let no_artifacts = PathBuf::from("/nonexistent-artifacts");

    let mut coordinator = Coordinator::with_engine(cloud.clone(), Engine::native(), SEED);
    let coordinator_trace = scenario(&mut coordinator, &corpus);

    let mut traces = Vec::new();
    for tracing in [true, false] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(2)
                .with_pjrt_workers(0)
                .with_artifacts_dir(no_artifacts.clone())
                .with_seed(SEED)
                .with_tracing(tracing),
        );
        let mut client = service.client();
        traces.push(scenario(&mut client, &corpus));

        let report = service.obs_report();
        if tracing {
            assert!(report.drained > 0, "enabled tracing must capture spans");
            assert!(!report.is_empty(), "enabled tracing must fill histograms");
        } else {
            assert_eq!(report.drained, 0, "disabled tracing must capture nothing");
            assert!(report.is_empty(), "disabled tracing must record no latency");
        }
        service.shutdown();
    }

    assert_eq!(
        traces[0], coordinator_trace,
        "traced service must match the sequential coordinator bit for bit"
    );
    assert_eq!(
        traces[1], coordinator_trace,
        "untraced service must match the sequential coordinator bit for bit"
    );
}

#[test]
fn compute_pool_is_behaviorally_inert_across_deployments() {
    // The shared compute pool must only change *when* retrain and
    // batch-scoring work runs, never what it computes: the full protocol
    // scenario served with the pool enabled and disabled produces
    // bitwise-identical decision traces, both equal to the sequential
    // (always-serial) coordinator's.
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud);
    let no_artifacts = PathBuf::from("/nonexistent-artifacts");

    let mut coordinator = Coordinator::with_engine(cloud.clone(), Engine::native(), SEED);
    let coordinator_trace = scenario(&mut coordinator, &corpus);

    for pool in [true, false] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(2)
                .with_pjrt_workers(0)
                .with_artifacts_dir(no_artifacts.clone())
                .with_seed(SEED)
                .with_compute_pool(pool),
        );
        let mut client = service.client();
        let trace = scenario(&mut client, &corpus);
        service.shutdown();
        assert_eq!(
            trace, coordinator_trace,
            "compute_pool={pool} deployment must match the sequential \
             coordinator bit for bit"
        );
    }
}
