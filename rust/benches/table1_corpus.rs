//! Bench: regenerate Table I (the 930-experiment corpus) and measure
//! corpus-generation throughput (the substrate's §Perf number).

use c3o::cloud::Cloud;
use c3o::figures;
use c3o::util::bench::{black_box, Bench};
use c3o::workloads::ExperimentGrid;

fn main() {
    let cloud = Cloud::aws_like();

    // --- reproduction: Table I -----------------------------------------
    let fig = figures::table1(&cloud, 42);
    println!("{}", fig.render());
    assert!(fig.all_claims_hold(), "Table I reproduction failed");

    // --- perf: grid execution throughput ---------------------------------
    let mut b = Bench::new("table1_corpus");
    let grid = ExperimentGrid::paper_table1();
    b.annotate("experiments", "930");
    b.annotate("repetitions", "5");
    b.run("full_930_grid_5reps", || {
        black_box(grid.execute(&cloud, 42).len())
    });
    let single = ExperimentGrid {
        experiments: grid.experiments[..1].to_vec(),
        repetitions: 1,
    };
    b.run("single_experiment", || {
        black_box(single.execute(&cloud, 42).len())
    });
    b.finish();
}
