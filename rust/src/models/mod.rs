//! Runtime prediction models (paper §V).
//!
//! Two model families over collaboratively shared runtime data:
//!
//! * **Pessimistic** ([`ModelKind::Pessimistic`]) — similarity-based:
//!   predictions are inverse-distance-weighted means of the most similar
//!   historical executions, with each feature's distance scaled by its
//!   correlation with the runtime (§V-A). Strong interpolation; robust to
//!   feature interdependence; needs nearby training points.
//! * **Optimistic** ([`ModelKind::Optimistic`]) — factorized: assumes
//!   features influence runtime independently (§V-B), learning one small
//!   basis (linear/log/reciprocal) per feature in log-runtime space.
//!   Parameter count linear in feature count, so it trains on sparse data
//!   and extrapolates (e.g. to unseen scale-outs).
//!
//! Both models execute as AOT-compiled XLA artifacts through
//! [`crate::runtime::Runtime`]: the pessimistic hot path is the Pallas
//! distance kernel (L1); the optimistic training step is a fused
//! Adam-on-MSE graph (L2). [`native`] holds bit-compatible pure-Rust
//! re-implementations used for differential testing and as a fallback,
//! and [`selection`] implements the paper's dynamic cross-validation
//! model choice (§V-C).
//!
//! ## Training cost: featurize once, retrain on deltas
//!
//! Training is dominated by assembling its inputs, not by the model
//! math: featurizing the corpus, standardizing columns, and (for the
//! kNN family) padding rows to the fixed kernel layout. Every trainer
//! therefore exposes two entry points: [`ModelTrainer::train`]
//! featurizes from scratch, while [`ModelTrainer::train_cached`]
//! accepts an incrementally maintained
//! [`FeatureMatrixCache`](crate::repo::FeatureMatrixCache) whose raw
//! rows were kept up to date by the repository's delta journal — so a
//! steady-state retrain re-featurizes only the records that changed
//! since the previous fit, and skips re-padding kNN rows entirely when
//! only targets changed. The cache feeds byte-identical matrices
//! through the same fit code, so both entry points produce bitwise
//! identical models; cross-validated selection
//! ([`selection::select_and_train_cached`]) trains its per-fold
//! sub-repos from scratch and hands the cache only to the winning
//! full-corpus fit.

pub mod native;
pub mod oracle;
pub mod selection;

use crate::cloud::Cloud;
use crate::repo::featurize::{FeatureMatrixCache, FeatureSpace, Featurizer};
use crate::repo::RuntimeDataRepo;
use crate::runtime::Runtime;
use crate::util::matrix::MatF32;
use crate::util::rng::Pcg32;
use crate::util::stats;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which model family (paper §V-A vs §V-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Pessimistic,
    Optimistic,
}

impl ModelKind {
    pub fn all() -> [ModelKind; 2] {
        [ModelKind::Pessimistic, ModelKind::Optimistic]
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Pessimistic => "pessimistic",
            ModelKind::Optimistic => "optimistic",
        }
    }
}

/// A prediction query: one candidate cluster configuration for a job.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigQuery {
    pub machine: String,
    pub scaleout: u32,
    /// Job features aligned with `JobKind::feature_names()`.
    pub job_features: Vec<f64>,
}

/// A set of candidate configurations for **one** job, featurized once
/// into a single raw-feature matrix.
///
/// The configurator's hot path scores every `machine × scaleout`
/// candidate of a request. Building a [`ConfigQuery`] per candidate and
/// re-deriving its feature row inside every model was the dominant
/// per-request cost; a `QueryBatch` resolves machine descriptors and job
/// features exactly once, and models score straight off `raw` (each
/// model applies its own scaling vectorized). The exact `f64` job
/// features are retained so consumers that need full precision (e.g. the
/// simulator-backed oracle) can reconstruct per-candidate queries.
#[derive(Debug, Clone)]
pub struct QueryBatch {
    /// Job features shared by every candidate row (full `f64` precision).
    pub job_features: Vec<f64>,
    /// Per-row machine type name.
    pub machines: Vec<String>,
    /// Per-row scale-out.
    pub scaleouts: Vec<u32>,
    /// `[n × (job features + cluster descriptors)]` raw feature rows, in
    /// the layout [`crate::repo::featurize::Featurizer::raw_row`] emits.
    pub raw: MatF32,
}

impl QueryBatch {
    /// Featurize `(machine, scaleout)` candidates for one job in a single
    /// pass over the catalog.
    ///
    /// # Panics
    /// Panics if a machine type is not in the catalog (same contract as
    /// [`crate::repo::featurize::Featurizer::raw_row`]).
    pub fn from_candidates(
        cloud: &Cloud,
        candidates: &[(String, u32)],
        job_features: &[f64],
    ) -> QueryBatch {
        let featurizer = Featurizer::new(cloud);
        let rows: Vec<Vec<f32>> = candidates
            .iter()
            .map(|(m, n)| featurizer.raw_row(m, *n, job_features))
            .collect();
        QueryBatch {
            job_features: job_features.to_vec(),
            machines: candidates.iter().map(|(m, _)| m.clone()).collect(),
            scaleouts: candidates.iter().map(|(_, n)| *n).collect(),
            raw: MatF32::from_rows(&rows),
        }
    }

    /// Concatenate several batches (same column layout) into one matrix
    /// for a single `predict_batch` execution — the service's
    /// cross-request coalescing path: candidates of multiple same-kind
    /// requests scored in one call.
    ///
    /// The batches may carry **different job features** (they share only
    /// the column layout), so the concatenated batch is only valid for
    /// backends that score `raw` directly. Both production backends
    /// ([`Predictor`] and [`native::NativeEngine`]) do; the
    /// [`QueryBatch::queries`] compatibility reconstruction is *not*
    /// meaningful on a concatenated batch and must not be used on one.
    ///
    /// # Panics
    /// Panics on an empty batch list or mismatched column counts.
    pub fn concat(batches: &[QueryBatch]) -> QueryBatch {
        assert!(!batches.is_empty(), "cannot concat zero batches");
        let cols = batches[0].raw.cols;
        let rows: usize = batches.iter().map(|b| b.raw.rows).sum();
        let mut raw = MatF32::zeros(rows, cols);
        let mut machines = Vec::with_capacity(rows);
        let mut scaleouts = Vec::with_capacity(rows);
        let mut r0 = 0;
        for b in batches {
            assert_eq!(b.raw.cols, cols, "mismatched feature layouts");
            for r in 0..b.raw.rows {
                raw.row_mut(r0 + r).copy_from_slice(b.raw.row(r));
            }
            machines.extend(b.machines.iter().cloned());
            scaleouts.extend(b.scaleouts.iter().copied());
            r0 += b.raw.rows;
        }
        QueryBatch {
            job_features: batches[0].job_features.clone(),
            machines,
            scaleouts,
            raw,
        }
    }

    pub fn len(&self) -> usize {
        self.machines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.machines.is_empty()
    }

    /// Reconstruct per-candidate queries (full-precision job features) —
    /// the compatibility path for models without a native batch
    /// implementation.
    pub fn queries(&self) -> Vec<ConfigQuery> {
        self.machines
            .iter()
            .zip(&self.scaleouts)
            .map(|(m, &n)| ConfigQuery {
                machine: m.clone(),
                scaleout: n,
                job_features: self.job_features.clone(),
            })
            .collect()
    }
}

/// Anything that can predict runtimes for configuration queries.
/// Implemented by [`Predictor`]+[`TrainedModel`] (the PJRT path), the
/// [`native`] fallbacks, and the simulator-backed [`oracle::SimOracle`]
/// used to measure regret in benches.
pub trait RuntimeModel {
    /// Predicted runtime in seconds for each query.
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>>;

    /// Predicted runtime for each row of a pre-featurized candidate
    /// batch. Models that can score the raw matrix directly override
    /// this; the default reconstructs per-candidate queries so every
    /// implementation stays correct.
    fn predict_batch(&mut self, cloud: &Cloud, batch: &QueryBatch) -> Result<Vec<f64>> {
        self.predict(cloud, &batch.queries())
    }
}

/// A training/serving backend for both model families: the PJRT-backed
/// [`Predictor`], the pure-Rust [`native::NativeEngine`], or the
/// [`Engine`] that picks between them. The coordinator layer talks to
/// models exclusively through this trait, so every deployment shape
/// (single-owner session, sharded multi-worker service) works with or
/// without compiled PJRT artifacts.
pub trait ModelTrainer {
    /// Human-readable backend name (`"pjrt"` / `"native"`).
    fn backend(&self) -> &'static str;

    /// Maximum kNN training rows this backend supports; repositories
    /// beyond it must be coverage-sampled (§III-C).
    fn knn_capacity(&self) -> usize;

    /// Train a model of the requested kind on a shared repository.
    fn train(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        kind: ModelKind,
    ) -> Result<TrainedModel> {
        self.train_cached(cloud, repo, kind, None)
    }

    /// Train like [`ModelTrainer::train`], optionally consuming an
    /// incremental [`FeatureMatrixCache`] already refreshed to `repo`'s
    /// journal position. The cached path skips per-record
    /// refeaturization (and re-padding of unchanged KNN rows) while
    /// producing bitwise-identical models; passing `None` is the
    /// from-scratch path.
    fn train_cached(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        kind: ModelKind,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel>;

    /// Predict runtimes (seconds) for a batch of queries.
    fn predict(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>>;

    /// Predict runtimes for a pre-featurized candidate batch in one call.
    fn predict_batch(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        batch: &QueryBatch,
    ) -> Result<Vec<f64>>;

    /// A `Send`-able native clone of this backend for fan-out across a
    /// [`crate::compute::ComputePool`], or `None` when the backend is
    /// thread-pinned (PJRT's client is not `Send`). A forked engine
    /// trains bitwise-identically to its parent: the native backend is
    /// pure configuration, so clones share no mutable state.
    fn fork_native(&self) -> Option<native::NativeEngine> {
        None
    }
}

/// Trained state for either model family.
#[derive(Debug, Clone)]
pub enum ModelState {
    Knn {
        space: FeatureSpace,
        /// [KNN_T × F] padded standardized training features.
        train_x: MatF32,
        train_y: Vec<f32>,
        valid: Vec<f32>,
        /// [F] per-feature |correlation with log-runtime| (padded cols 0).
        weights: Vec<f32>,
    },
    Opt {
        /// Per-column min and span for the [0,1] scaling the basis expects.
        mins: Vec<f32>,
        spans: Vec<f32>,
        y_mean: f32,
        y_sd: f32,
        /// [OPT_PARAMS] trained coefficients.
        params: Vec<f32>,
        /// Final training loss (observability).
        final_loss: f32,
        /// Column names (diagnostics).
        names: Vec<String>,
    },
}

/// A trained model, ready to answer [`ConfigQuery`]s through a
/// [`Predictor`].
#[derive(Debug, Clone)]
pub struct TrainedModel {
    pub kind: ModelKind,
    pub state: ModelState,
    /// Globally unique id, used to key the predictor's device-resident
    /// buffer cache (§Perf).
    pub id: u64,
}

pub(crate) fn next_model_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Fit the pessimistic model's state on a repository: standardize, learn
/// per-feature |correlation| relevance weights, and pad to the fixed
/// `(rows_cap × dim_cap)` layout both the PJRT artifacts and the native
/// scorer consume. Shared by [`Predictor::train_pessimistic`] and
/// [`native::NativeEngine`] so the two backends produce interchangeable
/// [`ModelState::Knn`] values (a model trained on one backend's worker
/// can be served by another's).
pub(crate) fn fit_knn_state(
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    rows_cap: usize,
    dim_cap: usize,
    feat: Option<&mut FeatureMatrixCache>,
) -> Result<ModelState> {
    if repo.is_empty() {
        bail!("cannot train on an empty repository");
    }
    if repo.len() > rows_cap {
        bail!(
            "repo has {} records, backend supports {} (use repo::sampling)",
            repo.len(),
            rows_cap
        );
    }
    // With a refreshed feature cache the fit is a standardization pass
    // over pre-built matrices, and the padded KNN block is memoized —
    // bitwise-identical to the from-scratch path either way, because
    // both run the same featurize helpers over the same raw bits.
    let mut cached_pad: Option<MatF32> = None;
    let (space, x, y) = match feat {
        Some(cache) => {
            let (space, x, y) = cache.fit(repo);
            if space.dim() > dim_cap {
                bail!("feature dim {} exceeds backend feature dim {dim_cap}", space.dim());
            }
            cached_pad = Some(cache.padded_x(rows_cap, dim_cap).clone());
            (space, x, y)
        }
        None => Featurizer::new(cloud).fit(repo),
    };
    let d = space.dim();
    if d > dim_cap {
        bail!("feature dim {d} exceeds backend feature dim {dim_cap}");
    }

    // weights: |corr(feature, y)| over the standardized data
    let mut weights = vec![0.0f32; dim_cap];
    let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
    for c in 0..d {
        let col: Vec<f64> = (0..x.rows).map(|r| x.at(r, c) as f64).collect();
        let corr = stats::pearson(&col, &yf);
        weights[c] = if corr.is_finite() { corr.abs() as f32 } else { 0.0 };
    }
    // Floor so no observed feature is fully ignored (a zero-corr
    // feature can still matter jointly).
    for w in weights.iter_mut().take(d) {
        *w = w.max(0.05);
    }

    // pad rows to rows_cap and cols to dim_cap (the x block comes
    // pre-padded from the cache when one was supplied)
    let train_x = match cached_pad {
        Some(px) => px,
        None => {
            let mut train_x = MatF32::zeros(rows_cap, dim_cap);
            for r in 0..x.rows {
                train_x.row_mut(r)[..d].copy_from_slice(x.row(r));
            }
            train_x
        }
    };
    let mut train_y = vec![0.0f32; rows_cap];
    let mut valid = vec![0.0f32; rows_cap];
    for r in 0..x.rows {
        train_y[r] = y[r];
        valid[r] = 1.0;
    }

    Ok(ModelState::Knn {
        space,
        train_x,
        train_y,
        valid,
        weights,
    })
}

/// Training hyper-parameters for the optimistic model.
#[derive(Debug, Clone)]
pub struct OptTrainConfig {
    pub max_steps: u32,
    pub lr: f32,
    /// Stop when the best loss hasn't improved by `tol` for `patience`
    /// steps.
    pub patience: u32,
    pub tol: f32,
    pub shuffle_seed: u64,
}

impl Default for OptTrainConfig {
    fn default() -> Self {
        OptTrainConfig {
            max_steps: 600,
            lr: 0.05,
            patience: 80,
            tol: 1e-5,
            shuffle_seed: 0xC30,
        }
    }
}

/// Device-resident kNN training set (constant across predict calls for
/// a given trained model — uploading it once is the single biggest
/// §Perf win on the predict path).
struct KnnDeviceCache {
    model_id: u64,
    train_x: xla::PjRtBuffer,
    train_y: xla::PjRtBuffer,
    valid: xla::PjRtBuffer,
    weights: xla::PjRtBuffer,
}

/// Device-resident optimistic parameters.
struct OptDeviceCache {
    model_id: u64,
    params: xla::PjRtBuffer,
}

/// The PJRT-backed predictor: owns the runtime, trains and serves both
/// model families.
pub struct Predictor {
    runtime: Runtime,
    knn_cache: Option<KnnDeviceCache>,
    opt_cache: Option<OptDeviceCache>,
}

impl Predictor {
    /// Load from an artifacts directory and pre-compile all executables.
    pub fn new(artifacts_dir: &Path) -> Result<Predictor> {
        let mut runtime = Runtime::load(artifacts_dir)?;
        runtime.warmup()?;
        Ok(Predictor {
            runtime,
            knn_cache: None,
            opt_cache: None,
        })
    }

    /// Load from the default artifacts directory.
    pub fn from_default_artifacts() -> Result<Predictor> {
        Predictor::new(&Runtime::default_dir())
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Train a model of the requested kind on a shared repository.
    pub fn train(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        kind: ModelKind,
    ) -> Result<TrainedModel> {
        match kind {
            ModelKind::Pessimistic => self.train_pessimistic(cloud, repo, None),
            ModelKind::Optimistic => {
                self.train_optimistic(cloud, repo, &OptTrainConfig::default(), None)
            }
        }
    }

    // --- pessimistic -------------------------------------------------------

    /// "Training" the pessimistic model = standardizing the shared data
    /// and learning per-feature relevance weights (|Pearson correlation|
    /// of each feature with log-runtime — the paper's "scaling each
    /// feature's relative distance by that feature's correlation with the
    /// runtime").
    pub fn train_pessimistic(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        let man = self.runtime.manifest().clone();
        let state = fit_knn_state(cloud, repo, man.knn_train_rows, man.feature_dim, feat)?;
        Ok(TrainedModel {
            kind: ModelKind::Pessimistic,
            id: next_model_id(),
            state,
        })
    }

    // --- optimistic --------------------------------------------------------

    /// Train the factorized model with mini-batch Adam, the epoch loop in
    /// Rust, each step one PJRT execution of the fused train graph.
    pub fn train_optimistic(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        cfg: &OptTrainConfig,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        let man = self.runtime.manifest().clone();
        if repo.is_empty() {
            bail!("cannot train on an empty repository");
        }
        // The cache's raw rows and log targets are bitwise what the
        // from-scratch loops below would produce, so every downstream
        // float lands on identical bits.
        let owned: Option<(Vec<Vec<f32>>, Vec<f32>)>;
        let (raw, log_y): (&[Vec<f32>], &[f32]) = match feat {
            Some(cache) => {
                assert!(cache.is_fresh(repo), "feature cache is stale: refresh() before train");
                (cache.raw_rows(), cache.log_y())
            }
            None => {
                let featurizer = Featurizer::new(cloud);
                owned = Some((
                    repo.records()
                        .iter()
                        .map(|r| featurizer.raw_row(&r.machine, r.scaleout, &r.job_features))
                        .collect(),
                    repo.records()
                        .iter()
                        .map(|r| r.runtime_s.ln() as f32)
                        .collect(),
                ));
                let (raw, log_y) = owned.as_ref().expect("just set");
                (raw, log_y)
            }
        };
        let d = raw[0].len();
        if d > man.feature_dim {
            bail!("feature dim {d} exceeds artifact feature dim {}", man.feature_dim);
        }
        let n = raw.len();

        // min-max scaling to [0, 1] (the basis domain)
        let mut mins = vec![f32::INFINITY; man.feature_dim];
        let mut maxs = vec![f32::NEG_INFINITY; man.feature_dim];
        for row in raw {
            for c in 0..d {
                mins[c] = mins[c].min(row[c]);
                maxs[c] = maxs[c].max(row[c]);
            }
        }
        let mut spans = vec![1.0f32; man.feature_dim];
        for c in 0..d {
            spans[c] = (maxs[c] - mins[c]).max(1e-6);
        }
        for c in d..man.feature_dim {
            mins[c] = 0.0;
            spans[c] = 1.0;
        }

        // standardized log target
        // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
        let y_mean = log_y.iter().sum::<f32>() / n as f32;
        // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
        let y_sd = (log_y.iter().map(|v| (v - y_mean).powi(2)).sum::<f32>() / n as f32)
            .sqrt()
            .max(1e-6);

        // scaled full dataset
        let mut x01 = MatF32::zeros(n, man.feature_dim);
        let mut y = vec![0.0f32; n];
        for (r, row) in raw.iter().enumerate() {
            for c in 0..d {
                x01.set(r, c, (row[c] - mins[c]) / spans[c]);
            }
            y[r] = (log_y[r] - y_mean) / y_sd;
        }

        // mini-batch loop
        let b = man.opt_batch;
        let mut params = vec![0.0f32; man.opt_params];
        let mut m = vec![0.0f32; man.opt_params];
        let mut v = vec![0.0f32; man.opt_params];
        let mut rng = Pcg32::new(cfg.shuffle_seed);
        let mut order: Vec<usize> = (0..n).collect();
        let mut best = f32::INFINITY;
        let mut since_best = 0u32;
        let mut final_loss = f32::INFINITY;
        let mut step = 0u32;
        'train: loop {
            rng.shuffle(&mut order);
            for chunk in order.chunks(b) {
                step += 1;
                if step > cfg.max_steps {
                    break 'train;
                }
                let mut bx = MatF32::zeros(b, man.feature_dim);
                let mut by = vec![0.0f32; b];
                let mut mask = vec![0.0f32; b];
                for (i, &r) in chunk.iter().enumerate() {
                    bx.row_mut(i).copy_from_slice(x01.row(r));
                    by[i] = y[r];
                    mask[i] = 1.0;
                }
                let out = self.runtime.execute(
                    "optimistic_train",
                    &[
                        Runtime::lit_vec(&params),
                        Runtime::lit_vec(&m),
                        Runtime::lit_vec(&v),
                        Runtime::lit_scalar(step as f32),
                        Runtime::lit_mat(&bx)?,
                        Runtime::lit_vec(&by),
                        Runtime::lit_vec(&mask),
                        Runtime::lit_scalar(cfg.lr),
                    ],
                )?;
                params = Runtime::vec_from(&out[0])?;
                m = Runtime::vec_from(&out[1])?;
                v = Runtime::vec_from(&out[2])?;
                final_loss = Runtime::vec_from(&out[3])?[0];
                if final_loss < best - cfg.tol {
                    best = final_loss;
                    since_best = 0;
                } else {
                    since_best += 1;
                    if since_best >= cfg.patience {
                        break 'train;
                    }
                }
            }
        }

        let names = {
            let mut names: Vec<String> = repo
                .job()
                .feature_names()
                .iter()
                .map(|s| s.to_string())
                .collect();
            names.extend(
                crate::repo::featurize::CLUSTER_FEATURES
                    .iter()
                    .map(|s| s.to_string()),
            );
            names
        };

        Ok(TrainedModel {
            kind: ModelKind::Optimistic,
            id: next_model_id(),
            state: ModelState::Opt {
                mins,
                spans,
                y_mean,
                y_sd,
                params,
                final_loss,
                names,
            },
        })
    }

    // --- prediction --------------------------------------------------------

    /// Predict runtimes (seconds) for a batch of queries with a trained
    /// model. Queries are chunked to the artifact batch sizes. The
    /// model's constant inputs (kNN training set / optimistic parameters)
    /// are uploaded to the device once and cached by model id (§Perf).
    pub fn predict(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>> {
        match &model.state {
            ModelState::Knn {
                space,
                train_x,
                train_y,
                valid,
                weights,
            } => {
                // refresh the device cache if a different model is bound
                if self.knn_cache.as_ref().map(|c| c.model_id) != Some(model.id) {
                    self.knn_cache = Some(KnnDeviceCache {
                        model_id: model.id,
                        train_x: self.runtime.buffer_mat(train_x)?,
                        train_y: self.runtime.buffer_vec(train_y)?,
                        valid: self.runtime.buffer_vec(valid)?,
                        weights: self.runtime.buffer_vec(weights)?,
                    });
                }
                self.predict_knn(cloud, space, queries)
            }
            ModelState::Opt {
                mins,
                spans,
                y_mean,
                y_sd,
                params,
                ..
            } => {
                if self.opt_cache.as_ref().map(|c| c.model_id) != Some(model.id) {
                    self.opt_cache = Some(OptDeviceCache {
                        model_id: model.id,
                        params: self.runtime.buffer_vec(params)?,
                    });
                }
                self.predict_opt(cloud, mins, spans, *y_mean, *y_sd, queries)
            }
        }
    }

    fn predict_knn(
        &mut self,
        cloud: &Cloud,
        space: &FeatureSpace,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>> {
        let man = self.runtime.manifest().clone();
        let featurizer = Featurizer::new(cloud);
        let d = space.dim();
        let mut out = Vec::with_capacity(queries.len());
        // reuse one query-staging matrix across chunks
        let mut q = MatF32::zeros(man.knn_query_rows, man.feature_dim);
        for chunk in queries.chunks(man.knn_query_rows) {
            q.data.fill(0.0);
            for (i, query) in chunk.iter().enumerate() {
                let row =
                    featurizer.transform(space, &query.machine, query.scaleout, &query.job_features);
                q.row_mut(i)[..d].copy_from_slice(&row);
            }
            let qbuf = self.runtime.buffer_mat(&q)?;
            let cache = self.knn_cache.as_ref().expect("cache ensured by predict");
            let inputs = [
                &cache.train_x,
                &cache.train_y,
                &cache.valid,
                &cache.weights,
                &qbuf,
            ];
            let result = self
                .runtime
                .execute_buffers("knn_predict", &inputs)
                .context("knn_predict execution")?;
            let preds = Runtime::vec_from(&result[0])?;
            for (i, _) in chunk.iter().enumerate() {
                out.push(space.unscale_runtime(preds[i]));
            }
        }
        Ok(out)
    }

    #[allow(clippy::too_many_arguments)]
    fn predict_opt(
        &mut self,
        cloud: &Cloud,
        mins: &[f32],
        spans: &[f32],
        y_mean: f32,
        y_sd: f32,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>> {
        let man = self.runtime.manifest().clone();
        let featurizer = Featurizer::new(cloud);
        let mut out = Vec::with_capacity(queries.len());
        let mut x = MatF32::zeros(man.opt_batch, man.feature_dim);
        for chunk in queries.chunks(man.opt_batch) {
            x.data.fill(0.0);
            for (i, query) in chunk.iter().enumerate() {
                let raw = featurizer.raw_row(&query.machine, query.scaleout, &query.job_features);
                for (c, &rv) in raw.iter().enumerate() {
                    // clamp below 0 so the reciprocal basis stays finite;
                    // above 1 extrapolation is intentional
                    x.set(i, c, (((rv - mins[c]) / spans[c]).max(-0.05)).min(5.0));
                }
            }
            let xbuf = self.runtime.buffer_mat(&x)?;
            let cache = self.opt_cache.as_ref().expect("cache ensured by predict");
            let inputs = [&cache.params, &xbuf];
            let result = self
                .runtime
                .execute_buffers("optimistic_predict", &inputs)
                .context("optimistic_predict execution")?;
            let preds = Runtime::vec_from(&result[0])?;
            for (i, _) in chunk.iter().enumerate() {
                out.push(((preds[i] * y_sd + y_mean) as f64).exp());
            }
        }
        Ok(out)
    }

    // --- batched prediction over pre-featurized candidates ------------------

    /// Predict runtimes for a [`QueryBatch`] whose raw feature matrix was
    /// built once by the configurator. Skips all per-candidate row
    /// building: each chunk is scaled straight from `batch.raw` into the
    /// staging matrix and executed. Bitwise-identical to calling
    /// [`Predictor::predict`] on the equivalent query list (same scaling
    /// ops, same chunk boundaries).
    pub fn predict_batch(
        &mut self,
        model: &TrainedModel,
        _cloud: &Cloud,
        batch: &QueryBatch,
    ) -> Result<Vec<f64>> {
        match &model.state {
            ModelState::Knn {
                space,
                train_x,
                train_y,
                valid,
                weights,
            } => {
                if self.knn_cache.as_ref().map(|c| c.model_id) != Some(model.id) {
                    self.knn_cache = Some(KnnDeviceCache {
                        model_id: model.id,
                        train_x: self.runtime.buffer_mat(train_x)?,
                        train_y: self.runtime.buffer_vec(train_y)?,
                        valid: self.runtime.buffer_vec(valid)?,
                        weights: self.runtime.buffer_vec(weights)?,
                    });
                }
                self.predict_knn_raw(space, &batch.raw)
            }
            ModelState::Opt {
                mins,
                spans,
                y_mean,
                y_sd,
                params,
                ..
            } => {
                if self.opt_cache.as_ref().map(|c| c.model_id) != Some(model.id) {
                    self.opt_cache = Some(OptDeviceCache {
                        model_id: model.id,
                        params: self.runtime.buffer_vec(params)?,
                    });
                }
                self.predict_opt_raw(mins, spans, *y_mean, *y_sd, &batch.raw)
            }
        }
    }

    fn predict_knn_raw(&mut self, space: &FeatureSpace, raw: &MatF32) -> Result<Vec<f64>> {
        let man = self.runtime.manifest().clone();
        let d = space.dim();
        debug_assert_eq!(raw.cols, d, "raw row layout must match feature space");
        let mut out = Vec::with_capacity(raw.rows);
        let mut q = MatF32::zeros(man.knn_query_rows, man.feature_dim);
        let mut r0 = 0;
        while r0 < raw.rows {
            let chunk = (raw.rows - r0).min(man.knn_query_rows);
            q.data.fill(0.0);
            for i in 0..chunk {
                let src = raw.row(r0 + i);
                let dst = q.row_mut(i);
                for c in 0..d {
                    dst[c] = (src[c] - space.mean[c]) / space.sd[c];
                }
            }
            let qbuf = self.runtime.buffer_mat(&q)?;
            let cache = self.knn_cache.as_ref().expect("cache ensured by predict_batch");
            let inputs = [
                &cache.train_x,
                &cache.train_y,
                &cache.valid,
                &cache.weights,
                &qbuf,
            ];
            let result = self
                .runtime
                .execute_buffers("knn_predict", &inputs)
                .context("knn_predict execution")?;
            let preds = Runtime::vec_from(&result[0])?;
            for p in preds.iter().take(chunk) {
                out.push(space.unscale_runtime(*p));
            }
            r0 += chunk;
        }
        Ok(out)
    }

    fn predict_opt_raw(
        &mut self,
        mins: &[f32],
        spans: &[f32],
        y_mean: f32,
        y_sd: f32,
        raw: &MatF32,
    ) -> Result<Vec<f64>> {
        let man = self.runtime.manifest().clone();
        let mut out = Vec::with_capacity(raw.rows);
        let mut x = MatF32::zeros(man.opt_batch, man.feature_dim);
        let mut r0 = 0;
        while r0 < raw.rows {
            let chunk = (raw.rows - r0).min(man.opt_batch);
            x.data.fill(0.0);
            for i in 0..chunk {
                let src = raw.row(r0 + i);
                for (c, &rv) in src.iter().enumerate() {
                    // clamp below 0 so the reciprocal basis stays finite;
                    // above 1 extrapolation is intentional
                    x.set(i, c, (((rv - mins[c]) / spans[c]).max(-0.05)).min(5.0));
                }
            }
            let xbuf = self.runtime.buffer_mat(&x)?;
            let cache = self.opt_cache.as_ref().expect("cache ensured by predict_batch");
            let inputs = [&cache.params, &xbuf];
            let result = self
                .runtime
                .execute_buffers("optimistic_predict", &inputs)
                .context("optimistic_predict execution")?;
            let preds = Runtime::vec_from(&result[0])?;
            for p in preds.iter().take(chunk) {
                out.push(((*p * y_sd + y_mean) as f64).exp());
            }
            r0 += chunk;
        }
        Ok(out)
    }
}

impl ModelTrainer for Predictor {
    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn knn_capacity(&self) -> usize {
        self.runtime.manifest().knn_train_rows
    }

    fn train_cached(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        kind: ModelKind,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        match kind {
            ModelKind::Pessimistic => self.train_pessimistic(cloud, repo, feat),
            ModelKind::Optimistic => {
                self.train_optimistic(cloud, repo, &OptTrainConfig::default(), feat)
            }
        }
    }

    fn predict(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>> {
        Predictor::predict(self, model, cloud, queries)
    }

    fn predict_batch(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        batch: &QueryBatch,
    ) -> Result<Vec<f64>> {
        Predictor::predict_batch(self, model, cloud, batch)
    }
}

/// The backend selector: the PJRT-backed [`Predictor`] when compiled
/// artifacts (and the PJRT runtime) are available, the pure-Rust
/// [`native::NativeEngine`] otherwise. Worker threads of the coordinator
/// service each own one `Engine`; the PJRT variant is not `Send` (the
/// PJRT client is thread-pinned), so engines are always constructed on
/// the thread that uses them.
pub enum Engine {
    Pjrt(Predictor),
    Native(native::NativeEngine),
}

impl Engine {
    /// PJRT if the artifacts directory is complete and the runtime
    /// loads; native fallback otherwise.
    pub fn auto(artifacts_dir: &Path) -> Engine {
        if Runtime::artifacts_available(artifacts_dir) {
            match Predictor::new(artifacts_dir) {
                Ok(p) => return Engine::Pjrt(p),
                Err(e) => {
                    eprintln!(
                        "warning: PJRT artifacts present but unloadable ({e:#}); \
                         falling back to native models"
                    );
                }
            }
        }
        Engine::Native(native::NativeEngine::default())
    }

    /// Always the pure-Rust backend.
    pub fn native() -> Engine {
        Engine::Native(native::NativeEngine::default())
    }

    pub fn is_pjrt(&self) -> bool {
        matches!(self, Engine::Pjrt(_))
    }
}

impl ModelTrainer for Engine {
    fn backend(&self) -> &'static str {
        match self {
            Engine::Pjrt(p) => p.backend(),
            Engine::Native(n) => ModelTrainer::backend(n),
        }
    }

    fn knn_capacity(&self) -> usize {
        match self {
            Engine::Pjrt(p) => ModelTrainer::knn_capacity(p),
            Engine::Native(n) => ModelTrainer::knn_capacity(n),
        }
    }

    fn train_cached(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        kind: ModelKind,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        match self {
            Engine::Pjrt(p) => ModelTrainer::train_cached(p, cloud, repo, kind, feat),
            Engine::Native(n) => ModelTrainer::train_cached(n, cloud, repo, kind, feat),
        }
    }

    fn predict(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>> {
        match self {
            Engine::Pjrt(p) => ModelTrainer::predict(p, model, cloud, queries),
            Engine::Native(n) => ModelTrainer::predict(n, model, cloud, queries),
        }
    }

    fn predict_batch(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        batch: &QueryBatch,
    ) -> Result<Vec<f64>> {
        match self {
            Engine::Pjrt(p) => ModelTrainer::predict_batch(p, model, cloud, batch),
            Engine::Native(n) => ModelTrainer::predict_batch(n, model, cloud, batch),
        }
    }

    fn fork_native(&self) -> Option<native::NativeEngine> {
        match self {
            Engine::Pjrt(p) => p.fork_native(),
            Engine::Native(n) => ModelTrainer::fork_native(n),
        }
    }
}

/// An `(engine, TrainedModel)` pair as a [`RuntimeModel`] — what the
/// coordinator hands the configurator.
pub struct EngineBound<'e> {
    pub engine: &'e mut dyn ModelTrainer,
    pub model: TrainedModel,
}

impl RuntimeModel for EngineBound<'_> {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        self.engine.predict(&self.model, cloud, queries)
    }

    fn predict_batch(&mut self, cloud: &Cloud, batch: &QueryBatch) -> Result<Vec<f64>> {
        self.engine.predict_batch(&self.model, cloud, batch)
    }
}

/// A `(Predictor, TrainedModel)` pair as a [`RuntimeModel`].
pub struct BoundModel<'p> {
    pub predictor: &'p mut Predictor,
    pub model: TrainedModel,
}

impl RuntimeModel for BoundModel<'_> {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        self.predictor.predict(&self.model, cloud, queries)
    }

    fn predict_batch(&mut self, cloud: &Cloud, batch: &QueryBatch) -> Result<Vec<f64>> {
        self.predictor.predict_batch(&self.model, cloud, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{ExperimentGrid, JobKind};

    macro_rules! require_artifacts {
        () => {{
            let dir = Runtime::default_dir();
            if !Runtime::artifacts_available(&dir) {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                return;
            }
            dir
        }};
    }

    fn grep_repo(cloud: &Cloud) -> RuntimeDataRepo {
        let grid = ExperimentGrid::paper_table1();
        let grep = ExperimentGrid {
            experiments: grid
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Grep)
                .collect(),
            repetitions: 3,
        };
        grep.execute(cloud, 11).repo_for(JobKind::Grep)
    }

    fn holdout_queries(repo: &RuntimeDataRepo, every: usize) -> (Vec<ConfigQuery>, Vec<f64>) {
        let mut qs = Vec::new();
        let mut truth = Vec::new();
        for (i, r) in repo.records().iter().enumerate() {
            if i % every == 0 {
                qs.push(ConfigQuery {
                    machine: r.machine.clone(),
                    scaleout: r.scaleout,
                    job_features: r.job_features.clone(),
                });
                truth.push(r.runtime_s);
            }
        }
        (qs, truth)
    }

    #[test]
    fn pessimistic_interpolates_training_points() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = grep_repo(&cloud);
        let mut p = Predictor::new(&dir).unwrap();
        let model = p.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
        // querying exact training configurations must be near-exact
        let (qs, truth) = holdout_queries(&repo, 7);
        let preds = p.predict(&model, &cloud, &qs).unwrap();
        let mape = stats::mape(&preds, &truth);
        assert!(mape < 3.0, "training-point MAPE {mape}%");
    }

    #[test]
    fn pessimistic_generalizes_leave_out() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = grep_repo(&cloud);
        // leave out every 5th record, train on the rest
        let mut train = RuntimeDataRepo::new(JobKind::Grep);
        let mut test = Vec::new();
        for (i, r) in repo.records().iter().enumerate() {
            if i % 5 == 0 {
                test.push(r.clone());
            } else {
                train.contribute(r.clone()).unwrap();
            }
        }
        let mut p = Predictor::new(&dir).unwrap();
        let model = p.train(&cloud, &train, ModelKind::Pessimistic).unwrap();
        let qs: Vec<ConfigQuery> = test
            .iter()
            .map(|r| ConfigQuery {
                machine: r.machine.clone(),
                scaleout: r.scaleout,
                job_features: r.job_features.clone(),
            })
            .collect();
        let truth: Vec<f64> = test.iter().map(|r| r.runtime_s).collect();
        let preds = p.predict(&model, &cloud, &qs).unwrap();
        let mape = stats::mape(&preds, &truth);
        assert!(mape < 25.0, "held-out MAPE {mape}%");
    }

    #[test]
    fn optimistic_trains_and_predicts() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = grep_repo(&cloud);
        let mut p = Predictor::new(&dir).unwrap();
        let model = p.train(&cloud, &repo, ModelKind::Optimistic).unwrap();
        if let ModelState::Opt { final_loss, .. } = &model.state {
            assert!(*final_loss < 0.5, "loss {final_loss}");
        } else {
            panic!("wrong state");
        }
        let (qs, truth) = holdout_queries(&repo, 7);
        let preds = p.predict(&model, &cloud, &qs).unwrap();
        let mape = stats::mape(&preds, &truth);
        assert!(mape < 35.0, "optimistic MAPE {mape}%");
    }

    #[test]
    fn optimistic_extrapolates_scaleout() {
        // train only on scale-outs 2..8; predict 10 and 12.
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = grep_repo(&cloud);
        let mut train = RuntimeDataRepo::new(JobKind::Grep);
        let mut test = Vec::new();
        for r in repo.records() {
            if r.scaleout <= 8 {
                train.contribute(r.clone()).unwrap();
            } else {
                test.push(r.clone());
            }
        }
        let mut p = Predictor::new(&dir).unwrap();
        let model = p.train(&cloud, &train, ModelKind::Optimistic).unwrap();
        let qs: Vec<ConfigQuery> = test
            .iter()
            .map(|r| ConfigQuery {
                machine: r.machine.clone(),
                scaleout: r.scaleout,
                job_features: r.job_features.clone(),
            })
            .collect();
        let truth: Vec<f64> = test.iter().map(|r| r.runtime_s).collect();
        let preds = p.predict(&model, &cloud, &qs).unwrap();
        let mape = stats::mape(&preds, &truth);
        assert!(mape < 40.0, "extrapolation MAPE {mape}%");
        // extrapolated runtimes must stay positive and finite
        assert!(preds.iter().all(|&t| t.is_finite() && t > 0.0));
    }

    #[test]
    fn query_batch_concat_preserves_rows_bitwise() {
        let cloud = Cloud::aws_like();
        let pairs = vec![
            ("m5.xlarge".to_string(), 2u32),
            ("c5.xlarge".to_string(), 4u32),
        ];
        let a = QueryBatch::from_candidates(&cloud, &pairs, &[10.0]);
        let b = QueryBatch::from_candidates(&cloud, &pairs, &[17.5]);
        let both = QueryBatch::concat(&[a.clone(), b.clone()]);
        assert_eq!(both.len(), a.len() + b.len());
        assert_eq!(both.raw.rows, a.raw.rows + b.raw.rows);
        for r in 0..a.raw.rows {
            assert_eq!(both.raw.row(r), a.raw.row(r));
            assert_eq!(both.raw.row(a.raw.rows + r), b.raw.row(r));
        }
        assert_eq!(both.machines[2], "m5.xlarge");
        assert_eq!(both.scaleouts[3], 4);
    }

    #[test]
    fn empty_repo_rejected() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let mut p = Predictor::new(&dir).unwrap();
        let empty = RuntimeDataRepo::new(JobKind::Sort);
        assert!(p.train(&cloud, &empty, ModelKind::Pessimistic).is_err());
        assert!(p.train(&cloud, &empty, ModelKind::Optimistic).is_err());
    }
}
