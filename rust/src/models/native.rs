//! Pure-Rust implementations of both model families.
//!
//! These exist for three reasons:
//!
//! 1. **Differential testing** — the PJRT-executed artifacts must agree
//!    with these to within f32 tolerance (see `rust/tests/`), which
//!    validates the entire AOT bridge end-to-end.
//! 2. **Fallback serving** — [`NativeEngine`] is a full
//!    [`ModelTrainer`] backend: it trains and serves both families
//!    without any compiled artifacts, producing [`ModelState`] values
//!    layout-compatible with the PJRT path (padded to the same fixed
//!    shapes), so models are interchangeable between backends and every
//!    coordinator deployment works on a bare `cargo test`.
//! 3. **Perf baseline** — the §Perf benches compare PJRT vs native
//!    latency to quantify what the XLA path buys (batch fusion).

use crate::cloud::Cloud;
use crate::compute::ComputePool;
use crate::models::{
    fit_knn_state, next_model_id, ConfigQuery, ModelKind, ModelState, ModelTrainer,
    OptTrainConfig, QueryBatch, RuntimeModel, TrainedModel,
};
use crate::repo::featurize::{FeatureMatrixCache, FeatureSpace, Featurizer};
use crate::repo::RuntimeDataRepo;
use crate::util::matrix::MatF32;
use crate::util::rng::Pcg32;
use crate::util::stats;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Distance assigned to padded rows (must match `ref.PAD_DISTANCE`).
pub const PAD_DISTANCE: f32 = 1e30;

/// Fixed native model shapes, mirroring the PJRT artifact manifest
/// (`python/compile/model.py`): padding native-trained states to the
/// same layout keeps them servable by PJRT workers and vice versa.
pub const NATIVE_FEATURE_DIM: usize = 16;
pub const NATIVE_KNN_ROWS: usize = 512;
pub const NATIVE_KNN_K: usize = 5;
pub const NATIVE_OPT_BATCH: usize = 256;

/// Smallest [`QueryBatch`] worth fanning across the compute pool:
/// below this the per-call thread spawn outweighs the row work.
pub const PARALLEL_PREDICT_MIN_ROWS: usize = 64;

/// Adam hyper-parameters (must match `python/compile/model.py`).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
/// L2 coefficient of the optimistic training loss (matches `_masked_mse`).
const OPT_L2: f32 = 1e-4;

/// Factorized-model forward pass in standardized log-runtime space:
/// `bias + Σ_c θ_lin·x + θ_log·ln(1+x) + θ_inv/(x+0.1)`.
pub(crate) fn opt_forward_std(params: &[f32], x01: &[f32]) -> f32 {
    let f = (params.len() - 1) / 3;
    let mut acc = params[0];
    for c in 0..f {
        let x = x01[c];
        acc += params[1 + c] * x;
        acc += params[1 + f + c] * (1.0 + x).ln();
        acc += params[1 + 2 * f + c] / (x + 0.1);
    }
    acc
}

/// Min-max scale a raw feature row into the optimistic basis domain,
/// zero-filling padded columns; clamps exactly like the PJRT query path.
pub(crate) fn opt_x01_from_raw(raw: &[f32], mins: &[f32], spans: &[f32]) -> Vec<f32> {
    let f = mins.len();
    let mut x01 = vec![0.0f32; f];
    for (c, &rv) in raw.iter().enumerate() {
        // clamp below 0 so the reciprocal basis stays finite; above 1
        // extrapolation is intentional
        x01[c] = (((rv - mins[c]) / spans[c]).max(-0.05)).min(5.0);
    }
    x01
}

/// Score one raw feature row with a (possibly padded) optimistic state.
pub(crate) fn opt_score_raw(
    mins: &[f32],
    spans: &[f32],
    y_mean: f32,
    y_sd: f32,
    params: &[f32],
    raw: &[f32],
) -> f64 {
    let x01 = opt_x01_from_raw(raw, mins, spans);
    let acc = opt_forward_std(params, &x01);
    ((acc * y_sd + y_mean) as f64).exp()
}

/// Score one raw feature row with a (possibly padded) pessimistic state:
/// standardize into the fitted space, inverse-distance-weight the `k`
/// nearest valid training rows. Mirrors `knn_predict_ref` including the
/// padding mask semantics.
pub(crate) fn knn_score_raw(
    space: &FeatureSpace,
    train_x: &MatF32,
    train_y: &[f32],
    valid: &[f32],
    weights: &[f32],
    k: usize,
    raw: &[f32],
) -> f64 {
    let d = space.dim();
    debug_assert_eq!(raw.len(), d, "raw row layout must match feature space");
    let mut row = vec![0.0f32; d];
    for c in 0..d {
        row[c] = (raw[c] - space.mean[c]) / space.sd[c];
    }
    let mut dists: Vec<(f32, usize)> = Vec::with_capacity(train_x.rows);
    for i in 0..train_x.rows {
        if valid[i] < 0.5 {
            continue; // padded row — PAD_DISTANCE would zero its weight
        }
        let tr = train_x.row(i);
        let mut dacc = 0.0f32;
        for c in 0..d {
            let diff = row[c] - tr[c];
            dacc += weights[c] * diff * diff;
        }
        dists.push((dacc, i));
    }
    dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let k = k.min(dists.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for &(dist, i) in dists.iter().take(k) {
        let w = 1.0 / (dist as f64 + 1e-6);
        num += w * train_y[i] as f64;
        den += w;
    }
    space.unscale_runtime((num / den.max(1e-6)) as f32)
}

/// One Adam step on the masked-MSE (+ L2) loss of the optimistic model —
/// the pure-Rust mirror of the AOT `optimistic_train` graph (analytic
/// gradient of `_masked_mse`, bias-corrected Adam from `adam_step_ref`).
/// Returns the step's loss.
#[allow(clippy::too_many_arguments)]
pub(crate) fn native_opt_train_step(
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    step: u32,
    bx: &MatF32,
    by: &[f32],
    mask: &[f32],
    lr: f32,
) -> f32 {
    let p = params.len();
    let f = (p - 1) / 3;
    // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
    let n_eff = mask.iter().sum::<f32>().max(1.0);
    let mut grad = vec![0.0f32; p];
    let mut loss = 0.0f32;
    for i in 0..bx.rows {
        if mask[i] == 0.0 {
            continue;
        }
        let x = bx.row(i);
        let pred = opt_forward_std(params, x);
        let err = pred - by[i];
        loss += err * err * mask[i];
        let dp = 2.0 * err * mask[i] / n_eff;
        grad[0] += dp;
        for c in 0..f {
            let xv = x[c];
            grad[1 + c] += dp * xv;
            grad[1 + f + c] += dp * (1.0 + xv).ln();
            grad[1 + 2 * f + c] += dp / (xv + 0.1);
        }
    }
    loss /= n_eff;
    for c in 1..p {
        loss += OPT_L2 * params[c] * params[c];
        grad[c] += 2.0 * OPT_L2 * params[c];
    }
    let b1t = 1.0 - ADAM_B1.powi(step as i32);
    let b2t = 1.0 - ADAM_B2.powi(step as i32);
    for j in 0..p {
        m[j] = ADAM_B1 * m[j] + (1.0 - ADAM_B1) * grad[j];
        v[j] = ADAM_B2 * v[j] + (1.0 - ADAM_B2) * grad[j] * grad[j];
        let mhat = m[j] / b1t;
        let vhat = v[j] / b2t;
        params[j] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
    loss
}

/// The pure-Rust [`ModelTrainer`] backend: trains and serves both model
/// families with no PJRT dependency. States are padded to the same fixed
/// shapes as the artifacts so they interchange with PJRT-trained models.
#[derive(Debug, Clone)]
pub struct NativeEngine {
    pub feature_dim: usize,
    pub knn_rows: usize,
    pub knn_k: usize,
    pub opt_batch: usize,
    pub opt_cfg: OptTrainConfig,
    /// Shared compute pool for chunked batch scoring (`None` = serial).
    /// Chunked results are reassembled in row order and each row is
    /// scored by the same pure function either way, so predictions are
    /// bitwise-identical with or without a pool.
    pub pool: Option<Arc<ComputePool>>,
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine {
            feature_dim: NATIVE_FEATURE_DIM,
            knn_rows: NATIVE_KNN_ROWS,
            knn_k: NATIVE_KNN_K,
            opt_batch: NATIVE_OPT_BATCH,
            opt_cfg: OptTrainConfig::default(),
            pool: None,
        }
    }
}

impl NativeEngine {
    /// Fit the pessimistic model (standardize + correlation weights),
    /// padded to the engine's fixed shapes.
    pub fn train_pessimistic(
        &self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        let state = fit_knn_state(cloud, repo, self.knn_rows, self.feature_dim, feat)?;
        Ok(TrainedModel {
            kind: ModelKind::Pessimistic,
            id: next_model_id(),
            state,
        })
    }

    /// Train the factorized model with mini-batch Adam — the same epoch
    /// loop as the PJRT path, with the train step executed natively.
    pub fn train_optimistic(
        &self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        cfg: &OptTrainConfig,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        if repo.is_empty() {
            bail!("cannot train on an empty repository");
        }
        let fd = self.feature_dim;
        // Cached raw rows/targets are bitwise what the from-scratch
        // loops would produce (same helper over the same records), so
        // the Adam trajectory is bit-for-bit unchanged.
        let owned: Option<(Vec<Vec<f32>>, Vec<f32>)>;
        let (raw, log_y): (&[Vec<f32>], &[f32]) = match feat {
            Some(cache) => {
                assert!(cache.is_fresh(repo), "feature cache is stale: refresh() before train");
                (cache.raw_rows(), cache.log_y())
            }
            None => {
                let featurizer = Featurizer::new(cloud);
                owned = Some((
                    repo.records()
                        .iter()
                        .map(|r| featurizer.raw_row(&r.machine, r.scaleout, &r.job_features))
                        .collect(),
                    repo.records()
                        .iter()
                        .map(|r| r.runtime_s.ln() as f32)
                        .collect(),
                ));
                let (raw, log_y) = owned.as_ref().expect("just set");
                (raw, log_y)
            }
        };
        let d = raw[0].len();
        if d > fd {
            bail!("feature dim {d} exceeds native feature dim {fd}");
        }
        let n = raw.len();

        // min-max scaling to [0, 1] (the basis domain)
        let mut mins = vec![f32::INFINITY; fd];
        let mut maxs = vec![f32::NEG_INFINITY; fd];
        for row in raw {
            for c in 0..d {
                mins[c] = mins[c].min(row[c]);
                maxs[c] = maxs[c].max(row[c]);
            }
        }
        let mut spans = vec![1.0f32; fd];
        for c in 0..d {
            spans[c] = (maxs[c] - mins[c]).max(1e-6);
        }
        for c in d..fd {
            mins[c] = 0.0;
            spans[c] = 1.0;
        }

        // standardized log target
        // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
        let y_mean = log_y.iter().sum::<f32>() / n as f32;
        // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
        let y_sd = (log_y.iter().map(|v| (v - y_mean).powi(2)).sum::<f32>() / n as f32)
            .sqrt()
            .max(1e-6);

        // scaled full dataset
        let mut x01 = MatF32::zeros(n, fd);
        let mut y = vec![0.0f32; n];
        for (r, row) in raw.iter().enumerate() {
            for c in 0..d {
                x01.set(r, c, (row[c] - mins[c]) / spans[c]);
            }
            y[r] = (log_y[r] - y_mean) / y_sd;
        }

        // mini-batch loop (identical control flow to the PJRT path)
        let b = self.opt_batch;
        let np = 1 + 3 * fd;
        let mut params = vec![0.0f32; np];
        let mut m = vec![0.0f32; np];
        let mut v = vec![0.0f32; np];
        let mut rng = Pcg32::new(cfg.shuffle_seed);
        let mut order: Vec<usize> = (0..n).collect();
        let mut best = f32::INFINITY;
        let mut since_best = 0u32;
        let mut final_loss = f32::INFINITY;
        let mut step = 0u32;
        'train: loop {
            rng.shuffle(&mut order);
            for chunk in order.chunks(b) {
                step += 1;
                if step > cfg.max_steps {
                    break 'train;
                }
                let mut bx = MatF32::zeros(b, fd);
                let mut by = vec![0.0f32; b];
                let mut mask = vec![0.0f32; b];
                for (i, &r) in chunk.iter().enumerate() {
                    bx.row_mut(i).copy_from_slice(x01.row(r));
                    by[i] = y[r];
                    mask[i] = 1.0;
                }
                final_loss =
                    native_opt_train_step(&mut params, &mut m, &mut v, step, &bx, &by, &mask, cfg.lr);
                if final_loss < best - cfg.tol {
                    best = final_loss;
                    since_best = 0;
                } else {
                    since_best += 1;
                    if since_best >= cfg.patience {
                        break 'train;
                    }
                }
            }
        }

        let names = {
            let mut names: Vec<String> = repo
                .job()
                .feature_names()
                .iter()
                .map(|s| s.to_string())
                .collect();
            names.extend(
                crate::repo::featurize::CLUSTER_FEATURES
                    .iter()
                    .map(|s| s.to_string()),
            );
            names
        };

        Ok(TrainedModel {
            kind: ModelKind::Optimistic,
            id: next_model_id(),
            state: ModelState::Opt {
                mins,
                spans,
                y_mean,
                y_sd,
                params,
                final_loss,
                names,
            },
        })
    }

    /// Install a shared compute pool; large batch predictions will be
    /// chunked across it (results stay bitwise-identical to serial).
    pub fn set_compute_pool(&mut self, pool: Arc<ComputePool>) {
        self.pool = Some(pool);
    }

    /// Score one raw feature row against a trained state.
    fn score_raw(&self, model: &TrainedModel, raw: &[f32]) -> f64 {
        match &model.state {
            ModelState::Knn {
                space,
                train_x,
                train_y,
                valid,
                weights,
            } => knn_score_raw(space, train_x, train_y, valid, weights, self.knn_k, raw),
            ModelState::Opt {
                mins,
                spans,
                y_mean,
                y_sd,
                params,
                ..
            } => opt_score_raw(mins, spans, *y_mean, *y_sd, params, raw),
        }
    }
}

impl ModelTrainer for NativeEngine {
    fn backend(&self) -> &'static str {
        "native"
    }

    fn knn_capacity(&self) -> usize {
        self.knn_rows
    }

    fn train_cached(
        &mut self,
        cloud: &Cloud,
        repo: &RuntimeDataRepo,
        kind: ModelKind,
        feat: Option<&mut FeatureMatrixCache>,
    ) -> Result<TrainedModel> {
        match kind {
            ModelKind::Pessimistic => self.train_pessimistic(cloud, repo, feat),
            ModelKind::Optimistic => {
                let cfg = self.opt_cfg.clone();
                self.train_optimistic(cloud, repo, &cfg, feat)
            }
        }
    }

    fn predict(
        &mut self,
        model: &TrainedModel,
        cloud: &Cloud,
        queries: &[ConfigQuery],
    ) -> Result<Vec<f64>> {
        let featurizer = Featurizer::new(cloud);
        Ok(queries
            .iter()
            .map(|q| {
                let raw = featurizer.raw_row(&q.machine, q.scaleout, &q.job_features);
                self.score_raw(model, &raw)
            })
            .collect())
    }

    fn predict_batch(
        &mut self,
        model: &TrainedModel,
        _cloud: &Cloud,
        batch: &QueryBatch,
    ) -> Result<Vec<f64>> {
        let rows = batch.raw.rows;
        let this: &NativeEngine = self;
        if let Some(pool) = this
            .pool
            .as_deref()
            .filter(|p| p.threads() > 1 && rows >= PARALLEL_PREDICT_MIN_ROWS)
        {
            // Row-chunked fan: each chunk scores its rows with the same
            // pure per-row function the serial loop uses, and chunks
            // are concatenated in chunk (= row) order, so the output is
            // bitwise-identical to the serial path below.
            let chunk = rows.div_ceil(pool.threads());
            let tasks: Vec<_> = (0..rows)
                .step_by(chunk)
                .map(|r0| {
                    let r1 = (r0 + chunk).min(rows);
                    move || {
                        (r0..r1)
                            .map(|r| this.score_raw(model, batch.raw.row(r)))
                            .collect::<Vec<f64>>()
                    }
                })
                .collect();
            return Ok(pool.map_ordered(tasks).into_iter().flatten().collect());
        }
        Ok((0..rows)
            .map(|r| this.score_raw(model, batch.raw.row(r)))
            .collect())
    }

    fn fork_native(&self) -> Option<NativeEngine> {
        Some(self.clone())
    }
}

/// Native similarity-weighted kNN (pessimistic model).
#[derive(Debug, Clone)]
pub struct NativeKnn {
    pub space: FeatureSpace,
    pub train_x: MatF32,
    pub train_y: Vec<f32>,
    pub weights: Vec<f32>,
    pub k: usize,
}

impl NativeKnn {
    /// Fit on a repository: standardize, learn correlation weights.
    /// Mirrors `Predictor::train_pessimistic` exactly (same weight floor).
    pub fn fit(cloud: &Cloud, repo: &RuntimeDataRepo, k: usize) -> Result<NativeKnn> {
        if repo.is_empty() {
            bail!("cannot fit on an empty repository");
        }
        let featurizer = Featurizer::new(cloud);
        let (space, x, y) = featurizer.fit(repo);
        let d = space.dim();
        let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let mut weights = vec![0.0f32; d];
        for c in 0..d {
            let col: Vec<f64> = (0..x.rows).map(|r| x.at(r, c) as f64).collect();
            let corr = stats::pearson(&col, &yf);
            weights[c] = if corr.is_finite() {
                (corr.abs() as f32).max(0.05)
            } else {
                0.05
            };
        }
        Ok(NativeKnn {
            space,
            train_x: x,
            train_y: y,
            weights,
            k,
        })
    }

    /// Predict one standardized query row (in the fitted space).
    pub fn predict_row(&self, row: &[f32]) -> f64 {
        let t = self.train_x.rows;
        let mut dists: Vec<(f32, usize)> = Vec::with_capacity(t);
        for i in 0..t {
            let tr = self.train_x.row(i);
            let mut d = 0.0f32;
            for c in 0..row.len() {
                let diff = row[c] - tr[c];
                d += self.weights[c] * diff * diff;
            }
            dists.push((d, i));
        }
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let k = self.k.min(t);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for &(d, i) in dists.iter().take(k) {
            let w = 1.0 / (d as f64 + 1e-6);
            num += w * self.train_y[i] as f64;
            den += w;
        }
        self.space.unscale_runtime((num / den.max(1e-6)) as f32)
    }
}

impl RuntimeModel for NativeKnn {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        let featurizer = Featurizer::new(cloud);
        Ok(queries
            .iter()
            .map(|q| {
                let row =
                    featurizer.transform(&self.space, &q.machine, q.scaleout, &q.job_features);
                self.predict_row(&row)
            })
            .collect())
    }
}

/// Native forward pass of the optimistic model (given trained params).
/// Mirrors `optimistic_predict_ref` in Python: bias + [x, log1p(x),
/// 1/(x+0.1)] basis.
#[derive(Debug, Clone)]
pub struct NativeOptimistic {
    pub mins: Vec<f32>,
    pub spans: Vec<f32>,
    pub y_mean: f32,
    pub y_sd: f32,
    pub params: Vec<f32>,
    /// Number of real (unpadded) feature columns.
    pub dim: usize,
}

impl NativeOptimistic {
    /// Build from the trained PJRT model state.
    pub fn from_state(
        mins: &[f32],
        spans: &[f32],
        y_mean: f32,
        y_sd: f32,
        params: &[f32],
        dim: usize,
    ) -> Self {
        NativeOptimistic {
            mins: mins.to_vec(),
            spans: spans.to_vec(),
            y_mean,
            y_sd,
            params: params.to_vec(),
            dim,
        }
    }

    /// Forward pass over scaled features x01 (full padded width).
    pub fn predict_x01(&self, x01: &[f32]) -> f64 {
        debug_assert_eq!(self.params.len(), 1 + 3 * self.mins.len());
        let acc = opt_forward_std(&self.params, x01);
        ((acc * self.y_sd + self.y_mean) as f64).exp()
    }
}

impl RuntimeModel for NativeOptimistic {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        let featurizer = Featurizer::new(cloud);
        let f = self.mins.len();
        Ok(queries
            .iter()
            .map(|q| {
                let raw = featurizer.raw_row(&q.machine, q.scaleout, &q.job_features);
                let mut x01 = vec![0.0f32; f];
                for (c, &rv) in raw.iter().enumerate() {
                    x01[c] = (((rv - self.mins[c]) / self.spans[c]).max(-0.05)).min(5.0);
                }
                self.predict_x01(&x01)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::RuntimeRecord;
    use crate::workloads::JobKind;

    fn toy_repo() -> RuntimeDataRepo {
        // runtime = 1000 / scaleout (pure scale-out law)
        let mut recs = Vec::new();
        for &n in &[2u32, 4, 6, 8, 10, 12] {
            for m in ["c5.xlarge", "m5.xlarge", "r5.xlarge"] {
                recs.push(RuntimeRecord {
                    job: JobKind::Sort,
                    org: "t".into(),
                    machine: m.into(),
                    scaleout: n,
                    job_features: vec![15.0],
                    runtime_s: 1000.0 / n as f64,
                });
            }
        }
        RuntimeDataRepo::from_records(JobKind::Sort, recs)
    }

    #[test]
    fn knn_exact_training_point() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut knn = NativeKnn::fit(&cloud, &repo, 5).unwrap();
        let qs = vec![ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![15.0],
        }];
        let pred = knn.predict(&cloud, &qs).unwrap()[0];
        assert!((pred - 250.0).abs() / 250.0 < 0.02, "pred {pred}");
    }

    #[test]
    fn knn_interpolates_between_scaleouts() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut knn = NativeKnn::fit(&cloud, &repo, 3).unwrap();
        let qs = vec![ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 5,
            job_features: vec![15.0],
        }];
        let pred = knn.predict(&cloud, &qs).unwrap()[0];
        // truth 200; neighbours 250 and 166.7 — prediction in between
        assert!((150.0..280.0).contains(&pred), "pred {pred}");
    }

    #[test]
    fn knn_weights_floor_applied() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let knn = NativeKnn::fit(&cloud, &repo, 5).unwrap();
        assert!(knn.weights.iter().all(|&w| w >= 0.05));
    }

    #[test]
    fn optimistic_forward_matches_manual() {
        let f = 3;
        let mut params = vec![0.0f32; 1 + 3 * f];
        params[0] = 1.0; // bias
        params[1] = 2.0; // x0 linear
        params[1 + f + 1] = -1.0; // x1 log
        params[1 + 2 * f + 2] = 0.5; // x2 reciprocal
        let m = NativeOptimistic {
            mins: vec![0.0; f],
            spans: vec![1.0; f],
            y_mean: 0.0,
            y_sd: 1.0,
            params,
            dim: f,
        };
        let x01 = vec![0.5f32, 0.3, 0.2];
        let want =
            (1.0 + 2.0 * 0.5 - (1.0f32 + 0.3).ln() + 0.5 / (0.2 + 0.1)) as f64;
        let got = m.predict_x01(&x01).ln();
        assert!((got - want as f64).abs() < 1e-5, "{got} vs {want}");
    }

    #[test]
    fn empty_repo_rejected() {
        let cloud = Cloud::aws_like();
        assert!(NativeKnn::fit(&cloud, &RuntimeDataRepo::new(JobKind::Sort), 5).is_err());
    }

    #[test]
    fn engine_trains_and_interpolates_pessimistic() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut engine = NativeEngine::default();
        let model = engine.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
        // exact training point must be near-exact
        let qs = vec![ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![15.0],
        }];
        let pred = engine.predict(&model, &cloud, &qs).unwrap()[0];
        assert!((pred - 250.0).abs() / 250.0 < 0.02, "pred {pred}");
    }

    #[test]
    fn engine_trains_optimistic_and_learns_scaleout_law() {
        // runtime = 1000/n is exactly expressible by the reciprocal basis;
        // training must drive loss down and predictions near truth.
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut engine = NativeEngine::default();
        let model = engine.train(&cloud, &repo, ModelKind::Optimistic).unwrap();
        if let ModelState::Opt { final_loss, .. } = &model.state {
            assert!(*final_loss < 0.5, "loss {final_loss}");
        } else {
            panic!("wrong state");
        }
        let qs = vec![ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 8,
            job_features: vec![15.0],
        }];
        let pred = engine.predict(&model, &cloud, &qs).unwrap()[0];
        assert!((pred - 125.0).abs() / 125.0 < 0.35, "pred {pred}");
    }

    #[test]
    fn engine_batched_predict_is_bitwise_equal_to_sequential() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut engine = NativeEngine::default();
        let features = vec![15.0];
        let candidates: Vec<(String, u32)> = ["c5.xlarge", "m5.xlarge", "r5.xlarge"]
            .iter()
            .flat_map(|m| (2..=12).map(move |n| (m.to_string(), n)))
            .collect();
        let batch = QueryBatch::from_candidates(&cloud, &candidates, &features);
        for kind in ModelKind::all() {
            let model = engine.train(&cloud, &repo, kind).unwrap();
            let batched = engine.predict_batch(&model, &cloud, &batch).unwrap();
            let sequential = engine.predict(&model, &cloud, &batch.queries()).unwrap();
            assert_eq!(batched.len(), sequential.len());
            for (i, (a, b)) in batched.iter().zip(&sequential).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{kind:?} candidate {i}: batched {a} vs sequential {b}"
                );
            }
        }
    }

    #[test]
    fn engine_states_are_padded_to_artifact_layout() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut engine = NativeEngine::default();
        let knn = engine.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
        if let ModelState::Knn { train_x, valid, weights, .. } = &knn.state {
            assert_eq!(train_x.rows, NATIVE_KNN_ROWS);
            assert_eq!(train_x.cols, NATIVE_FEATURE_DIM);
            assert_eq!(valid.iter().filter(|&&v| v > 0.5).count(), repo.len());
            assert_eq!(weights.len(), NATIVE_FEATURE_DIM);
        } else {
            panic!("wrong state");
        }
        let opt = engine.train(&cloud, &repo, ModelKind::Optimistic).unwrap();
        if let ModelState::Opt { mins, params, .. } = &opt.state {
            assert_eq!(mins.len(), NATIVE_FEATURE_DIM);
            assert_eq!(params.len(), 1 + 3 * NATIVE_FEATURE_DIM);
        } else {
            panic!("wrong state");
        }
    }

    #[test]
    fn engine_rejects_oversized_repo() {
        let cloud = Cloud::aws_like();
        let engine = NativeEngine {
            knn_rows: 4, // tiny cap to trigger the guard
            ..NativeEngine::default()
        };
        let repo = toy_repo(); // 18 records
        assert!(engine.train_pessimistic(&cloud, &repo, None).is_err());
    }
}
