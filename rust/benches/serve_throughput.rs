//! Bench: requests/second of the sharded multi-worker service vs the
//! single-thread ordered session, at 1, 4, and 8 client threads.
//!
//! Three scenarios:
//!
//! * **write-heavy** (the original): pure `Submit` traffic. The workload
//!   interleaves four job kinds so the service's per-kind shards can
//!   actually run concurrently; the session baseline serves the
//!   identical battery through its strictly-ordered single worker.
//! * **read-heavy**: `Recommend:Submit ≈ 9:1` — the paper's real shape
//!   (many cheap configurator queries, few contributed runs). The
//!   service serves reads lock-free from published model snapshots and
//!   coalesces same-kind reads into one predict batch, so this is where
//!   the read/write split pays.
//! * **write mix**: `Recommend:Submit ≈ 1:9` with pipelined submits —
//!   the shape that exercises write-side coalescing (same-kind submit
//!   groups pre-scored as one predict batch) and the incremental
//!   feature cache (delta-aware retrains inside the timed window).
//! * **tracing overhead**: the read-heavy mix served twice more, with
//!   span tracing enabled and disabled, recording the throughput delta
//!   the `c3o::obs` layer costs (it must be cheap enough to leave on).
//!   The traced run also supplies the exported per-kind latency
//!   percentiles.
//! * **retrain-heavy**: a 1:1 read/write mix under an aggressive
//!   retrain policy (`retrain_every = 2`), so writes keep the shards
//!   busy retraining. This is the shape the two-lane affinity queue is
//!   for: read-class workers drain the read lane first and steal write
//!   work only when no reads are queued, so reads keep flowing past the
//!   retrain storm. The cross-lane steal counters land in the JSON.
//!
//! Both paths are warmed by the corpus share (writes train the model),
//! so initial training is paid outside the timed window; retrains inside
//! the window are governed by the same generation-gating policy on both
//! sides.
//!
//! Emits `BENCH_serve_throughput.json` with the measured throughputs and
//! the speedup of the 8-client service over the session baseline.
//! Shrink with `C3O_SERVE_JOBS=24` for smoke runs.

use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::session::Session;
use c3o::coordinator::{CoordinatorService, Organization, ServiceConfig};
use c3o::util::json::Json;
use c3o::workloads::{ExperimentGrid, JobKind};
use std::time::Instant;

const KINDS: [JobKind; 4] = [JobKind::Sort, JobKind::Grep, JobKind::Sgd, JobKind::KMeans];

/// In the read-heavy mix, this many of every 10 requests are reads.
const READS_PER_10: usize = 9;

fn request_for(i: usize) -> JobRequest {
    let gb = 10.0 + (i % 10) as f64;
    match i % KINDS.len() {
        0 => JobRequest::sort(gb),
        1 => JobRequest::grep(gb, 0.1),
        2 => JobRequest::sgd(gb, 60),
        _ => JobRequest::kmeans(gb, 5, 0.001),
    }
}

fn is_read(i: usize) -> bool {
    i % 10 < READS_PER_10
}

fn corpus(cloud: &Cloud, seed: u64) -> c3o::workloads::Corpus {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| KINDS.contains(&e.spec.kind()))
            .collect(),
        repetitions: 1,
    }
    .execute(cloud, seed)
}

fn main() {
    let cloud = Cloud::aws_like();
    let total_jobs: usize = std::env::var("C3O_SERVE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);
    let corpus = corpus(&cloud, 42);
    let org = Organization::new("bench");

    // Both sides run the native model engines even when PJRT artifacts
    // are built (nonexistent artifacts dir / pjrt_workers = 0): the
    // speedup must measure the sharded architecture, not a PJRT-vs-native
    // backend difference.
    let no_artifacts = std::path::PathBuf::from("bench-no-artifacts");

    // ---- scenario 1: write-heavy (pure submissions) ---------------------

    // baseline: the ordered single-worker session
    let session = Session::spawn(cloud.clone(), no_artifacts.clone(), 7);
    for kind in KINDS {
        session.share(corpus.repo_for(kind)).unwrap(); // warm: trains
    }
    let t0 = Instant::now();
    for i in 0..total_jobs {
        session.submit(&org, request_for(i)).unwrap();
    }
    let baseline = total_jobs as f64 / t0.elapsed().as_secs_f64();
    session.shutdown();
    println!("write-heavy  session   1 client : {baseline:>8.1} submissions/s  (ordered single worker)");

    // the sharded service at 1, 4, 8 client threads
    let mut points: Vec<(usize, f64)> = Vec::new();
    for &clients in &[1usize, 4, 8] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(8)
                .with_pjrt_workers(0)
                .with_seed(7),
        );
        for kind in KINDS {
            service.share(corpus.repo_for(kind)).unwrap(); // warm: trains
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = service.client();
                scope.spawn(move || {
                    let org = Organization::new(&format!("client-{c}"));
                    let mut i = c;
                    while i < total_jobs {
                        client.submit(&org, request_for(i)).unwrap();
                        i += clients;
                    }
                });
            }
        });
        let jobs_per_s = total_jobs as f64 / t0.elapsed().as_secs_f64();
        println!("write-heavy  service  {clients:>2} clients: {jobs_per_s:>8.1} submissions/s");
        points.push((clients, jobs_per_s));
        service.shutdown();
    }

    let best = points.iter().map(|&(_, j)| j).fold(0.0f64, f64::max);
    let speedup = best / baseline;
    println!("write-heavy speedup (best service vs session): {speedup:.2}x");
    if speedup < 2.0 {
        eprintln!(
            "WARN: speedup {speedup:.2}x below the 2x goal — expected on \
             single-core machines; the sharded path needs real parallelism"
        );
    }

    // ---- scenario 2: read-heavy (recommend:submit ≈ 9:1) ----------------

    // baseline: the same mix through the ordered session (reads queue
    // behind writes — the shape's ceiling)
    let session = Session::spawn(cloud.clone(), no_artifacts.clone(), 7);
    for kind in KINDS {
        session.share(corpus.repo_for(kind)).unwrap();
    }
    let t0 = Instant::now();
    for i in 0..total_jobs {
        if is_read(i) {
            session.recommend(request_for(i)).unwrap();
        } else {
            session.submit(&org, request_for(i)).unwrap();
        }
    }
    let read_baseline = total_jobs as f64 / t0.elapsed().as_secs_f64();
    session.shutdown();
    println!("read-heavy   session   1 client : {read_baseline:>8.1} requests/s");

    let mut read_points: Vec<(usize, f64, u64)> = Vec::new();
    for &clients in &[1usize, 4, 8] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(8)
                .with_pjrt_workers(0)
                .with_seed(7),
        );
        for kind in KINDS {
            service.share(corpus.repo_for(kind)).unwrap();
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = service.client();
                scope.spawn(move || {
                    let org = Organization::new(&format!("client-{c}"));
                    let mut i = c;
                    while i < total_jobs {
                        if is_read(i) {
                            client.recommend(request_for(i)).unwrap();
                        } else {
                            client.submit(&org, request_for(i)).unwrap();
                        }
                        i += clients;
                    }
                });
            }
        });
        let req_per_s = total_jobs as f64 / t0.elapsed().as_secs_f64();
        let coalesced = service.metrics().unwrap().coalesced_batches;
        println!(
            "read-heavy   service  {clients:>2} clients: {req_per_s:>8.1} requests/s  \
             ({coalesced} coalesced read batches)"
        );
        read_points.push((clients, req_per_s, coalesced));
        service.shutdown();
    }

    let read_best = read_points.iter().map(|&(_, j, _)| j).fold(0.0f64, f64::max);
    let read_speedup = read_best / read_baseline;
    println!("read-heavy speedup (best service vs session): {read_speedup:.2}x");

    // ---- scenario 3: write mix (recommend:submit ≈ 1:9, pipelined) ------
    // The inverse of scenario 2: the serialized write path dominates.
    // Service clients pipeline their submits as tickets, so queue depth
    // builds and the write-side coalescing pre-scores same-kind submit
    // groups as one predict batch before their contribute steps.

    let is_rare_read = |i: usize| i % 10 == 0;

    let session = Session::spawn(cloud.clone(), no_artifacts, 7);
    for kind in KINDS {
        session.share(corpus.repo_for(kind)).unwrap();
    }
    let t0 = Instant::now();
    for i in 0..total_jobs {
        if is_rare_read(i) {
            session.recommend(request_for(i)).unwrap();
        } else {
            session.submit(&org, request_for(i)).unwrap();
        }
    }
    let write_baseline = total_jobs as f64 / t0.elapsed().as_secs_f64();
    session.shutdown();
    println!("write-mix    session   1 client : {write_baseline:>8.1} requests/s");

    let mut write_points: Vec<(usize, f64, u64, u64)> = Vec::new();
    for &clients in &[1usize, 4, 8] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(8)
                .with_pjrt_workers(0)
                .with_seed(7),
        );
        for kind in KINDS {
            service.share(corpus.repo_for(kind)).unwrap();
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = service.client();
                scope.spawn(move || {
                    let org = Organization::new(&format!("client-{c}"));
                    let mut tickets = Vec::new();
                    let mut i = c;
                    while i < total_jobs {
                        if is_rare_read(i) {
                            client.recommend(request_for(i)).unwrap();
                        } else {
                            tickets.push(
                                client.submit_nowait(&org, request_for(i)).unwrap(),
                            );
                        }
                        i += clients;
                    }
                    for ticket in tickets {
                        ticket.wait().unwrap();
                    }
                });
            }
        });
        let req_per_s = total_jobs as f64 / t0.elapsed().as_secs_f64();
        let m = service.metrics().unwrap();
        println!(
            "write-mix    service  {clients:>2} clients: {req_per_s:>8.1} requests/s  \
             ({} coalesced write batches, {} featurized rows reused)",
            m.coalesced_write_batches, m.featurized_rows_reused
        );
        write_points.push((
            clients,
            req_per_s,
            m.coalesced_write_batches,
            m.featurized_rows_reused,
        ));
        service.shutdown();
    }

    let write_best = write_points.iter().map(|&(_, j, _, _)| j).fold(0.0f64, f64::max);
    let write_speedup = write_best / write_baseline;
    println!("write-mix speedup (best service vs session): {write_speedup:.2}x");

    // ---- scenario 4: tracing overhead (on vs off, read-heavy mix) -------

    let mut traced_req_per_s = [0.0f64; 2];
    let mut latency = Json::Null;
    for (slot, tracing) in [(0usize, true), (1usize, false)] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(8)
                .with_pjrt_workers(0)
                .with_seed(7)
                .with_tracing(tracing),
        );
        for kind in KINDS {
            service.share(corpus.repo_for(kind)).unwrap();
        }
        let clients = 8usize;
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = service.client();
                scope.spawn(move || {
                    let org = Organization::new(&format!("client-{c}"));
                    let mut i = c;
                    while i < total_jobs {
                        if is_read(i) {
                            client.recommend(request_for(i)).unwrap();
                        } else {
                            client.submit(&org, request_for(i)).unwrap();
                        }
                        i += clients;
                    }
                });
            }
        });
        traced_req_per_s[slot] = total_jobs as f64 / t0.elapsed().as_secs_f64();
        if tracing {
            latency = service.obs_report().to_json();
        }
        service.shutdown();
    }
    let tracing_overhead_pct = if traced_req_per_s[0] > 0.0 {
        100.0 * (traced_req_per_s[1] / traced_req_per_s[0] - 1.0)
    } else {
        0.0
    };
    println!(
        "tracing overhead: on {:.1} req/s vs off {:.1} req/s \
         (untraced {tracing_overhead_pct:+.1}% faster)",
        traced_req_per_s[0], traced_req_per_s[1]
    );

    // ---- scenario 5: retrain-heavy mix (affinity routing) ---------------

    let retrain_policy = c3o::coordinator::ShardPolicy {
        retrain_every: 2, // every other write retrains its shard
        ..Default::default()
    };
    let mut retrain_points: Vec<(usize, f64, u64, u64, u64)> = Vec::new();
    for &clients in &[1usize, 4, 8] {
        let service = CoordinatorService::spawn(
            cloud.clone(),
            ServiceConfig::default()
                .with_workers(8)
                .with_pjrt_workers(0)
                .with_seed(7)
                .with_policy(retrain_policy.clone()),
        );
        for kind in KINDS {
            service.share(corpus.repo_for(kind)).unwrap();
        }
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = service.client();
                scope.spawn(move || {
                    let org = Organization::new(&format!("client-{c}"));
                    let mut i = c;
                    while i < total_jobs {
                        if i % 2 == 0 {
                            client.recommend(request_for(i)).unwrap();
                        } else {
                            client.submit(&org, request_for(i)).unwrap();
                        }
                        i += clients;
                    }
                });
            }
        });
        let req_per_s = total_jobs as f64 / t0.elapsed().as_secs_f64();
        let m = service.metrics().unwrap();
        let (reads_stolen, writes_stolen) = service.queue_steals();
        println!(
            "retrain-heavy service {clients:>2} clients: {req_per_s:>8.1} requests/s  \
             ({} retrains, {reads_stolen} reads / {writes_stolen} writes stolen)",
            m.retrains
        );
        retrain_points.push((clients, req_per_s, m.retrains, reads_stolen, writes_stolen));
        service.shutdown();
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("serve_throughput".to_string())),
        ("total_jobs", Json::Num(total_jobs as f64)),
        ("baseline_session_jobs_per_s", Json::Num(baseline)),
        (
            "service",
            Json::Arr(
                points
                    .iter()
                    .map(|&(clients, jobs_per_s)| {
                        Json::obj(vec![
                            ("clients", Json::Num(clients as f64)),
                            ("jobs_per_s", Json::Num(jobs_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_vs_session", Json::Num(speedup)),
        (
            "read_heavy",
            Json::obj(vec![
                (
                    "mix",
                    Json::Str(format!("{READS_PER_10}:{} recommend:submit", 10 - READS_PER_10)),
                ),
                ("baseline_session_req_per_s", Json::Num(read_baseline)),
                (
                    "service",
                    Json::Arr(
                        read_points
                            .iter()
                            .map(|&(clients, req_per_s, coalesced)| {
                                Json::obj(vec![
                                    ("clients", Json::Num(clients as f64)),
                                    ("req_per_s", Json::Num(req_per_s)),
                                    ("coalesced_batches", Json::Num(coalesced as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("speedup_vs_session", Json::Num(read_speedup)),
            ]),
        ),
        (
            "write_mix",
            Json::obj(vec![
                (
                    "mix",
                    Json::Str(format!("{}:{READS_PER_10} recommend:submit", 10 - READS_PER_10)),
                ),
                ("baseline_session_req_per_s", Json::Num(write_baseline)),
                (
                    "service",
                    Json::Arr(
                        write_points
                            .iter()
                            .map(|&(clients, req_per_s, coalesced, reused)| {
                                Json::obj(vec![
                                    ("clients", Json::Num(clients as f64)),
                                    ("req_per_s", Json::Num(req_per_s)),
                                    (
                                        "coalesced_write_batches",
                                        Json::Num(coalesced as f64),
                                    ),
                                    (
                                        "featurized_rows_reused",
                                        Json::Num(reused as f64),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("speedup_vs_session", Json::Num(write_speedup)),
            ]),
        ),
        (
            "tracing",
            Json::obj(vec![
                ("on_req_per_s", Json::Num(traced_req_per_s[0])),
                ("off_req_per_s", Json::Num(traced_req_per_s[1])),
                ("overhead_pct", Json::Num(tracing_overhead_pct)),
            ]),
        ),
        (
            "retrain_heavy",
            Json::obj(vec![
                (
                    "mix",
                    Json::Str("1:1 recommend:submit, retrain_every=2".to_string()),
                ),
                (
                    "service",
                    Json::Arr(
                        retrain_points
                            .iter()
                            .map(|&(clients, req_per_s, retrains, reads, writes)| {
                                Json::obj(vec![
                                    ("clients", Json::Num(clients as f64)),
                                    ("req_per_s", Json::Num(req_per_s)),
                                    ("retrains", Json::Num(retrains as f64)),
                                    ("reads_stolen", Json::Num(reads as f64)),
                                    ("writes_stolen", Json::Num(writes as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        ("latency", latency),
    ]);
    std::fs::write("BENCH_serve_throughput.json", json.render() + "\n").unwrap();
    println!("wrote BENCH_serve_throughput.json");
}
