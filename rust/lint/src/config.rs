//! `lint.toml` — the checked-in invariant-zone map and rule tables,
//! parsed by a deliberately minimal TOML-subset reader (sections,
//! string/bool/integer values, and single- or multi-line string arrays;
//! everything this tool needs and nothing more, so the lint crate stays
//! dependency-free like the workspace it checks).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Which invariant zone a top-level module belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Zone {
    /// Bitwise-reproducibility zone: `repo`, `models`, `store`,
    /// `configurator` — anything whose output feeds converged-peer or
    /// cached-vs-scratch equality.
    Deterministic,
    /// Request-serving zone: `api`, `coordinator` — panics are outages,
    /// failures must speak the typed `ApiError` taxonomy.
    Serving,
    /// Everything else (util, sim, cloud, CLI, figures, ...).
    Boundary,
}

impl Zone {
    pub fn name(self) -> &'static str {
        match self {
            Zone::Deterministic => "deterministic",
            Zone::Serving => "serving",
            Zone::Boundary => "boundary",
        }
    }
}

/// Parsed configuration for one run of the analyzer.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Source root the walker scans (resolved relative to the config
    /// file's directory).
    pub root: PathBuf,
    /// module name -> zone (top-level path component under `root`).
    pub zones: BTreeMap<String, Zone>,
    /// Modules the `float-order` rule applies to.
    pub float_order_modules: Vec<String>,
    /// Modules exempt from `no-anyhow-public` (the documented internal
    /// engine layers whose pub surface is folded into `ApiError` at the
    /// boundary).
    pub anyhow_exempt_modules: Vec<String>,
    /// Lock classes, matched by substring against the receiver's
    /// deciding identifier (e.g. class `shard` matches `self.shards[..]`).
    pub lock_classes: Vec<String>,
    /// Allowed nestings: `(outer, inner)` pairs.
    pub lock_order: Vec<(String, String)>,
}

/// All rule identifiers, in reporting order.
pub const RULES: &[&str] = &[
    "hash-iter",
    "float-order",
    "no-panic-serving",
    "no-anyhow-public",
    "lock-discipline",
    "bad-suppression",
];

pub fn is_known_rule(name: &str) -> bool {
    RULES.contains(&name)
}

impl LintConfig {
    /// Parse `lint.toml` at `path`. `root` inside the file is resolved
    /// relative to the file's parent directory.
    pub fn load(path: &Path) -> Result<LintConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {}", path.display(), e))?;
        let table = parse_toml_subset(&text).map_err(|e| format!("{}: {}", path.display(), e))?;
        let base = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        LintConfig::from_table(&table, &base)
    }

    fn from_table(table: &TomlTable, base: &Path) -> Result<LintConfig, String> {
        let root_rel = table
            .string("", "root")
            .ok_or("missing top-level `root` key")?;
        let mut zones = BTreeMap::new();
        for m in table.strings("zones", "deterministic") {
            zones.insert(m, Zone::Deterministic);
        }
        for m in table.strings("zones", "serving") {
            zones.insert(m, Zone::Serving);
        }
        let mut lock_order = Vec::new();
        for entry in table.strings("rules.lock-discipline", "order") {
            let (outer, inner) = entry
                .split_once("->")
                .ok_or_else(|| format!("lock order entry `{entry}` is not `outer -> inner`"))?;
            lock_order.push((outer.trim().to_string(), inner.trim().to_string()));
        }
        Ok(LintConfig {
            root: base.join(root_rel),
            zones,
            float_order_modules: table.strings("rules.float-order", "modules"),
            anyhow_exempt_modules: table.strings("rules.no-anyhow-public", "exempt"),
            lock_classes: table.strings("rules.lock-discipline", "classes"),
            lock_order,
        })
    }

    /// Zone of a top-level module name (`repo`, `api`, `main`, ...).
    pub fn zone_of(&self, module: &str) -> Zone {
        self.zones.get(module).copied().unwrap_or(Zone::Boundary)
    }
}

/// section name (`""` for top level) -> key -> value.
struct TomlTable {
    values: BTreeMap<(String, String), TomlValue>,
}

enum TomlValue {
    Str(String),
    Array(Vec<String>),
    #[allow(dead_code)]
    Other(String),
}

impl TomlTable {
    fn string(&self, section: &str, key: &str) -> Option<String> {
        match self.values.get(&(section.to_string(), key.to_string())) {
            Some(TomlValue::Str(s)) => Some(s.clone()),
            _ => None,
        }
    }
    fn strings(&self, section: &str, key: &str) -> Vec<String> {
        match self.values.get(&(section.to_string(), key.to_string())) {
            Some(TomlValue::Array(v)) => v.clone(),
            _ => Vec::new(),
        }
    }
}

/// Strip a `#` comment that is outside any quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_toml_subset(text: &str) -> Result<TomlTable, String> {
    let mut values = BTreeMap::new();
    let mut section = String::new();
    let mut lines = text.lines().enumerate();
    while let Some((n, raw)) = lines.next() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unclosed section header", n + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, mut value) = line
            .split_once('=')
            .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
            .ok_or_else(|| format!("line {}: expected `key = value`", n + 1))?;
        // Multi-line arrays: keep consuming until brackets balance.
        if value.starts_with('[') {
            while value.matches('[').count() > value.matches(']').count() {
                let (_, next) = lines
                    .next()
                    .ok_or_else(|| format!("line {}: unterminated array", n + 1))?;
                value.push(' ');
                value.push_str(strip_comment(next).trim());
            }
        }
        let parsed = if let Some(inner) = value.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated array", n + 1))?;
            let mut items = Vec::new();
            for item in inner.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    continue;
                }
                items.push(unquote(item).ok_or_else(|| {
                    format!("line {}: array items must be quoted strings", n + 1)
                })?);
            }
            TomlValue::Array(items)
        } else if let Some(s) = unquote(&value) {
            TomlValue::Str(s)
        } else {
            TomlValue::Other(value)
        };
        values.insert((section.clone(), key), parsed);
    }
    Ok(TomlTable { values })
}

fn unquote(s: &str) -> Option<String> {
    let s = s.strip_prefix('"')?.strip_suffix('"')?;
    Some(s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# the zone map
root = "../src"

[zones]
deterministic = ["repo", "models", "store", "configurator"]
serving = ["api", "coordinator"]

[rules.float-order]
modules = ["models", "repo"]

[rules.no-anyhow-public]
exempt = [
    "util",    # utility layer
    "runtime",
]

[rules.lock-discipline]
classes = ["shard", "metrics", "snapshot", "queue", "store"]
order = ["shard -> snapshot", "shard -> store"]
"#;

    #[test]
    fn parses_full_config() {
        let table = parse_toml_subset(SAMPLE).unwrap();
        let cfg = LintConfig::from_table(&table, Path::new("/x/lint")).unwrap();
        assert_eq!(cfg.root, Path::new("/x/lint/../src"));
        assert_eq!(cfg.zone_of("repo"), Zone::Deterministic);
        assert_eq!(cfg.zone_of("api"), Zone::Serving);
        assert_eq!(cfg.zone_of("sim"), Zone::Boundary);
        assert_eq!(cfg.anyhow_exempt_modules, vec!["util", "runtime"]);
        assert_eq!(
            cfg.lock_order,
            vec![
                ("shard".to_string(), "snapshot".to_string()),
                ("shard".to_string(), "store".to_string())
            ]
        );
    }

    #[test]
    fn comments_inside_strings_survive() {
        let table = parse_toml_subset("root = \"a#b\"").unwrap();
        assert_eq!(table.string("", "root").unwrap(), "a#b");
    }

    #[test]
    fn bad_lock_order_entry_is_an_error() {
        let table = parse_toml_subset(
            "root = \"s\"\n[rules.lock-discipline]\norder = [\"shard snapshot\"]",
        )
        .unwrap();
        assert!(LintConfig::from_table(&table, Path::new(".")).is_err());
    }
}
