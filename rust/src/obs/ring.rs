//! Bounded lock-free MPMC ring buffer with overwrite-oldest semantics.
//!
//! The trace collector's hot path: a worker finishing a request
//! `force_push`es its [`super::Trace`] into its lane — no allocation,
//! no mutex — and the service drains lanes with [`Ring::pop`] when a
//! report or export is requested. The queue is the classic
//! Vyukov bounded MPMC design: every slot carries a sequence number
//! that hands the slot back and forth between producers and consumers,
//! so a slot's payload is only ever touched by the thread that won the
//! CAS for it (no seqlock-style torn reads; clean under
//! ThreadSanitizer). When the ring is full, `force_push` pops (and
//! drops) the oldest entry and retries, counting the overwrite — a
//! bounded trace window degrades by forgetting history, never by
//! blocking the serving path.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

struct Slot<T> {
    /// Slot handoff state. `seq == pos`: free for the producer whose
    /// ticket is `pos`; `seq == pos + 1`: holds that producer's value,
    /// free for the matching consumer; consumers release with
    /// `seq = pos + capacity` for the next lap.
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded MPMC queue (power-of-two capacity).
pub struct Ring<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    enq: AtomicUsize,
    deq: AtomicUsize,
    /// Entries discarded by `force_push` because the ring was full.
    lost: AtomicU64,
}

// SAFETY: values move whole between threads through the slot handoff
// protocol above — a slot is written only after winning the enq CAS and
// read only after winning the deq CAS, with release/acquire ordering on
// `seq` fencing the payload access.
unsafe impl<T: Send> Send for Ring<T> {}
unsafe impl<T: Send> Sync for Ring<T> {}

impl<T> Ring<T> {
    /// A ring holding at least `capacity` entries (rounded up to a
    /// power of two, minimum 2).
    pub fn new(capacity: usize) -> Ring<T> {
        let cap = capacity.next_power_of_two().max(2);
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Ring {
            slots,
            mask: cap - 1,
            enq: AtomicUsize::new(0),
            deq: AtomicUsize::new(0),
            lost: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Entries dropped by `force_push` overwrites so far.
    pub fn lost(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Try to enqueue; hands the value back when the ring is full.
    pub fn push(&self, val: T) -> Result<(), T> {
        let mut pos = self.enq.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.enq.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS gives this thread sole
                        // ownership of the slot until the Release below.
                        unsafe { (*slot.val.get()).write(val) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return Err(val); // full: the slot is still a lap behind
            } else {
                pos = self.enq.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue the oldest entry, `None` when empty.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.deq.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos.wrapping_add(1) as isize;
            if dif == 0 {
                match self.deq.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: winning the CAS gives this thread sole
                        // ownership of the initialized slot payload.
                        let val = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq
                            .store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(val);
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                return None; // empty
            } else {
                pos = self.deq.load(Ordering::Relaxed);
            }
        }
    }

    /// Enqueue unconditionally: when the ring is full, drop the oldest
    /// entry and retry. Returns how many entries were discarded (0 on a
    /// clean push), also accumulated in [`Ring::lost`].
    pub fn force_push(&self, val: T) -> u64 {
        let mut val = val;
        let mut dropped = 0u64;
        loop {
            match self.push(val) {
                Ok(()) => {
                    if dropped > 0 {
                        self.lost.fetch_add(dropped, Ordering::Relaxed);
                    }
                    return dropped;
                }
                Err(back) => {
                    val = back;
                    if self.pop().is_some() {
                        dropped += 1;
                    }
                }
            }
        }
    }
}

impl<T> std::fmt::Debug for Ring<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ring")
            .field("capacity", &self.capacity())
            .field("lost", &self.lost())
            .finish()
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_capacity() {
        let ring: Ring<u32> = Ring::new(8);
        assert_eq!(ring.capacity(), 8);
        assert_eq!(ring.pop(), None);
        for i in 0..8 {
            assert!(ring.push(i).is_ok());
        }
        assert_eq!(ring.push(99), Err(99), "full ring rejects plain push");
        for i in 0..8 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert_eq!(ring.pop(), None);
    }

    #[test]
    fn force_push_overwrites_oldest() {
        let ring: Ring<u64> = Ring::new(8);
        let mut dropped = 0;
        for i in 0..20 {
            dropped += ring.force_push(i);
        }
        assert_eq!(dropped, 12, "20 pushes into 8 slots drop the 12 oldest");
        assert_eq!(ring.lost(), 12);
        // what survives is exactly the newest window, still in order
        let drained: Vec<u64> = std::iter::from_fn(|| ring.pop()).collect();
        assert_eq!(drained, (12..20).collect::<Vec<u64>>());
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(Ring::<u8>::new(0).capacity(), 2);
        assert_eq!(Ring::<u8>::new(3).capacity(), 4);
        assert_eq!(Ring::<u8>::new(1024).capacity(), 1024);
    }

    #[test]
    fn drop_releases_remaining_entries() {
        let token = Arc::new(());
        {
            let ring: Ring<Arc<()>> = Ring::new(4);
            for _ in 0..3 {
                ring.force_push(Arc::clone(&token));
            }
            assert_eq!(Arc::strong_count(&token), 4);
        }
        assert_eq!(Arc::strong_count(&token), 1, "drop must free queued entries");
    }
}
