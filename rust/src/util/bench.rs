//! Criterion-style micro/meso benchmark harness for the `harness = false`
//! bench binaries (criterion itself is not in the offline vendor set).
//!
//! Usage in a bench target:
//!
//! ```no_run
//! use c3o::util::bench::Bench;
//! let mut b = Bench::new("fig6_scaleout");
//! b.run("simulate_sort_n4", || { /* workload */ });
//! b.finish();
//! ```
//!
//! Each case is warmed up, then timed for a fixed wall-clock budget; the
//! report prints iteration counts, mean, and p50/p90/p99 latencies, and is
//! also appended to `target/bench_results.csv` for the EXPERIMENTS.md
//! tables.

use std::fs::OpenOptions;
use std::io::Write as _;
use std::time::{Duration, Instant};

/// One measured case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p99_ns: f64,
}

impl CaseResult {
    fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.0} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

/// Benchmark group: collects cases, prints a table, persists CSV rows.
pub struct Bench {
    group: String,
    warmup: Duration,
    budget: Duration,
    results: Vec<CaseResult>,
    extra_cols: Vec<(String, String)>,
}

impl Bench {
    /// New group with default 0.2 s warmup and 1 s measurement budget.
    pub fn new(group: &str) -> Self {
        // Quick mode for smoke runs: C3O_BENCH_QUICK=1 shrinks budgets.
        let quick = std::env::var("C3O_BENCH_QUICK").is_ok();
        Bench {
            group: group.to_string(),
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(200) },
            budget: if quick { Duration::from_millis(100) } else { Duration::from_secs(1) },
            results: Vec::new(),
            extra_cols: Vec::new(),
        }
    }

    /// Override the measurement budget.
    pub fn with_budget(mut self, warmup: Duration, budget: Duration) -> Self {
        self.warmup = warmup;
        self.budget = budget;
        self
    }

    /// Attach a key=value annotation emitted with every CSV row
    /// (e.g. workload parameters).
    pub fn annotate(&mut self, key: &str, value: &str) {
        self.extra_cols.push((key.to_string(), value.to_string()));
    }

    /// Measure a closure. The closure's return value is black-boxed.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &CaseResult {
        // Warmup.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters < 1 {
            black_box(f());
            warm_iters += 1;
        }
        // Estimate per-iter cost to pick a batch size that keeps timer
        // overhead below ~1%.
        let est_ns = (self.warmup.as_nanos() as f64 / warm_iters.max(1) as f64).max(1.0);
        let batch = (100.0 / est_ns * 1000.0).clamp(1.0, 10_000.0) as u64;

        let mut samples: Vec<f64> = Vec::new();
        let mut iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < self.budget {
            let bt = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let per_iter = bt.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(per_iter);
            iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let result = CaseResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: q(0.50),
            p90_ns: q(0.90),
            p99_ns: q(0.99),
        };
        println!(
            "{:<48} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            format!("{}/{}", self.group, name),
            result.iters,
            CaseResult::human(result.mean_ns),
            CaseResult::human(result.p50_ns),
            CaseResult::human(result.p99_ns),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print the summary and append rows to `target/bench_results.csv`.
    pub fn finish(&self) {
        let path = std::path::Path::new("target/bench_results.csv");
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let add_header = !path.exists();
        if let Ok(mut f) = OpenOptions::new().create(true).append(true).open(path) {
            if add_header {
                let _ = writeln!(f, "group,case,iters,mean_ns,p50_ns,p90_ns,p99_ns,annotations");
            }
            let ann = self
                .extra_cols
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(";");
            for r in &self.results {
                let _ = writeln!(
                    f,
                    "{},{},{},{:.1},{:.1},{:.1},{:.1},{}",
                    self.group, r.name, r.iters, r.mean_ns, r.p50_ns, r.p90_ns, r.p99_ns, ann
                );
            }
        }
    }

    /// Access collected results (used by bench binaries that also assert
    /// reproduction claims).
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }
}

/// Opaque value sink that defeats dead-code elimination without `unsafe`.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").with_budget(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let r = b.run("noop_sum", || (0..100u64).sum::<u64>()).clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn human_units() {
        assert_eq!(CaseResult::human(500.0), "500 ns");
        assert_eq!(CaseResult::human(1500.0), "1.50 µs");
        assert_eq!(CaseResult::human(2.5e6), "2.50 ms");
        assert_eq!(CaseResult::human(3.2e9), "3.200 s");
    }
}
