//! Peer delta-sync: convergent runtime-data exchange between
//! independently-running C3O deployments, at **record-level** (op log)
//! granularity.
//!
//! The protocol is three [`crate::api`] requests, all spoken through the
//! deployment-agnostic [`Client`] trait, so any two deployments (two
//! services, a service and a sequential coordinator, ...) can gossip:
//!
//! 1. `Watermarks { job }` — read the local per-org op-log positions
//!    (`(seqno, digest)` [`crate::repo::OrgWatermark`]s).
//! 2. `SyncPull { job, watermarks }` — ask a peer for the ops past each
//!    of our marks; prefix-aligned logs ship **only the changed
//!    records** (O(changed), not O(org corpus)); the reply also carries
//!    the peer's own marks, so one round trip primes the reverse
//!    direction.
//! 3. `SyncPush { job, ops }` — apply a delta through merge-level dedup
//!    with deterministic conflict resolution, then canonicalize the
//!    repo order. Idempotent: re-pushing a delta changes nothing, and a
//!    merge-rejected op still advances the receiver's watermark (logged
//!    as *seen*), so blind duplicate contributions are never re-offered.
//!
//! [`sync_job`] performs one full bidirectional exchange; because merge
//! resolution is a deterministic total order, repeated exchanges drive
//! any set of peers to **bitwise-identical** repositories regardless of
//! gossip order (property-tested in `rust/tests/federation.rs`).
//! [`sync_job_v2`] speaks the legacy org-granular exchange
//! (`SyncPullV2`/`SyncPushV2`) against deployments that predate the op
//! log — kept as the compatibility path and as the comparison baseline
//! of `benches/sync_throughput.rs`. [`SyncDriver`] runs exchanges on a
//! background thread at a fixed interval — the service-side gossip loop.

use crate::api::{ApiError, Client};
use crate::workloads::JobKind;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Counters from one or more sync exchanges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// `SyncPull` round trips issued.
    pub pulls: u64,
    /// Records applied locally (adds + replacements).
    pub records_in: u64,
    /// Records the peer applied from us.
    pub records_out: u64,
    /// Ops shipped over the wire in either direction, applied or not.
    /// With record-level deltas this tracks `records_in + records_out`
    /// except on the first delivery of blind-duplicate history (shipped
    /// once, then marked seen) or after log divergence (the whole-org
    /// fallback, which re-ships until content converges).
    pub offered: u64,
    /// Ops shipped but not applied: already-seen re-deliveries plus
    /// merge-rejected (seen) ops.
    pub skipped: u64,
    /// Runtime disagreements surfaced by either side.
    pub conflicts: u64,
    /// Exchanges that failed (driver keeps going; the next tick retries).
    pub errors: u64,
    /// Wall-time spent inside `SyncPull` round trips, nanoseconds.
    /// Observability only — never feeds a protocol decision.
    pub pull_nanos: u64,
    /// Wall-time spent inside `SyncPush` round trips (which include the
    /// receiver's merge/apply), nanoseconds. Observability only.
    pub push_nanos: u64,
}

impl SyncStats {
    /// Accumulate another stats block.
    pub fn fold(&mut self, other: &SyncStats) {
        self.pulls += other.pulls;
        self.records_in += other.records_in;
        self.records_out += other.records_out;
        self.offered += other.offered;
        self.skipped += other.skipped;
        self.conflicts += other.conflicts;
        self.errors += other.errors;
        self.pull_nanos += other.pull_nanos;
        self.push_nanos += other.push_nanos;
    }

    /// True when the exchange *changed* no repository in either
    /// direction — the peers hold converged (merge-equivalent) data for
    /// the synced jobs.
    pub fn quiescent(&self) -> bool {
        self.records_in == 0 && self.records_out == 0
    }
}

/// Per-organization accounting of one or more exchanges: how many ops
/// of this org's log were offered over the wire, how many the receiver
/// applied, and how many it skipped (seen/duplicate). The
/// `c3o sync --json` breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OrgExchange {
    pub offered: u64,
    pub applied: u64,
    pub skipped: u64,
}

impl OrgExchange {
    /// Accumulate another exchange's counters (rounds, directions).
    pub fn fold(&mut self, other: &OrgExchange) {
        self.offered += other.offered;
        self.applied += other.applied;
        self.skipped += other.skipped;
    }
}

/// Per-org exchange accounting, folded across directions and rounds.
pub type OrgExchangeMap = BTreeMap<String, OrgExchange>;

/// Fold one per-org map into another (the accumulation the driver and
/// the `c3o sync` CLI both perform across rounds).
pub fn fold_orgs(into: &mut OrgExchangeMap, from: &OrgExchangeMap) {
    for (org, x) in from {
        into.entry(org.clone()).or_default().fold(x);
    }
}

/// One direction of a v3 exchange: pull the delta `dst` is missing from
/// `src` (against `dst_marks`, or a fresh `Watermarks` read when
/// `None`), push it into `dst`, account per org — crediting
/// `records_in` when `inbound`, `records_out` otherwise. Returns the
/// source's marks from the pull reply, priming the reverse direction.
fn exchange_direction(
    dst: &mut dyn Client,
    src: &mut dyn Client,
    job: JobKind,
    dst_marks: Option<BTreeMap<String, crate::repo::OrgWatermark>>,
    inbound: bool,
    stats: &mut SyncStats,
    orgs: &mut OrgExchangeMap,
) -> Result<BTreeMap<String, crate::repo::OrgWatermark>, ApiError> {
    let marks = match dst_marks {
        Some(marks) => marks,
        None => dst.watermarks(job)?.watermarks,
    };
    let pull_started = std::time::Instant::now();
    let delta = src.sync_pull(job, marks)?;
    stats.pull_nanos += pull_started.elapsed().as_nanos() as u64;
    stats.pulls += 1;
    let src_marks = delta.watermarks.clone();
    stats.offered += delta.ops.len() as u64;
    for op in &delta.ops {
        orgs.entry(op.org.clone()).or_default().offered += 1;
    }
    if !delta.ops.is_empty() {
        let push_started = std::time::Instant::now();
        let report = dst.sync_push(job, delta.ops)?;
        stats.push_nanos += push_started.elapsed().as_nanos() as u64;
        let applied = if inbound {
            &mut stats.records_in
        } else {
            &mut stats.records_out
        };
        *applied += report.changed() as u64;
        stats.skipped += report.skipped as u64;
        stats.conflicts += report.conflicts.len() as u64;
        for (org, applied) in &report.applied_by_org {
            orgs.entry(org.clone()).or_default().applied += applied;
        }
    }
    Ok(src_marks)
}

/// One full bidirectional exchange for one job kind, with per-org
/// accounting.
///
/// Inbound: read local marks, pull the peer's delta against them, apply
/// it. Outbound: the pull reply carried the peer's marks — compute our
/// delta against those (a local `SyncPull`) and push it, *after* the
/// inbound apply so ops we just learned (that the peer already holds)
/// are not echoed back. Both directions reuse merge's dedup, so the
/// exchange is idempotent; prefix-aligned op logs make each direction
/// O(changed records).
pub fn sync_job_detailed(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<(SyncStats, OrgExchangeMap), ApiError> {
    let mut stats = SyncStats::default();
    let mut orgs = OrgExchangeMap::new();
    let peer_marks =
        exchange_direction(local, peer, job, None, true, &mut stats, &mut orgs)?;
    exchange_direction(peer, local, job, Some(peer_marks), false, &mut stats, &mut orgs)?;
    for x in orgs.values_mut() {
        x.skipped = x.offered.saturating_sub(x.applied);
    }
    Ok((stats, orgs))
}

/// One full bidirectional exchange for one job kind (see
/// [`sync_job_detailed`] for the per-org accounting variant).
pub fn sync_job(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync_job_detailed(local, peer, job).map(|(stats, _)| stats)
}

/// One full bidirectional exchange over the **legacy v2** org-granular
/// protocol (`WatermarksV2`/`SyncPullV2`/`SyncPushV2`): a changed org
/// ships whole, and blind-duplicate holders are re-offered forever.
/// Kept to interoperate with pre-op-log deployments and as the
/// comparison baseline for the record-level path.
pub fn sync_job_v2(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    let mut stats = SyncStats::default();

    let ours = local.watermarks_v2(job)?;
    let delta = peer.sync_pull_v2(job, ours.watermarks)?;
    stats.pulls += 1;
    let peer_marks = delta.watermarks.clone();
    stats.offered += delta.records.len() as u64;
    if !delta.records.is_empty() {
        let report = local.sync_push_v2(job, delta.records)?;
        stats.records_in += report.changed() as u64;
        stats.skipped += report.skipped as u64;
        stats.conflicts += report.conflicts.len() as u64;
    }

    let out = local.sync_pull_v2(job, peer_marks)?;
    stats.pulls += 1;
    stats.offered += out.records.len() as u64;
    if !out.records.is_empty() {
        let report = peer.sync_push_v2(job, out.records)?;
        stats.records_out += report.changed() as u64;
        stats.skipped += report.skipped as u64;
        stats.conflicts += report.conflicts.len() as u64;
    }
    Ok(stats)
}

/// [`sync_job`] over several job kinds, stats folded.
pub fn sync_all(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<SyncStats, ApiError> {
    let mut total = SyncStats::default();
    for &job in jobs {
        total.fold(&sync_job(local, peer, job)?);
    }
    Ok(total)
}

/// [`sync_job_detailed`] over several job kinds: folded stats plus the
/// per-(job, org) breakdown.
pub fn sync_all_detailed(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<(SyncStats, BTreeMap<JobKind, OrgExchangeMap>), ApiError> {
    let mut total = SyncStats::default();
    let mut by_job: BTreeMap<JobKind, OrgExchangeMap> = BTreeMap::new();
    for &job in jobs {
        let (stats, orgs) = sync_job_detailed(local, peer, job)?;
        total.fold(&stats);
        fold_orgs(by_job.entry(job).or_default(), &orgs);
    }
    Ok((total, by_job))
}

/// Background gossip loop: exchanges deltas between a local deployment
/// and a set of peers at a fixed interval, on its own thread.
///
/// The driver holds plain [`Client`] handles (e.g.
/// [`ServiceClient`](crate::coordinator::service::ServiceClient)s), so
/// it composes with any deployment. A failed exchange is counted and
/// retried on the next tick; a peer answering
/// [`ApiError::Stopped`] ends the loop (the deployment is gone).
pub struct SyncDriver {
    stop: mpsc::Sender<()>,
    handle: Option<JoinHandle<SyncStats>>,
}

impl SyncDriver {
    /// Spawn the loop: one immediate round, then one round per
    /// `interval` until [`SyncDriver::stop`].
    pub fn spawn<C: Client + Send + 'static>(
        mut local: C,
        mut peers: Vec<C>,
        jobs: Vec<JobKind>,
        interval: Duration,
    ) -> SyncDriver {
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let handle = std::thread::spawn(move || {
            let mut total = SyncStats::default();
            loop {
                for peer in peers.iter_mut() {
                    for &job in &jobs {
                        match sync_job(&mut local, peer, job) {
                            Ok(stats) => total.fold(&stats),
                            Err(ApiError::Stopped) => return total,
                            Err(_) => total.errors += 1,
                        }
                    }
                }
                match stop_rx.recv_timeout(interval) {
                    // stop requested, or the driver handle is gone
                    Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => return total,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                }
            }
        });
        SyncDriver {
            stop: stop_tx,
            handle: Some(handle),
        }
    }

    /// Stop the loop and return the accumulated stats.
    pub fn stop(mut self) -> SyncStats {
        self.stop_inner()
    }

    fn stop_inner(&mut self) -> SyncStats {
        let _ = self.stop.send(());
        match self.handle.take() {
            Some(handle) => handle.join().unwrap_or_default(),
            None => SyncStats::default(),
        }
    }
}

impl Drop for SyncDriver {
    fn drop(&mut self) {
        self.stop_inner();
    }
}
