//! Shared scoped compute pool for embarrassingly parallel hot loops.
//!
//! The retrain critical section — k-fold CV over both [`ModelKind`]s
//! with every fold trained from scratch — and the all-candidates
//! configurator scan are both embarrassingly parallel, yet ran fully
//! serially inside the shard lock before PR-9. [`ComputePool`] fans
//! such task sets across `min(cores, tasks)` scoped std threads
//! (no external dependency, no rayon) and reassembles the results in
//! **task-index order**, so the reduction the caller performs over the
//! returned `Vec` visits results in exactly the order the serial loop
//! would have produced them.
//!
//! # Determinism contract
//!
//! [`ComputePool::map_ordered`] guarantees: given pure tasks (no
//! shared mutable state, no ambient randomness), the returned vector
//! is **bitwise-identical** to running the same closures serially in
//! index order. Parallelism only changes *when* a task runs, never
//! *what* it computes or *where* its result lands. Callers that need
//! deterministic floating-point reductions simply fold the returned
//! vector in order — the summation order is then fixed regardless of
//! thread count, permit availability, or scheduling. This is
//! property-tested across thread counts 1/2/8 in `tests/proptests.rs`.
//!
//! # Sharing and sizing
//!
//! One pool is shared by all service workers. It does not own
//! long-lived threads; instead it owns a *permit budget* equal to its
//! configured width. Each `map_ordered` call borrows up to
//! `min(permits_available, tasks)` permits, spawns that many scoped
//! helper threads for the duration of the call, and returns the
//! permits afterwards. Concurrent callers therefore degrade gracefully
//! toward inline serial execution (zero permits → the caller computes
//! everything itself) instead of oversubscribing the machine — and the
//! serial fallback is bitwise-identical by the contract above, so
//! permit races never affect results.
//!
//! # Lock discipline
//!
//! The pool's internal task queue lock (`pool_tasks`) is leaf-level:
//! no other c3o lock is ever taken while it is held. Shard callers
//! acquire `shard` first and the pool second (`shard -> pool` in
//! `rust/lint/lint.toml`).
//!
//! [`ModelKind`]: crate::models::ModelKind

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

/// A width-bounded scoped worker pool with deterministic ordered
/// collection. See the module docs for the determinism contract.
#[derive(Debug)]
pub struct ComputePool {
    threads: usize,
    /// Helper-thread permits currently available across all callers.
    permits: AtomicUsize,
}

impl ComputePool {
    /// A pool that will use at most `threads` helper threads across
    /// all concurrent callers. Width is floored at 1; a width-1 pool
    /// always computes inline (serially).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        ComputePool {
            threads,
            permits: AtomicUsize::new(threads),
        }
    }

    /// A pool sized to the machine's available parallelism.
    pub fn with_default_parallelism() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Configured width (maximum helper threads).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Try to borrow up to `want` permits; returns how many were
    /// actually acquired (possibly 0).
    fn acquire_permits(&self, want: usize) -> usize {
        let mut got = 0usize;
        let _ = self.permits.fetch_update(Ordering::AcqRel, Ordering::Acquire, |avail| {
            got = avail.min(want);
            Some(avail - got)
        });
        got
    }

    fn release_permits(&self, n: usize) {
        self.permits.fetch_add(n, Ordering::AcqRel);
    }

    /// Run `tasks` (possibly in parallel) and return their results in
    /// task-index order — bitwise-identical to running them serially.
    pub fn map_ordered<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        self.map_ordered_timed(tasks).0
    }

    /// [`map_ordered`](ComputePool::map_ordered) plus the caller's
    /// collection-wait time in nanoseconds — the `Stage::PoolWait`
    /// span: how long the caller sat waiting on helper threads after
    /// finishing its own share of the work (0 for serial execution).
    pub fn map_ordered_timed<T, F>(&self, tasks: Vec<F>) -> (Vec<T>, u64)
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = tasks.len();
        if n <= 1 || self.threads <= 1 {
            return (tasks.into_iter().map(|f| f()).collect(), 0);
        }
        // A caller never needs more helpers than tasks, and leaves one
        // logical slot for itself only implicitly: helpers do all the
        // work here so the index bookkeeping stays trivial.
        let helpers = self.acquire_permits(self.threads.min(n));
        if helpers == 0 {
            return (tasks.into_iter().map(|f| f()).collect(), 0);
        }

        let indexed: Vec<(usize, F)> = tasks.into_iter().enumerate().collect();
        // Leaf lock (class `pool`): helpers pop the next task under it
        // and compute outside it; no other lock is taken while held.
        let pool_tasks = Mutex::new(indexed.into_iter());
        let (tx, rx) = mpsc::channel::<(usize, T)>();

        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let mut wait_nanos = 0u64;

        std::thread::scope(|scope| {
            for _ in 0..helpers {
                let tx = tx.clone();
                let pool_tasks = &pool_tasks;
                scope.spawn(move || loop {
                    let next = pool_tasks.lock().expect("pool lock poisoned").next();
                    match next {
                        Some((i, f)) => {
                            // a dropped receiver just means the caller
                            // panicked; nothing to unwind here
                            let _ = tx.send((i, f()));
                        }
                        None => break,
                    }
                });
            }
            drop(tx); // the clones in the helpers keep the channel open
            let t0 = Instant::now();
            for (i, v) in rx {
                out[i] = Some(v);
            }
            wait_nanos = t0.elapsed().as_nanos() as u64;
        });
        self.release_permits(helpers);

        let out = out
            .into_iter()
            .map(|v| v.expect("every task index sends exactly once"))
            .collect();
        (out, wait_nanos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_land_in_task_index_order() {
        let pool = ComputePool::new(4);
        let tasks: Vec<_> = (0..32usize).map(|i| move || i * i).collect();
        let out = pool.map_ordered(tasks);
        assert_eq!(out, (0..32usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn width_one_pool_is_serial_inline() {
        let pool = ComputePool::new(1);
        let tasks: Vec<_> = (0..8usize).map(|i| move || i + 1).collect();
        let (out, wait) = pool.map_ordered_timed(tasks);
        assert_eq!(out, (1..=8usize).collect::<Vec<_>>());
        assert_eq!(wait, 0, "serial execution reports zero pool wait");
    }

    #[test]
    fn zero_width_request_floors_at_one() {
        let pool = ComputePool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.map_ordered(vec![|| 7]), vec![7]);
    }

    #[test]
    fn float_reduction_is_bitwise_identical_to_serial() {
        // the reduction the CV fan relies on: fold the ordered results
        // in index order and compare bits against the serial loop
        let vals: Vec<f64> = (0..100).map(|i| 1.0 / (i as f64 + 3.0)).collect();
        let serial: f64 = vals.iter().sum();
        for width in [1usize, 2, 8] {
            let pool = ComputePool::new(width);
            let tasks: Vec<_> = vals.iter().map(|&v| move || v).collect();
            let out = pool.map_ordered(tasks);
            // c3o-lint: allow(float-order) — in-order fold over index-ordered results
            let parallel: f64 = out.iter().sum();
            assert_eq!(serial.to_bits(), parallel.to_bits(), "width {width}");
        }
    }

    #[test]
    fn permits_are_returned_after_a_call() {
        let pool = ComputePool::new(3);
        for _ in 0..5 {
            let tasks: Vec<_> = (0..10usize).map(|i| move || i).collect();
            pool.map_ordered(tasks);
        }
        assert_eq!(pool.permits.load(Ordering::Acquire), 3);
    }

    #[test]
    fn exhausted_permits_fall_back_to_inline_serial() {
        let pool = ComputePool::new(2);
        let drained = pool.acquire_permits(2);
        assert_eq!(drained, 2);
        let tasks: Vec<_> = (0..6usize).map(|i| move || i * 2).collect();
        let (out, wait) = pool.map_ordered_timed(tasks);
        assert_eq!(out, (0..6usize).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(wait, 0);
        pool.release_permits(drained);
    }

    #[test]
    fn tasks_run_exactly_once() {
        static RUNS: AtomicUsize = AtomicUsize::new(0);
        let pool = ComputePool::new(8);
        let tasks: Vec<_> = (0..64usize)
            .map(|i| {
                move || {
                    RUNS.fetch_add(1, Ordering::Relaxed);
                    i
                }
            })
            .collect();
        let out = pool.map_ordered(tasks);
        assert_eq!(out.len(), 64);
        assert_eq!(RUNS.load(Ordering::Relaxed), 64);
    }
}
