//! The durable segment store: a per-job append-only WAL plus atomic
//! snapshots, so a coordinator recovers its full corpus on startup.
//!
//! On-disk layout, one directory per [`JobKind`] under the store root:
//!
//! ```text
//! <root>/<job>/
//!   snap-00000000000000000126.csv   # atomic snapshot at generation 126
//!   wal-000003.log                  # segment: one checksummed op/line
//!   wal-000004.log                  # current segment
//! ```
//!
//! * **WAL lines.** Every repository mutation is one line:
//!   `gen,op,seq,job,org,machine,scaleout,features,runtime,checksum`.
//!   `gen` is the repo generation *after* the op; `seq` is the op's
//!   per-organization sequence number in the repo's operation log
//!   ([`crate::repo`]) — the same numbering the sync protocol ships, so
//!   recovery and sync replay one shared log; `op` is `C` (blind
//!   contribute), `M` (merge-applied add-or-replace), `S` (sync op seen
//!   but merge-rejected: advances the org log and watermark, the
//!   generation does not move), or `K` (canonical reorder, no content
//!   change). The trailing FNV-1a checksum makes a torn tail write
//!   detectable on recovery. Legacy (PR-3 format) lines without the
//!   `seq` field still parse: replay assigns the sequence numbers,
//!   which is deterministic because replay order is.
//! * **Segments** rotate at [`JobStore::with_segment_cap`] lines, so
//!   compaction never rewrites unbounded history.
//! * **Snapshots** are whole-repo CSVs written to a temp file and
//!   `rename`d into place (atomic on POSIX), with the generation in the
//!   file name, paired with an `oplog-<gen>.csv` sidecar persisting the
//!   per-org operation logs (which the holdings alone cannot
//!   reconstruct: replaced and seen-but-rejected ops live only there)
//!   and — when any org has an acked-floor truncation horizon — a
//!   `floor-<gen>.csv` sidecar of per-org `(floor, floor_digest)`
//!   pairs. A truncated org's oplog rows hold only the retained suffix
//!   (first seqno = floor + 1); the folded prefix exists solely as the
//!   holdings plus the floor digest, which is exactly the repo's
//!   in-memory shape after [`crate::repo::RuntimeDataRepo::truncate_org_log`].
//!   [`JobStore::compact`] writes all of these and deletes every
//!   segment — each op they held is ≤ the snapshot generation. A legacy
//!   snapshot without sidecars still recovers: the logs are rebuilt
//!   from the holdings with floor 0 (losing reject/replace history,
//!   which at worst degrades the org to the v2 whole-org sync
//!   fallback).
//! * **Recovery** ([`JobStore::open`]) loads the newest snapshot (and
//!   its oplog sidecar), then replays segments in order, skipping ops
//!   the snapshot already covers. A checksum-failing or newline-less
//!   final line is tolerated as a crash-torn tail (and the store
//!   rotates to a fresh segment so it never appends after torn bytes);
//!   corruption anywhere else is a hard error. Replay re-applies ops
//!   through the same `contribute`/`merge_records`/seen code the live
//!   write path uses, and cross-checks every line's generation and
//!   sequence stamps, so a recovered repo is bitwise-identical to the
//!   pre-crash one — including record order and org-log positions.
//!
//! **Durability scope.** Under the default [`FsyncPolicy::Never`],
//! appends flush to the OS (surviving process crashes, the failure
//! mode of the simulated substrate) but do not fsync per batch, so an
//! OS/power failure can lose the tail of the page cache.
//! [`FsyncPolicy::PerBatch`] ([`StoreConfig::fsync_policy`], or
//! [`JobStore::with_fsync_policy`]) additionally fsyncs the segment
//! file after every appended batch, extending the guarantee to power
//! failures at a per-write syscall cost. [`FsyncPolicy::EveryN`] sits
//! between the two: a group-commit mode that fsyncs once every N
//! appended batches (and always before a segment rotation closes the
//! file), bounding power-failure loss to the last `< N` batches while
//! amortizing the syscall. [`FsyncPolicy::Interval`] is the
//! wall-clock analogue: a batch fsyncs when at least the configured
//! duration has passed since the last fsync (and always before a
//! rotation), bounding power-failure loss to one interval of batches.
//! Snapshots are always fsynced before the rename publishes them (plus
//! a best-effort directory sync).
//!
//! **Error taxonomy.** The four pub entry points — [`JobStore::open`],
//! [`JobStore::append`], [`JobStore::compact`],
//! [`JobStore::maybe_compact`] — fail with [`ApiError::Store`]; the
//! `anyhow` context chains live only in the private `*_inner`
//! implementations and are folded exactly once at this boundary
//! (`no-anyhow-public` in `rust/lint`).

use crate::api::ApiError;
use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::util::csv;
use crate::util::hash::fnv1a64;
use crate::workloads::JobKind;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::fs;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};

/// Default WAL lines per segment before rotation.
pub const DEFAULT_SEGMENT_CAP: usize = 256;
/// Default un-snapshotted ops before [`JobStore::maybe_compact`] fires.
pub const DEFAULT_COMPACT_THRESHOLD: usize = 1024;

/// One durable repository mutation, as logged to (and replayed from)
/// the WAL. Record-bearing ops carry the per-org sequence number the
/// repository's operation log assigned — `seqno == 0` only on lines
/// parsed from a legacy (PR-3 format) WAL, where replay assigns it.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreOp {
    /// Blind append — the contribute path. Replay re-contributes, so
    /// locally-observed duplicate configurations survive recovery.
    /// Advances the generation.
    Contribute { seqno: u64, record: RuntimeRecord },
    /// Merge-applied record (an add or a deterministic-winner
    /// replacement). Replay re-merges, reproducing the same slot.
    /// Advances the generation.
    Merge { seqno: u64, record: RuntimeRecord },
    /// Sync op *seen* but merge-rejected: advances the org's operation
    /// log (and thus its watermark) without touching the holdings —
    /// the generation does not move. Logged so a restarted deployment
    /// never re-pulls (or re-offers) ops it already saw.
    Seen { seqno: u64, record: RuntimeRecord },
    /// Canonical reordering of the whole repo (content unchanged, the
    /// generation does not move). Logged so recovery reproduces record
    /// *order* bitwise, not just content.
    Canonicalize,
}

/// When appended WAL batches are forced to stable storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// Flush each batch to the OS only (the default, and the store's
    /// historical behavior): appends survive process crashes; an
    /// OS/power failure can lose the page-cache tail, which recovery
    /// tolerates as a torn tail.
    #[default]
    Never,
    /// `fsync` the segment file after every appended batch: appends
    /// survive power failures too, at one extra syscall per write
    /// batch.
    PerBatch,
    /// Group commit: `fsync` once every N appended batches, and always
    /// before a rotation closes the segment. Power-failure loss is
    /// bounded to the trailing `< N` un-synced batches (recovered as a
    /// torn tail); the syscall cost is amortized N-fold. `EveryN(0)`
    /// and `EveryN(1)` behave like [`FsyncPolicy::PerBatch`].
    EveryN(usize),
    /// Timer-based group commit: a batch `fsync`s when at least this
    /// duration has passed since the last fsync (the first batch after
    /// open/compaction always syncs), and any un-synced tail settles
    /// before a rotation closes the segment. Power-failure loss is
    /// bounded to one interval's worth of batches; the syscall rate is
    /// capped at one per interval regardless of write rate.
    /// `Interval(Duration::ZERO)` behaves like
    /// [`FsyncPolicy::PerBatch`].
    Interval(std::time::Duration),
}

/// Deployment knobs for a [`JobStore`], applied at
/// [`JobStore::open_with_config`] time.
#[derive(Debug, Clone, Copy, Default)]
pub struct StoreConfig {
    /// When appended batches are forced to stable storage.
    pub fsync_policy: FsyncPolicy,
}

/// Append-only, generation-stamped record log for one job kind, with
/// atomic snapshot + segment compaction.
pub struct JobStore {
    dir: PathBuf,
    job: JobKind,
    /// Repo generation after the last appended op (mirrors the owning
    /// repo; cross-checked on every append).
    generation: u64,
    /// Generation covered by the newest on-disk snapshot.
    snapshot_generation: u64,
    /// Ops applied since the last snapshot (the compaction trigger).
    pending: usize,
    seg_ordinal: u64,
    seg_records: usize,
    writer: Option<BufWriter<fs::File>>,
    segment_cap: usize,
    compact_threshold: usize,
    fsync_policy: FsyncPolicy,
    /// Batches appended since the last fsync (drives
    /// [`FsyncPolicy::EveryN`] group commit, and tells rotation whether
    /// an un-synced tail must settle for the timer policy too).
    unsynced_batches: usize,
    /// When the segment file last fsynced (drives
    /// [`FsyncPolicy::Interval`]; `None` = sync on the next batch).
    last_fsync: Option<std::time::Instant>,
    /// Wall-time spent writing WAL bytes since the last
    /// [`JobStore::take_io_nanos`] drain. Observability only.
    append_nanos: u64,
    /// Wall-time spent in `fsync` since the last drain.
    fsync_nanos: u64,
}

impl JobStore {
    /// Open (or create) the store for `job` under `root` and recover
    /// its repository: newest snapshot + WAL replay. Failures surface
    /// as [`ApiError::Store`] with the full context chain rendered.
    pub fn open(root: &Path, job: JobKind) -> Result<(JobStore, RuntimeDataRepo), ApiError> {
        Self::open_inner(root, job).map_err(ApiError::store)
    }

    /// [`JobStore::open`] with explicit [`StoreConfig`] knobs.
    pub fn open_with_config(
        root: &Path,
        job: JobKind,
        config: StoreConfig,
    ) -> Result<(JobStore, RuntimeDataRepo), ApiError> {
        let (store, repo) = Self::open_inner(root, job).map_err(ApiError::store)?;
        Ok((store.with_fsync_policy(config.fsync_policy), repo))
    }

    fn open_inner(root: &Path, job: JobKind) -> Result<(JobStore, RuntimeDataRepo)> {
        let dir = root.join(job.name());
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;

        let mut snaps: Vec<(u64, PathBuf)> = Vec::new();
        let mut oplogs: Vec<(u64, PathBuf)> = Vec::new();
        let mut floors_files: Vec<(u64, PathBuf)> = Vec::new();
        let mut segs: Vec<(u64, PathBuf)> = Vec::new();
        for entry in
            fs::read_dir(&dir).with_context(|| format!("reading {}", dir.display()))?
        {
            let entry = entry.with_context(|| format!("reading {}", dir.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(gen) = name
                .strip_prefix("snap-")
                .and_then(|s| s.strip_suffix(".csv"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                snaps.push((gen, entry.path()));
            } else if let Some(gen) = name
                .strip_prefix("oplog-")
                .and_then(|s| s.strip_suffix(".csv"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                oplogs.push((gen, entry.path()));
            } else if let Some(gen) = name
                .strip_prefix("floor-")
                .and_then(|s| s.strip_suffix(".csv"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                floors_files.push((gen, entry.path()));
            } else if let Some(ord) = name
                .strip_prefix("wal-")
                .and_then(|s| s.strip_suffix(".log"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                segs.push((ord, entry.path()));
            }
            // anything else (snap.tmp from an interrupted compaction,
            // foreign files) is ignored
        }
        snaps.sort();
        segs.sort();

        // 1) newest snapshot, if any, plus its op-log sidecar
        let (mut repo, snap_gen) = match snaps.last() {
            None => (RuntimeDataRepo::new(job), 0u64),
            Some((gen, path)) => {
                let table = csv::Table::load(path)
                    .map_err(|e| anyhow!("loading snapshot {}: {e}", path.display()))?;
                let repo = RuntimeDataRepo::from_table(job, &table)
                    .map_err(anyhow::Error::msg)
                    .with_context(|| format!("parsing snapshot {}", path.display()))?;
                ensure!(
                    *gen >= repo.generation(),
                    "snapshot {} names generation {gen} but holds {} records",
                    path.display(),
                    repo.len()
                );
                let mut repo = repo;
                repo.restore_generation(*gen);
                // the sidecar carries the true op logs (incl. replaced
                // and seen-but-rejected history); a legacy snapshot
                // without one keeps the holdings-rebuilt logs, which at
                // worst degrades affected orgs to the v2 sync fallback.
                // A floor sidecar (absent on pre-v4 and never-truncated
                // stores) pre-seeds the folded prefix positions so the
                // oplog rows — the retained suffix — stack on top.
                if let Some((_, oplog_path)) =
                    oplogs.iter().find(|(oplog_gen, _)| oplog_gen == gen)
                {
                    let floors = match floors_files
                        .iter()
                        .find(|(floor_gen, _)| floor_gen == gen)
                    {
                        None => std::collections::BTreeMap::new(),
                        Some((_, floor_path)) => load_floors(floor_path)?,
                    };
                    let logs = load_oplog(job, oplog_path, &floors)?;
                    repo.restore_org_logs(floors, logs)
                        .map_err(anyhow::Error::msg)
                        .with_context(|| format!("restoring {}", oplog_path.display()))?;
                }
                (repo, *gen)
            }
        };

        // 2) replay segments in order
        let mut pending = 0usize;
        let mut torn_tail = false;
        let mut last_seg_lines = 0usize;
        let nsegs = segs.len();
        for (si, (_ord, path)) in segs.iter().enumerate() {
            let text = fs::read_to_string(path)
                .with_context(|| format!("reading segment {}", path.display()))?;
            let last_seg = si + 1 == nsegs;
            if last_seg && !text.is_empty() && !text.ends_with('\n') {
                // the final line was cut before its newline; even if its
                // content happens to parse, never append after it
                torn_tail = true;
            }
            let lines: Vec<&str> = text.lines().collect();
            let nlines = lines.len();
            if last_seg {
                // remembered so the append path knows how full the
                // segment is without re-reading it
                last_seg_lines = lines.iter().filter(|l| !l.is_empty()).count();
            }
            for (li, line) in lines.iter().enumerate() {
                if line.is_empty() {
                    continue;
                }
                let last_line = last_seg && li + 1 == nlines;
                match parse_wal_line(job, line) {
                    Err(e) => {
                        if last_line {
                            // crash-torn tail: the op never fully landed
                            torn_tail = true;
                            break;
                        }
                        bail!(
                            "corrupt WAL line {} in {}: {e:#}",
                            li + 1,
                            path.display()
                        );
                    }
                    Ok((gen, op)) => {
                        let applied = apply_wal_op(&mut repo, snap_gen, gen, op)
                            .with_context(|| {
                                format!("replaying {} line {}", path.display(), li + 1)
                            })?;
                        if applied {
                            pending += 1;
                        }
                    }
                }
            }
        }

        let last_ord = segs.last().map(|(ord, _)| *ord).unwrap_or(0);
        let (seg_ordinal, seg_records) = if torn_tail || segs.is_empty() {
            (last_ord + 1, 0)
        } else {
            // continue the last segment (its line count bounds rotation)
            (last_ord.max(1), last_seg_lines)
        };

        let store = JobStore {
            dir,
            job,
            generation: repo.generation(),
            snapshot_generation: snap_gen,
            pending,
            seg_ordinal,
            seg_records,
            writer: None,
            segment_cap: DEFAULT_SEGMENT_CAP,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
            fsync_policy: FsyncPolicy::default(),
            unsynced_batches: 0,
            last_fsync: None,
            append_nanos: 0,
            fsync_nanos: 0,
        };
        Ok((store, repo))
    }

    /// Override the per-segment line cap (tests, benches).
    pub fn with_segment_cap(mut self, cap: usize) -> Self {
        self.segment_cap = cap.max(1);
        self
    }

    /// Override the auto-compaction threshold (tests, benches).
    pub fn with_compact_threshold(mut self, threshold: usize) -> Self {
        self.compact_threshold = threshold.max(1);
        self
    }

    /// Override when appended batches are forced to stable storage.
    pub fn with_fsync_policy(mut self, policy: FsyncPolicy) -> Self {
        self.fsync_policy = policy;
        self
    }

    /// The store's current fsync policy.
    pub fn fsync_policy(&self) -> FsyncPolicy {
        self.fsync_policy
    }

    /// Drain the wall-time the store spent writing WAL bytes and in
    /// `fsync` since the last drain, as `(append_nanos, fsync_nanos)`.
    /// Observability only — the owning shard folds these into its
    /// per-stage trace scratch; nothing durable depends on them.
    pub fn take_io_nanos(&mut self) -> (u64, u64) {
        (
            std::mem::take(&mut self.append_nanos),
            std::mem::take(&mut self.fsync_nanos),
        )
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    /// Directory this job's segments and snapshots live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Repo generation after the last appended op.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Generation covered by the newest snapshot (0 if none yet).
    pub fn snapshot_generation(&self) -> u64 {
        self.snapshot_generation
    }

    /// Ops appended (or replayed) since the last snapshot.
    pub fn pending_ops(&self) -> usize {
        self.pending
    }

    /// Durably log a batch of ops. `repo_generation_after` is the owning
    /// repository's generation after the batch — the store stamps each
    /// op itself and cross-checks the result, so a store/repo desync is
    /// an error instead of silent corruption.
    pub fn append(&mut self, ops: &[StoreOp], repo_generation_after: u64) -> Result<(), ApiError> {
        self.append_inner(ops, repo_generation_after).map_err(ApiError::store)
    }

    fn append_inner(&mut self, ops: &[StoreOp], repo_generation_after: u64) -> Result<()> {
        // Render against a local generation cursor: nothing in the
        // store's state moves until the batch is fully written, so a
        // rejected or failed append leaves the mirror exactly where it
        // was (no compounding drift across retries).
        let mut gen = self.generation;
        let mut lines = String::new();
        for op in ops {
            let line = render_op(self.job, &mut gen, op)?;
            lines.push_str(&line);
            lines.push('\n');
        }
        ensure!(
            gen == repo_generation_after,
            "store/repo generation desync after append: store {gen}, repo {repo_generation_after}"
        );
        if ops.is_empty() {
            return Ok(());
        }
        if self.seg_records >= self.segment_cap {
            self.rotate()?;
        }
        let fsync = self.fsync_policy;
        let write_started = std::time::Instant::now();
        let writer = self.writer()?;
        writer.write_all(lines.as_bytes())?;
        writer.flush()?;
        self.append_nanos += write_started.elapsed().as_nanos() as u64;
        let sync_now = match fsync {
            FsyncPolicy::Never => false,
            FsyncPolicy::PerBatch => true,
            // group commit: every Nth batch settles the whole group
            FsyncPolicy::EveryN(n) => {
                self.unsynced_batches += 1;
                self.unsynced_batches >= n.max(1)
            }
            // timer-based group commit: the first batch after open or
            // compaction always syncs (last_fsync is None), then batches
            // ride until the interval elapses
            FsyncPolicy::Interval(d) => {
                self.unsynced_batches += 1;
                self.last_fsync.map_or(true, |t| t.elapsed() >= d)
            }
        };
        if sync_now {
            let sync_started = std::time::Instant::now();
            self.writer()?
                .get_ref()
                .sync_all()
                .context("fsyncing WAL segment after batch")?;
            self.fsync_nanos += sync_started.elapsed().as_nanos() as u64;
            self.unsynced_batches = 0;
            self.last_fsync = Some(std::time::Instant::now());
        }
        self.generation = gen;
        self.seg_records += ops.len();
        self.pending += ops.len();
        Ok(())
    }

    /// Write an atomic snapshot of `repo` — the holdings CSV plus the
    /// `oplog-<gen>.csv` op-log sidecar (and, when any org log is
    /// truncated, the `floor-<gen>.csv` sidecar), each temp file +
    /// rename — then delete every segment and superseded
    /// snapshot/sidecar: all their ops are ≤ the snapshot generation.
    /// Sidecars are published FIRST (floor, then oplog, then snapshot):
    /// a crash between renames leaves orphan sidecars and no new
    /// snapshot, so recovery falls back to the previous snapshot +
    /// still-present segments at full fidelity (orphan sidecars are
    /// ignored — they pair by exact generation). Publishing in the
    /// other order would be the real hazard: a snapshot without its
    /// sidecars silently drops replaced/seen op-log history or
    /// misreads a truncated suffix as a from-genesis log.
    pub fn compact(&mut self, repo: &RuntimeDataRepo) -> Result<(), ApiError> {
        self.compact_inner(repo).map_err(ApiError::store)
    }

    /// [`JobStore::compact`] for a repo whose generation moved WITHOUT
    /// WAL appends — snapshot adoption and op-log truncation rebase the
    /// repo's history in place, so the store adopts the repo's position
    /// instead of demanding an exact match, then snapshots as usual.
    /// The repo may only be ahead: a behind-the-store repo is still a
    /// desync bug.
    pub fn compact_rebased(&mut self, repo: &RuntimeDataRepo) -> Result<(), ApiError> {
        if repo.generation() < self.generation {
            return Err(ApiError::store(anyhow!(
                "rebased compaction against a stale repo: store {}, repo {}",
                self.generation,
                repo.generation()
            )));
        }
        self.generation = repo.generation();
        self.compact_inner(repo).map_err(ApiError::store)
    }

    fn compact_inner(&mut self, repo: &RuntimeDataRepo) -> Result<()> {
        ensure!(
            repo.generation() == self.generation,
            "compacting against a desynced repo: store {}, repo {}",
            self.generation,
            repo.generation()
        );
        let gen = self.generation;
        // floor sidecar first: the oplog rows for a truncated org hold
        // only the retained suffix, which is meaningless without the
        // folded-prefix position underneath it. Written only when some
        // org actually has a floor — never-truncated stores keep the
        // pre-v4 two-file layout byte for byte.
        let floors = repo.log_floors();
        let floor_path = if floors.is_empty() {
            None
        } else {
            let path = self.dir.join(format!("floor-{gen:020}.csv"));
            write_atomic(
                &self.dir,
                "floor.tmp",
                &path,
                floors_table(&floors).to_csv().as_bytes(),
            )?;
            Some(path)
        };
        let oplog_path = self.dir.join(format!("oplog-{gen:020}.csv"));
        write_atomic(
            &self.dir,
            "oplog.tmp",
            &oplog_path,
            oplog_table(repo).to_csv().as_bytes(),
        )?;
        let final_path = self.dir.join(format!("snap-{gen:020}.csv"));
        write_atomic(
            &self.dir,
            "snap.tmp",
            &final_path,
            repo.to_table().to_csv().as_bytes(),
        )?;
        // best-effort directory sync so the renames themselves are
        // durable (not supported on every platform; recovery tolerates a
        // lost rename by falling back to the previous snapshot + segments)
        if let Ok(dir_handle) = fs::File::open(&self.dir) {
            let _ = dir_handle.sync_all();
        }
        // drop the open segment handle before unlinking segments; any
        // un-synced group-commit tail is superseded by the (fsynced)
        // snapshot published above
        self.writer = None;
        self.unsynced_batches = 0;
        self.last_fsync = None;
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let superseded_snap = name.starts_with("snap-")
                && name.ends_with(".csv")
                && entry.path() != final_path;
            let superseded_oplog = name.starts_with("oplog-")
                && name.ends_with(".csv")
                && entry.path() != oplog_path;
            let superseded_floor = name.starts_with("floor-")
                && name.ends_with(".csv")
                && floor_path.as_deref() != Some(entry.path().as_path());
            let segment = name.starts_with("wal-") && name.ends_with(".log");
            if superseded_snap || superseded_oplog || superseded_floor || segment {
                fs::remove_file(entry.path())
                    .with_context(|| format!("removing {}", name))?;
            }
        }
        self.seg_ordinal += 1;
        self.seg_records = 0;
        self.pending = 0;
        self.snapshot_generation = gen;
        Ok(())
    }

    /// Compact when the un-snapshotted op count crosses the threshold.
    /// Returns whether a compaction ran.
    pub fn maybe_compact(&mut self, repo: &RuntimeDataRepo) -> Result<bool, ApiError> {
        if self.pending >= self.compact_threshold {
            self.compact_inner(repo).map_err(ApiError::store)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn rotate(&mut self) -> Result<()> {
        // group commit promised durability no worse than N batches
        // behind; settle the un-synced tail before the handle closes
        if self.unsynced_batches > 0 {
            if let Some(w) = &mut self.writer {
                let sync_started = std::time::Instant::now();
                w.get_ref()
                    .sync_all()
                    .context("fsyncing WAL segment before rotation")?;
                self.fsync_nanos += sync_started.elapsed().as_nanos() as u64;
            }
            self.unsynced_batches = 0;
        }
        self.writer = None; // BufWriter flushed on every append already
        self.seg_ordinal += 1;
        self.seg_records = 0;
        Ok(())
    }

    fn writer(&mut self) -> Result<&mut BufWriter<fs::File>> {
        if self.writer.is_none() {
            let path = self.dir.join(format!("wal-{:06}.log", self.seg_ordinal));
            let file = fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening segment {}", path.display()))?;
            self.writer = Some(BufWriter::new(file));
        }
        Ok(self.writer.as_mut().expect("just set"))
    }

}

/// fsync-then-rename publication of one file (the snapshot discipline).
fn write_atomic(dir: &Path, tmp_name: &str, final_path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(tmp_name);
    {
        let mut file =
            fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        file.write_all(bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        // published files supersede segments, so they must actually be
        // on disk before the rename
        file.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
    }
    fs::rename(&tmp, final_path)
        .with_context(|| format!("publishing {}", final_path.display()))
}

/// The six record fields in the one text form both the WAL and the
/// op-log sidecar use — `(job, org, machine, scaleout, ';'-joined
/// features, runtime)` with `{}` float formatting. ONE serializer, so
/// the bitwise round-trip invariant cannot drift between the formats.
fn record_to_fields(r: &RuntimeRecord) -> [String; 6] {
    [
        r.job.name().to_string(),
        r.org.clone(),
        r.machine.clone(),
        r.scaleout.to_string(),
        r.job_features
            .iter()
            .map(|f| format!("{f}"))
            .collect::<Vec<_>>()
            .join(";"),
        format!("{}", r.runtime_s),
    ]
}

/// Inverse of [`record_to_fields`]: parse six fields (job, org,
/// machine, scaleout, features, runtime) back into a record of `job`.
fn record_from_fields(job: JobKind, fields: &[String]) -> Result<RuntimeRecord> {
    ensure!(fields.len() == 6, "expected 6 record fields, got {}", fields.len());
    ensure!(
        fields[0] == job.name(),
        "foreign job {:?} in {} store",
        fields[0],
        job.name()
    );
    let job_features: Vec<f64> = if fields[4].is_empty() {
        Vec::new()
    } else {
        fields[4]
            .split(';')
            .map(|s| s.parse::<f64>().map_err(|_| anyhow!("bad feature {s:?}")))
            .collect::<Result<_>>()?
    };
    Ok(RuntimeRecord {
        job,
        org: fields[1].clone(),
        machine: fields[2].clone(),
        scaleout: fields[3].parse().context("bad scaleout")?,
        job_features,
        runtime_s: fields[5]
            .parse()
            .map_err(|_| anyhow!("bad runtime {:?}", fields[5]))?,
    })
}

const OPLOG_HEADER: [&str; 7] = [
    "seqno", "job", "org", "machine", "scaleout", "features", "runtime_s",
];

/// Op-log sidecar schema: one row per org-log entry — the seqno
/// followed by the shared [`record_to_fields`] columns — grouped per
/// org in sequence order.
fn oplog_table(repo: &RuntimeDataRepo) -> csv::Table {
    let mut t = csv::Table::new(&OPLOG_HEADER);
    for org in repo.watermarks().keys() {
        for op in repo.ops_since(org, 0) {
            let mut row = vec![op.seqno.to_string()];
            row.extend(record_to_fields(&op.record));
            t.push(row);
        }
    }
    t
}

const FLOOR_HEADER: [&str; 3] = ["org", "floor", "floor_digest"];

/// Floor sidecar schema: one row per truncated org — the folded-prefix
/// length and the genesis-cumulative digest it carries. Orgs absent
/// from the file (and stores without one) have floor 0: full history.
fn floors_table(floors: &std::collections::BTreeMap<String, (u64, u64)>) -> csv::Table {
    let mut t = csv::Table::new(&FLOOR_HEADER);
    for (org, (floor, digest)) in floors {
        t.push(vec![org.clone(), floor.to_string(), digest.to_string()]);
    }
    t
}

/// Parse a floor sidecar back into org → (floor, floor_digest).
fn load_floors(path: &Path) -> Result<std::collections::BTreeMap<String, (u64, u64)>> {
    let table = csv::Table::load(path)
        .map_err(|e| anyhow!("loading floor sidecar {}: {e}", path.display()))?;
    ensure!(
        table.header == FLOOR_HEADER,
        "unrecognized floor-sidecar schema in {}: {:?}",
        path.display(),
        table.header
    );
    let mut floors: std::collections::BTreeMap<String, (u64, u64)> = Default::default();
    for (i, row) in table.rows.iter().enumerate() {
        let line = i + 2; // 1-based, after the header
        ensure!(row.len() == 3, "{} line {line}: expected 3 fields", path.display());
        let floor: u64 = row[1]
            .parse()
            .with_context(|| format!("{} line {line}: bad floor", path.display()))?;
        let digest: u64 = row[2]
            .parse()
            .with_context(|| format!("{} line {line}: bad floor digest", path.display()))?;
        ensure!(floor >= 1, "{} line {line}: floor 0 row (would be implicit)", path.display());
        ensure!(
            floors.insert(row[0].clone(), (floor, digest)).is_none(),
            "{} line {line}: duplicate org {:?}",
            path.display(),
            row[0]
        );
    }
    Ok(floors)
}

/// Parse an op-log sidecar back into per-org record sequences (each
/// org's rows must be contiguous seqnos in order, starting right above
/// the org's floor — exactly what [`oplog_table`] writes).
fn load_oplog(
    job: JobKind,
    path: &Path,
    floors: &std::collections::BTreeMap<String, (u64, u64)>,
) -> Result<std::collections::BTreeMap<String, Vec<RuntimeRecord>>> {
    let table = csv::Table::load(path)
        .map_err(|e| anyhow!("loading op log {}: {e}", path.display()))?;
    ensure!(
        table.header == OPLOG_HEADER,
        "unrecognized op-log schema in {}: {:?}",
        path.display(),
        table.header
    );
    let mut logs: std::collections::BTreeMap<String, Vec<RuntimeRecord>> = Default::default();
    for (i, row) in table.rows.iter().enumerate() {
        let line = i + 2; // 1-based, after the header
        let seqno: u64 = row[0]
            .parse()
            .with_context(|| format!("{} line {line}: bad seqno", path.display()))?;
        let record = record_from_fields(job, &row[1..])
            .with_context(|| format!("{} line {line}", path.display()))?;
        let floor = floors.get(&record.org).map_or(0, |(f, _)| *f);
        let log = logs.entry(record.org.clone()).or_default();
        ensure!(
            seqno == floor + log.len() as u64 + 1,
            "{} line {line}: op log gap for {:?} (seqno {seqno} after {})",
            path.display(),
            record.org,
            floor + log.len() as u64
        );
        log.push(record);
    }
    Ok(logs)
}

/// Render one op to its sealed WAL line, advancing the caller's
/// generation cursor for holdings-mutating ops (pure with respect to
/// the store — [`JobStore::append`] commits the cursor only after the
/// batch hits the file).
fn render_op(job: JobKind, gen: &mut u64, op: &StoreOp) -> Result<String> {
    let fields = match op {
        StoreOp::Contribute { seqno, record: r }
        | StoreOp::Merge { seqno, record: r }
        | StoreOp::Seen { seqno, record: r } => {
            // defense in depth: RuntimeRecord::validate already rejects
            // these at every ingress, but a framing break would corrupt
            // the WAL, so re-check at the last line of defense
            ensure!(
                framing_safe(&r.org) && framing_safe(&r.machine),
                "org/machine may not contain newlines (WAL framing): {:?}/{:?}",
                r.org,
                r.machine
            );
            ensure!(
                r.job == job,
                "{} record appended to {} store",
                r.job.name(),
                job.name()
            );
            ensure!(*seqno >= 1, "record op without an assigned seqno");
            let code = match op {
                StoreOp::Contribute { .. } => "C",
                StoreOp::Merge { .. } => "M",
                _ => "S",
            };
            if code != "S" {
                *gen += 1; // seen ops never move the generation
            }
            let mut fields = vec![gen.to_string(), code.to_string(), seqno.to_string()];
            fields.extend(record_to_fields(r));
            fields
        }
        StoreOp::Canonicalize => vec![
            gen.to_string(),
            "K".to_string(),
            "0".to_string(),
            job.name().to_string(),
            String::new(),
            String::new(),
            "0".to_string(),
            String::new(),
            "0".to_string(),
        ],
    };
    let body = csv::render_line(&fields);
    let sum = fnv1a64(body.as_bytes());
    Ok(format!("{body},{sum:016x}"))
}

fn framing_safe(s: &str) -> bool {
    !s.contains('\n') && !s.contains('\r')
}

/// Parse one sealed WAL line back into its generation stamp and op.
/// Accepts both the op-log format (9-field body with `seq`) and the
/// legacy PR-3 format (8-field body without it); legacy record ops come
/// back with `seqno == 0`, meaning "assign during replay".
fn parse_wal_line(job: JobKind, line: &str) -> Result<(u64, StoreOp)> {
    let (body, sum_hex) = line.rsplit_once(',').context("missing checksum")?;
    let sum = u64::from_str_radix(sum_hex, 16).context("bad checksum field")?;
    ensure!(sum == fnv1a64(body.as_bytes()), "checksum mismatch");
    let fields = csv::parse_line(body).map_err(|e| anyhow!("bad WAL row: {e}"))?;
    let (seqno, rest) = match fields.len() {
        9 => (
            fields[2].parse::<u64>().context("bad seqno")?,
            &fields[3..],
        ),
        8 => (0u64, &fields[2..]), // legacy PR-3 line: no seq field
        n => bail!("expected 8 (legacy) or 9 fields, got {n}"),
    };
    let gen: u64 = fields[0].parse().context("bad generation")?;
    let op = match fields[1].as_str() {
        "K" => StoreOp::Canonicalize,
        code @ ("C" | "M" | "S") => {
            ensure!(
                code != "S" || fields.len() == 9,
                "seen op in a legacy-format WAL line"
            );
            let record = record_from_fields(job, rest)?;
            match code {
                "C" => StoreOp::Contribute { seqno, record },
                "M" => StoreOp::Merge { seqno, record },
                _ => StoreOp::Seen { seqno, record },
            }
        }
        other => bail!("unknown WAL op {other:?}"),
    };
    Ok((gen, op))
}

/// Replay one op against the recovering repo. Ops the snapshot already
/// covers are skipped; everything else must advance the generation (and
/// its org's log) in exact sequence. Returns whether the op was applied.
fn apply_wal_op(
    repo: &mut RuntimeDataRepo,
    snap_gen: u64,
    gen: u64,
    op: StoreOp,
) -> Result<bool> {
    match op {
        StoreOp::Contribute { seqno, record } => {
            if gen <= snap_gen {
                return Ok(false);
            }
            ensure!(
                gen == repo.generation() + 1,
                "WAL generation gap: line stamped {gen}, repo at {}",
                repo.generation()
            );
            let assigned = repo.contribute(record).map_err(anyhow::Error::msg)?;
            ensure!(
                seqno == 0 || seqno == assigned,
                "WAL seqno gap: line stamped {seqno}, log assigned {assigned}"
            );
            Ok(true)
        }
        StoreOp::Merge { seqno, record } => {
            if gen <= snap_gen {
                return Ok(false);
            }
            ensure!(
                gen == repo.generation() + 1,
                "WAL generation gap: line stamped {gen}, repo at {}",
                repo.generation()
            );
            let out = repo
                .merge_records(std::slice::from_ref(&record))
                .map_err(anyhow::Error::msg)?;
            ensure!(
                out.changed() == 1,
                "WAL merge line replayed as a no-op at generation {gen}"
            );
            let assigned = out.applied[0].seqno;
            ensure!(
                seqno == 0 || seqno == assigned,
                "WAL seqno gap: line stamped {seqno}, log assigned {assigned}"
            );
            Ok(true)
        }
        StoreOp::Seen { seqno, record } => {
            // seen ops never move the generation, so coverage is decided
            // by the op's own position in the (snapshot-restored) log
            let len = repo.log_len(&record.org);
            if seqno <= len {
                return Ok(false); // covered by the oplog sidecar
            }
            ensure!(
                seqno == len + 1,
                "WAL seen-op gap: line stamped {seqno}, {} log at {len}",
                record.org
            );
            repo.replay_seen(record).map_err(anyhow::Error::msg)?;
            Ok(true)
        }
        StoreOp::Canonicalize => {
            if gen < snap_gen {
                return Ok(false);
            }
            ensure!(
                gen == repo.generation(),
                "canonicalize stamped {gen} but repo is at {}",
                repo.generation()
            );
            repo.canonicalize();
            Ok(true)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(org: &str, scaleout: u32, gb: f64, runtime: f64) -> RuntimeRecord {
        RuntimeRecord {
            job: JobKind::Sort,
            org: org.into(),
            machine: "m5.xlarge".into(),
            scaleout,
            job_features: vec![gb],
            runtime_s: runtime,
        }
    }

    fn temp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "c3o_segstore_{}_{name}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    /// Drive a (repo, store) pair through the contribute motion a shard
    /// performs.
    fn contribute(repo: &mut RuntimeDataRepo, store: &mut JobStore, r: RuntimeRecord) {
        let seqno = repo.contribute(r.clone()).unwrap();
        store
            .append(&[StoreOp::Contribute { seqno, record: r }], repo.generation())
            .unwrap();
    }

    /// Drive a (repo, store) pair through a merge that must change the
    /// repo, WAL-framing the applied op.
    fn merge(repo: &mut RuntimeDataRepo, store: &mut JobStore, r: RuntimeRecord) {
        let out = repo.merge_records(std::slice::from_ref(&r)).unwrap();
        assert_eq!(out.changed(), 1, "test op must change the repo");
        let op = &out.applied[0];
        store
            .append(
                &[StoreOp::Merge {
                    seqno: op.seqno,
                    record: op.record.clone(),
                }],
                repo.generation(),
            )
            .unwrap();
    }

    fn canonicalize(repo: &mut RuntimeDataRepo, store: &mut JobStore) {
        repo.canonicalize();
        store
            .append(&[StoreOp::Canonicalize], repo.generation())
            .unwrap();
    }

    #[test]
    fn append_and_reopen_round_trip() {
        let root = temp_store("round_trip");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 100.0));
        merge(&mut repo, &mut store, rec("b", 8, 10.0, 60.0));
        canonicalize(&mut repo, &mut store);
        drop(store);

        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records(), "bitwise incl. order");
        assert_eq!(repo2.generation(), repo.generation());
        assert_eq!(repo2.watermarks(), repo.watermarks(), "op logs recover");
        assert_eq!(store2.generation(), repo.generation());
        assert_eq!(store2.pending_ops(), 3);
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn per_batch_fsync_recovers_bitwise() {
        let root = temp_store("per_batch_fsync");
        let config = StoreConfig {
            fsync_policy: FsyncPolicy::PerBatch,
        };
        let (mut store, mut repo) =
            JobStore::open_with_config(&root, JobKind::Sort, config).unwrap();
        assert_eq!(store.fsync_policy(), FsyncPolicy::PerBatch);
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 100.0));
        merge(&mut repo, &mut store, rec("b", 8, 10.0, 60.0));
        canonicalize(&mut repo, &mut store);
        drop(store);

        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records(), "bitwise incl. order");
        assert_eq!(repo2.generation(), repo.generation());
        assert_eq!(repo2.watermarks(), repo.watermarks());
        assert_eq!(store2.generation(), repo.generation());
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn every_n_fsync_recovers_bitwise() {
        let root = temp_store("every_n_fsync");
        let config = StoreConfig {
            fsync_policy: FsyncPolicy::EveryN(3),
        };
        let (store, mut repo) =
            JobStore::open_with_config(&root, JobKind::Sort, config).unwrap();
        assert_eq!(store.fsync_policy(), FsyncPolicy::EveryN(3));
        // a small segment cap forces a mid-stream rotation, exercising
        // the settle-before-close fsync of the group-commit tail
        let mut store = store.with_segment_cap(4);
        for i in 0..7u32 {
            contribute(
                &mut repo,
                &mut store,
                rec("a", 2 + i, 10.0 + f64::from(i), 100.0),
            );
        }
        merge(&mut repo, &mut store, rec("b", 8, 10.0, 60.0));
        canonicalize(&mut repo, &mut store);
        let (append_ns, fsync_ns) = store.take_io_nanos();
        assert!(append_ns > 0, "append wall-time accumulates");
        assert!(fsync_ns > 0, "group commit fsynced at least once");
        assert_eq!(store.take_io_nanos(), (0, 0), "drain resets the clocks");
        drop(store);

        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records(), "bitwise incl. order");
        assert_eq!(repo2.generation(), repo.generation());
        assert_eq!(repo2.watermarks(), repo.watermarks());
        assert_eq!(store2.generation(), repo.generation());
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn interval_fsync_recovers_bitwise() {
        let root = temp_store("interval_fsync");
        // a long interval: after the always-synced first batch, every
        // later batch rides the timer and only rotation settles it
        let config = StoreConfig {
            fsync_policy: FsyncPolicy::Interval(std::time::Duration::from_secs(3600)),
        };
        let (store, mut repo) =
            JobStore::open_with_config(&root, JobKind::Sort, config).unwrap();
        assert_eq!(
            store.fsync_policy(),
            FsyncPolicy::Interval(std::time::Duration::from_secs(3600))
        );
        let mut store = store.with_segment_cap(4);
        for i in 0..7u32 {
            contribute(
                &mut repo,
                &mut store,
                rec("a", 2 + i, 10.0 + f64::from(i), 100.0),
            );
        }
        merge(&mut repo, &mut store, rec("b", 8, 10.0, 60.0));
        canonicalize(&mut repo, &mut store);
        let (append_ns, fsync_ns) = store.take_io_nanos();
        assert!(append_ns > 0, "append wall-time accumulates");
        assert!(fsync_ns > 0, "first batch + rotation tail fsynced");
        drop(store);

        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records(), "bitwise incl. order");
        assert_eq!(repo2.generation(), repo.generation());
        assert_eq!(repo2.watermarks(), repo.watermarks());
        assert_eq!(store2.generation(), repo.generation());

        // an elapsed interval syncs on the very next batch
        let config = StoreConfig {
            fsync_policy: FsyncPolicy::Interval(std::time::Duration::ZERO),
        };
        let (mut store3, mut repo3) =
            JobStore::open_with_config(&root, JobKind::Sort, config).unwrap();
        contribute(&mut repo3, &mut store3, rec("c", 16, 4.0, 30.0));
        let (_, fsync_ns) = store3.take_io_nanos();
        assert!(fsync_ns > 0, "a zero interval degenerates to per-batch");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn floored_store_compacts_and_reopens_bitwise() {
        let root = temp_store("floored");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        for i in 0..4u32 {
            contribute(&mut repo, &mut store, rec("a", 2 + i, 10.0 + f64::from(i), 100.0));
        }
        contribute(&mut repo, &mut store, rec("b", 8, 10.0, 60.0));

        // fold a's first three ops; the repo rebases without WAL lines,
        // so durability goes through the rebased compaction path
        assert_eq!(repo.truncate_org_log("a", 3), 3);
        store.compact_rebased(&repo).unwrap();
        assert_eq!(repo.log_floor("a"), 3);
        assert_eq!(repo.log_len("a"), 4, "suffix survives the fold");
        drop(store);

        let (_store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records(), "bitwise incl. order");
        assert_eq!(repo2.generation(), repo.generation());
        assert_eq!(repo2.watermarks(), repo.watermarks(), "floors recover");
        assert_eq!(repo2.log_floor("a"), 3);
        assert_eq!(repo2.log_floor("b"), 0);
        assert_eq!(
            repo2.retained_log_entries(),
            repo.retained_log_entries(),
            "only the unacked suffix is held in memory after reopen"
        );
        // the recovered suffix still serves deltas: a peer at the floor
        // pulls ops, a fresh peer falls back to the whole-org snapshot
        let at_floor = crate::repo::OrgWatermark {
            seqno: 3,
            digest: repo.log_digest_at("a", 3).unwrap(),
            floor: 0,
        };
        let plan = repo2.delta_plan(&std::collections::BTreeMap::from([(
            "a".to_string(),
            at_floor,
        )]));
        assert_eq!(plan.ops.iter().filter(|op| op.org == "a").count(), 1);
        let plan = repo2.delta_plan(&std::collections::BTreeMap::new());
        assert!(plan.ops.iter().all(|op| op.org != "a"));
        assert_eq!(plan.snapshots.len(), 1, "below-floor pull → org snapshot");

        // further appends after reopen extend the floored log cleanly
        let (mut store3, mut repo3) = JobStore::open(&root, JobKind::Sort).unwrap();
        contribute(&mut repo3, &mut store3, rec("a", 32, 50.0, 200.0));
        assert_eq!(repo3.log_len("a"), 5);
        drop(store3);
        let (_store4, repo4) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo4.records(), repo3.records());
        assert_eq!(repo4.watermarks(), repo3.watermarks());
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn seen_ops_recover_the_watermark_without_moving_the_generation() {
        let root = temp_store("seen");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 100.0));
        // a peer pushes the blind-duplicate history of org "p": the
        // winner applies, the loser is seen-but-rejected
        let ops = vec![
            crate::repo::SyncOp {
                org: "p".into(),
                seqno: 1,
                record: rec("p", 4, 10.0, 90.0),
            },
            crate::repo::SyncOp {
                org: "p".into(),
                seqno: 2,
                record: rec("p", 4, 10.0, 95.0),
            },
        ];
        let out = repo.apply_sync_ops(&ops).unwrap();
        assert_eq!(out.changed(), 1, "the 90.0 replaces, the 95.0 is seen");
        let store_ops: Vec<StoreOp> = out
            .logged
            .iter()
            .map(|l| {
                if l.applied {
                    StoreOp::Merge {
                        seqno: l.seqno,
                        record: l.record.clone(),
                    }
                } else {
                    StoreOp::Seen {
                        seqno: l.seqno,
                        record: l.record.clone(),
                    }
                }
            })
            .collect();
        store.append(&store_ops, repo.generation()).unwrap();
        assert_eq!(repo.generation(), 2);
        assert_eq!(repo.log_len("p"), 2, "both ops seen");
        drop(store);

        let (_store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records());
        assert_eq!(repo2.generation(), 2, "seen op did not move the generation");
        assert_eq!(
            repo2.watermarks(),
            repo.watermarks(),
            "the seen op's watermark advance survives restart"
        );
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn compaction_supersedes_segments() {
        let root = temp_store("compact");
        let (store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        let mut store = store.with_segment_cap(2);
        for i in 0..5u32 {
            contribute(&mut repo, &mut store, rec("a", 2 + i, 10.0 + i as f64, 100.0));
        }
        store.compact(&repo).unwrap();
        assert_eq!(store.pending_ops(), 0);
        assert_eq!(store.snapshot_generation(), 5);
        let names: Vec<String> = fs::read_dir(store.dir())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(names.iter().all(|n| !n.starts_with("wal-")), "{names:?}");
        assert_eq!(names.iter().filter(|n| n.starts_with("snap-")).count(), 1);
        assert_eq!(
            names.iter().filter(|n| n.starts_with("oplog-")).count(),
            1,
            "the op-log sidecar is published with the snapshot: {names:?}"
        );

        // appends continue after compaction; reopen sees snapshot + tail
        contribute(&mut repo, &mut store, rec("a", 9, 21.0, 90.0));
        drop(store);
        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records());
        assert_eq!(repo2.generation(), 6);
        assert_eq!(repo2.watermarks(), repo.watermarks());
        assert_eq!(store2.pending_ops(), 1, "only the post-snapshot op is pending");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn oplog_sidecar_preserves_replaced_and_seen_history_across_compaction() {
        let root = temp_store("sidecar");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        // blind duplicate by org "a" (both logged, holdings dedup later),
        // then a merge replacement by org "b"
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 100.0));
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 95.0));
        merge(&mut repo, &mut store, rec("b", 4, 10.0, 80.0));
        store.compact(&repo).unwrap();
        drop(store);

        // the WAL is gone; only snapshot + sidecar remain — yet the op
        // logs (incl. the replaced duplicate history) must recover, or a
        // restarted peer would be re-offered org "a" forever
        let (_store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records());
        assert_eq!(repo2.log_len("a"), 2, "replaced history recovered");
        assert_eq!(repo2.log_len("b"), 1);
        assert_eq!(repo2.watermarks(), repo.watermarks());
        assert!(
            repo2.delta_for(&repo.watermarks()).is_empty()
                && repo.delta_for(&repo2.watermarks()).is_empty(),
            "restart is invisible to peers"
        );
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn torn_tail_is_ignored_and_never_appended_after() {
        let root = temp_store("torn");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 100.0));
        contribute(&mut repo, &mut store, rec("a", 8, 10.0, 60.0));
        drop(store);

        // simulate a crash mid-append: half a line, no newline
        let seg = fs::read_dir(root.join("sort"))
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| p.to_string_lossy().contains("wal-"))
            .unwrap();
        let mut bytes = fs::read(&seg).unwrap();
        bytes.extend_from_slice(b"3,C,3,sort,org-x,m5.xl");
        fs::write(&seg, bytes).unwrap();

        let (mut store2, mut repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.len(), 2, "complete records survive, torn op is dropped");
        assert_eq!(repo2.generation(), 2);

        // new appends land in a fresh segment, then everything recovers
        contribute(&mut repo2, &mut store2, rec("b", 2, 12.0, 200.0));
        drop(store2);
        let (_store3, repo3) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo3.records(), repo2.records());
        assert_eq!(repo3.generation(), 3);
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn corruption_before_the_tail_is_a_hard_error() {
        let root = temp_store("corrupt");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        contribute(&mut repo, &mut store, rec("a", 4, 10.0, 100.0));
        contribute(&mut repo, &mut store, rec("a", 8, 10.0, 60.0));
        drop(store);
        let seg = fs::read_dir(root.join("sort"))
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| p.to_string_lossy().contains("wal-"))
            .unwrap();
        let text = fs::read_to_string(&seg).unwrap();
        // flip a byte in the FIRST line: mid-file corruption, not a torn tail
        let mangled = text.replacen("m5.xlarge", "m5.xlargX", 1);
        assert_ne!(text, mangled);
        fs::write(&seg, mangled).unwrap();
        let err = JobStore::open(&root, JobKind::Sort).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn legacy_pr3_wal_lines_replay_with_assigned_seqnos() {
        // hand-build a PR-3 format segment (8-field body, no seq) and
        // recover it with the current reader: records and generation
        // must come back bitwise, with seqnos assigned in replay order
        let root = temp_store("legacy");
        let dir = root.join("sort");
        fs::create_dir_all(&dir).unwrap();
        let mut wal = String::new();
        for body in [
            "1,C,sort,org-a,m5.xlarge,4,10.5,100",
            "2,C,sort,org-a,m5.xlarge,4,10.5,90",
            "3,M,sort,org-b,m5.xlarge,8,11,80",
            "3,K,sort,,,0,,0",
        ] {
            let sum = fnv1a64(body.as_bytes());
            wal.push_str(&format!("{body},{sum:016x}\n"));
        }
        fs::write(dir.join("wal-000001.log"), wal).unwrap();

        let (store, repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo.len(), 3);
        assert_eq!(repo.generation(), 3);
        assert_eq!(store.generation(), 3);
        assert_eq!(repo.log_len("org-a"), 2, "legacy replay assigns seqnos");
        assert_eq!(repo.log_len("org-b"), 1);
        // the canonicalize replayed: blind duplicates ordered by runtime
        assert_eq!(repo.records()[0].runtime_s, 90.0);
        assert_eq!(repo.records()[1].runtime_s, 100.0);
        assert_eq!(repo.records()[2].org, "org-b");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn merge_replacements_replay_bitwise() {
        let root = temp_store("replace");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        contribute(&mut repo, &mut store, rec("z", 4, 10.0, 100.0));
        // a deterministic-winner replacement (smaller runtime) + reorder
        merge(&mut repo, &mut store, rec("a", 4, 10.0, 90.0));
        canonicalize(&mut repo, &mut store);
        assert_eq!(repo.len(), 1);
        assert_eq!(repo.generation(), 2, "replacement advanced the generation");
        drop(store);
        let (_s, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records());
        assert_eq!(repo2.generation(), 2);
        assert_eq!(repo2.watermarks(), repo.watermarks());
        let _ = fs::remove_dir_all(root);
    }
}
