#!/usr/bin/env python3
"""Diff a fresh BENCH_serve_throughput.json against the committed baseline.

Usage: bench_trend.py BASELINE.json CURRENT.json [EXTRA.json ...]

Prints a throughput comparison table for CI trend reporting. Exits
nonzero only on a gross regression (current < REGRESSION_FLOOR x
baseline) so ordinary CI-runner jitter never blocks a merge; the
uploaded artifact carries the precise numbers.

A baseline with {"placeholder": true} records that no reference numbers
have been committed yet: the script then just prints the current run and
succeeds. Refresh the baseline by copying a representative run's
BENCH_serve_throughput.json over the .baseline.json file.

EXTRA files are additional BENCH_*.json outputs without a committed
baseline (e.g. BENCH_sync_throughput.json): each is summarized,
report-only. The sync_throughput schema gets a dedicated table; anything
else is pretty-printed.
"""

import json
import sys

REGRESSION_FLOOR = 0.5


def report_extra(path):
    with open(path) as f:
        doc = json.load(f)
    print(f"\n--- {path} (report-only, no baseline) ---")
    if doc.get("bench") == "sync_throughput":
        replay = doc.get("replay", {})
        sync = doc.get("sync", {})
        incremental = doc.get("incremental", {})
        print(f"{'metric':<42} {'value':>14}")
        rows = [
            ("records", doc.get("records")),
            ("replay WAL (records/s)", replay.get("wal_records_per_s")),
            ("replay snapshot (records/s)", replay.get("snapshot_records_per_s")),
            ("sync exchange (records/s)", sync.get("records_per_s")),
            ("sync records exchanged", sync.get("records_exchanged")),
            ("sync pulls", sync.get("pulls")),
            ("sync conflicts", sync.get("conflicts")),
            ("1-of-N incremental: v3 records shipped", incremental.get("v3_records_shipped")),
            ("1-of-N incremental: v2 records shipped", incremental.get("v2_records_shipped")),
            ("1-of-N incremental: v2/v3 ship ratio", incremental.get("ship_ratio_v2_over_v3")),
        ]
        for label, value in rows:
            if value is not None:
                print(f"{label:<42} {float(value):>14.1f}")
    else:
        print(json.dumps(doc, indent=2))


def service_points(doc, section=None, key="jobs_per_s"):
    node = doc.get(section, {}) if section else doc
    return {int(p["clients"]): float(p[key]) for p in node.get("service", [])}


def main():
    if len(sys.argv) < 3:
        sys.exit(__doc__)
    with open(sys.argv[1]) as f:
        base = json.load(f)
    with open(sys.argv[2]) as f:
        cur = json.load(f)
    extras = sys.argv[3:]

    if base.get("placeholder"):
        print("baseline is a placeholder — reporting current numbers only")
        print(json.dumps(cur, indent=2))
        print(
            "\nTo start trend-diffing, commit this run as "
            "BENCH_serve_throughput.baseline.json"
        )
        for path in extras:
            report_extra(path)
        return

    failures = []

    def compare(label, base_v, cur_v):
        ratio = cur_v / base_v if base_v else float("inf")
        flag = ""
        if ratio < REGRESSION_FLOOR:
            flag = "  << REGRESSION"
            failures.append(label)
        print(f"{label:<42} {base_v:>10.1f} {cur_v:>10.1f} {ratio:>7.2f}x{flag}")

    print(f"{'metric':<42} {'baseline':>10} {'current':>10} {'ratio':>8}")
    compare(
        "write-heavy session 1 client (jobs/s)",
        float(base["baseline_session_jobs_per_s"]),
        float(cur["baseline_session_jobs_per_s"]),
    )
    base_svc = service_points(base)
    cur_svc = service_points(cur)
    for clients in sorted(base_svc):
        if clients in cur_svc:
            compare(
                f"write-heavy service {clients} clients (jobs/s)",
                base_svc[clients],
                cur_svc[clients],
            )

    if "read_heavy" in base and "read_heavy" in cur:
        compare(
            "read-heavy session 1 client (req/s)",
            float(base["read_heavy"]["baseline_session_req_per_s"]),
            float(cur["read_heavy"]["baseline_session_req_per_s"]),
        )
        base_r = service_points(base, "read_heavy", "req_per_s")
        cur_r = service_points(cur, "read_heavy", "req_per_s")
        for clients in sorted(base_r):
            if clients in cur_r:
                compare(
                    f"read-heavy service {clients} clients (req/s)",
                    base_r[clients],
                    cur_r[clients],
                )

    for path in extras:
        report_extra(path)

    if failures:
        sys.exit(f"gross throughput regression (< {REGRESSION_FLOOR}x baseline): {failures}")
    print("\nno gross regression")


if __name__ == "__main__":
    main()
