//! The cluster configurator — the decision core of C3O (paper Fig. 2).
//!
//! Given a job (with dataset characteristics and parameters), a runtime
//! target, and a trained prediction model, the configurator enumerates
//! every candidate (machine type × scale-out) configuration the cloud
//! offers, predicts each one's runtime, prices it under the cloud's
//! billing policy, and returns the **cheapest configuration whose
//! predicted runtime meets the target** (falling back to the fastest
//! configuration when no candidate meets it). With no target it simply
//! minimizes cost.
//!
//! It also implements the Fig. 3 analysis: per-algorithm cost-efficiency
//! **ranking of machine types**, which the paper observes to be largely
//! scale-out-invariant — enabling the two-stage heuristic of fixing the
//! machine type first and then choosing the scale-out.

use crate::api::ApiError;
use crate::cloud::Cloud;
use crate::models::{QueryBatch, RuntimeModel};
use crate::util::json::Json;
use crate::workloads::{JobKind, JobSpec};

/// A user's request: the job plus constraints (paper Fig. 1 "job inputs:
/// dataset, parameters, runtime target").
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub spec: JobSpec,
    /// Runtime target in seconds (None = just minimize cost).
    pub target_s: Option<f64>,
}

impl JobRequest {
    pub fn new(spec: JobSpec) -> Self {
        JobRequest {
            spec,
            target_s: None,
        }
    }

    pub fn sort(data_gb: f64) -> Self {
        Self::new(JobSpec::sort(data_gb))
    }
    pub fn grep(data_gb: f64, ratio: f64) -> Self {
        Self::new(JobSpec::grep(data_gb, ratio))
    }
    pub fn sgd(data_gb: f64, iters: u32) -> Self {
        Self::new(JobSpec::sgd(data_gb, iters))
    }
    pub fn kmeans(data_gb: f64, k: u32, conv: f64) -> Self {
        Self::new(JobSpec::kmeans(data_gb, k, conv))
    }
    pub fn pagerank(graph_mb: f64, conv: f64) -> Self {
        Self::new(JobSpec::pagerank(graph_mb, conv))
    }

    /// Attach a runtime target. The builder never panics: an invalid
    /// target (zero, negative, NaN, infinite) is stored as-is and
    /// rejected by [`JobRequest::validate`] at the API boundary, surfaced
    /// as [`ApiError::InvalidRequest`].
    pub fn with_target_seconds(mut self, target: f64) -> Self {
        self.target_s = Some(target);
        self
    }

    pub fn kind(&self) -> JobKind {
        self.spec.kind()
    }

    /// Validate the request before it touches any shared state: the
    /// runtime target (if any) must be a positive finite number of
    /// seconds, and every job feature must be finite. Every deployment
    /// validates at submission/recommendation time.
    pub fn validate(&self) -> Result<(), ApiError> {
        if let Some(t) = self.target_s {
            if !(t.is_finite() && t > 0.0) {
                return Err(ApiError::InvalidRequest(format!(
                    "runtime target must be a positive finite number of seconds, got {t}"
                )));
            }
        }
        let features = self.spec.job_features();
        if let Some(bad) = features.iter().find(|f| !f.is_finite()) {
            return Err(ApiError::InvalidRequest(format!(
                "non-finite job feature {bad} in {:?} request",
                self.kind().name()
            )));
        }
        Ok(())
    }
}

/// One evaluated candidate configuration.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub machine: String,
    pub scaleout: u32,
    pub predicted_runtime_s: f64,
    pub predicted_cost_usd: f64,
    pub meets_target: bool,
}

impl Candidate {
    /// JSON projection (stable key order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machine", Json::Str(self.machine.clone())),
            ("scaleout", Json::Num(self.scaleout as f64)),
            ("predicted_runtime_s", Json::Num(self.predicted_runtime_s)),
            ("predicted_cost_usd", Json::Num(self.predicted_cost_usd)),
            ("meets_target", Json::Bool(self.meets_target)),
        ])
    }
}

/// The configurator's decision.
#[derive(Debug, Clone)]
pub struct ClusterChoice {
    pub machine_type: String,
    pub node_count: u32,
    pub predicted_runtime_s: f64,
    pub expected_cost_usd: f64,
    pub meets_target: bool,
    /// Every candidate evaluated (sorted by cost), for reports/figures.
    pub candidates: Vec<Candidate>,
}

impl ClusterChoice {
    /// JSON projection (stable key order) for `c3o recommend --json`:
    /// the decision plus every scored candidate, cheapest first.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machine_type", Json::Str(self.machine_type.clone())),
            ("node_count", Json::Num(self.node_count as f64)),
            ("predicted_runtime_s", Json::Num(self.predicted_runtime_s)),
            ("expected_cost_usd", Json::Num(self.expected_cost_usd)),
            ("meets_target", Json::Bool(self.meets_target)),
            (
                "candidates",
                Json::Arr(self.candidates.iter().map(Candidate::to_json).collect()),
            ),
        ])
    }
}

/// Enumerates and scores candidate configurations.
#[derive(Debug, Clone)]
pub struct Configurator<'c> {
    cloud: &'c Cloud,
    scaleouts: Vec<u32>,
    /// When set, only these machine types are candidates. The coordinator
    /// restricts to machines *observed in the shared data*: black-box
    /// models cannot be trusted to extrapolate across the memory-cliff to
    /// machine types nobody has measured (the spill behaviour is sharply
    /// non-linear in RAM-per-node).
    machines: Option<Vec<String>>,
}

impl<'c> Configurator<'c> {
    /// Candidates over the full catalog and scale-outs 2..=12.
    pub fn new(cloud: &'c Cloud) -> Self {
        Configurator {
            cloud,
            scaleouts: (2..=12).collect(),
            machines: None,
        }
    }

    /// Restrict the scale-out axis (ablations, tests).
    pub fn with_scaleouts(mut self, scaleouts: Vec<u32>) -> Self {
        assert!(!scaleouts.is_empty());
        self.scaleouts = scaleouts;
        self
    }

    /// Restrict the machine-type axis (e.g. to types with training data).
    pub fn with_machines(mut self, machines: Vec<String>) -> Self {
        assert!(!machines.is_empty());
        self.machines = Some(machines);
        self
    }

    /// All candidate (machine, scale-out) pairs.
    pub fn enumerate(&self) -> Vec<(String, u32)> {
        let mut out = Vec::new();
        for m in self.cloud.machine_types() {
            if let Some(allow) = &self.machines {
                if !allow.contains(&m.name) {
                    continue;
                }
            }
            for &n in &self.scaleouts {
                out.push((m.name.clone(), n));
            }
        }
        out
    }

    /// Score every candidate with the model and pick per the policy.
    /// All candidates are featurized **once** into a single matrix and
    /// scored in one batched `predict` call (no per-candidate row
    /// building on the hot path). Returns `None` only if the catalog is
    /// empty.
    pub fn configure(
        &self,
        model: &mut dyn RuntimeModel,
        request: &JobRequest,
    ) -> Result<Option<ClusterChoice>, ApiError> {
        // re-validate at this depth too: `configure` is public, so
        // library users bypassing the coordinator boundary must not get
        // silent everything-misses-the-target behavior from a NaN target
        // (this check replaced the old panicking builder assert)
        request.validate()?;
        let pairs = self.enumerate();
        if pairs.is_empty() {
            return Ok(None);
        }
        let features = request.spec.job_features();
        let batch = QueryBatch::from_candidates(self.cloud, &pairs, &features);
        let runtimes = model
            .predict_batch(self.cloud, &batch)
            .map_err(ApiError::internal)?;
        Ok(self.choose(request, &pairs, &runtimes))
    }

    /// Build the decision from already-predicted runtimes: price each
    /// candidate, sort by cost, pick per the policy. Split out of
    /// [`Configurator::configure`] so the service can score several
    /// same-kind `Recommend` requests as **one coalesced predict batch**
    /// and still make each request's decision through the exact same
    /// code (bitwise-identical to an uncoalesced `configure`).
    ///
    /// `runtimes[i]` is the predicted runtime of `pairs[i]`. Returns
    /// `None` only when `pairs` is empty.
    pub fn choose(
        &self,
        request: &JobRequest,
        pairs: &[(String, u32)],
        runtimes: &[f64],
    ) -> Option<ClusterChoice> {
        debug_assert_eq!(pairs.len(), runtimes.len());
        if pairs.is_empty() {
            return None;
        }
        let mut candidates: Vec<Candidate> = pairs
            .iter()
            .zip(runtimes)
            .map(|((m, n), &t)| {
                let cost = self.cloud.cost_usd(m, *n, t);
                Candidate {
                    machine: m.clone(),
                    scaleout: *n,
                    predicted_runtime_s: t,
                    predicted_cost_usd: cost,
                    meets_target: request.target_s.map_or(true, |tt| t <= tt),
                }
            })
            .collect();
        candidates.sort_by(|a, b| {
            a.predicted_cost_usd
                .partial_cmp(&b.predicted_cost_usd)
                .unwrap()
        });

        // Policy: cheapest meeting the target; else fastest overall.
        let best = candidates
            .iter()
            .find(|c| c.meets_target)
            .or_else(|| {
                candidates.iter().min_by(|a, b| {
                    a.predicted_runtime_s
                        .partial_cmp(&b.predicted_runtime_s)
                        .unwrap()
                })
            })
            .cloned()
            .expect("candidates nonempty");

        Some(ClusterChoice {
            machine_type: best.machine.clone(),
            node_count: best.scaleout,
            predicted_runtime_s: best.predicted_runtime_s,
            expected_cost_usd: best.predicted_cost_usd,
            meets_target: best.meets_target,
            candidates,
        })
    }

    /// Fig. 3 analysis: rank machine types by total predicted cost for a
    /// job at a given scale-out (lower = more cost-efficient). Scored as
    /// one featurized batch like [`Configurator::configure`].
    pub fn rank_machine_types(
        &self,
        model: &mut dyn RuntimeModel,
        spec: &JobSpec,
        scaleout: u32,
    ) -> Result<Vec<(String, f64)>, ApiError> {
        let features = spec.job_features();
        let pairs: Vec<(String, u32)> = self
            .cloud
            .machine_types()
            .iter()
            .map(|m| (m.name.clone(), scaleout))
            .collect();
        let batch = QueryBatch::from_candidates(self.cloud, &pairs, &features);
        let runtimes = model
            .predict_batch(self.cloud, &batch)
            .map_err(ApiError::internal)?;
        let mut ranked: Vec<(String, f64)> = pairs
            .iter()
            .zip(&runtimes)
            .map(|((m, _), &t)| (m.clone(), self.cloud.cost_usd(m, scaleout, t)))
            .collect();
        ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        Ok(ranked)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::oracle::SimOracle;
    use crate::workloads::JobKind;

    #[test]
    fn enumerate_covers_catalog_times_scaleouts() {
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let pairs = cfg.enumerate();
        assert_eq!(pairs.len(), cloud.machine_types().len() * 11);
    }

    #[test]
    fn configure_with_oracle_meets_target() {
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let req = JobRequest::sort(15.0).with_target_seconds(400.0);
        let choice = cfg.configure(&mut oracle, &req).unwrap().unwrap();
        assert!(choice.meets_target);
        assert!(choice.predicted_runtime_s <= 400.0);
        // verify it is the cheapest among target-meeting candidates
        for c in choice.candidates.iter().filter(|c| c.meets_target) {
            assert!(choice.expected_cost_usd <= c.predicted_cost_usd + 1e-9);
        }
    }

    #[test]
    fn tighter_target_costs_more() {
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let loose = cfg
            .configure(&mut oracle, &JobRequest::sort(15.0).with_target_seconds(2000.0))
            .unwrap()
            .unwrap();
        let tight = cfg
            .configure(&mut oracle, &JobRequest::sort(15.0).with_target_seconds(150.0))
            .unwrap()
            .unwrap();
        assert!(
            tight.expected_cost_usd >= loose.expected_cost_usd,
            "tight {} loose {}",
            tight.expected_cost_usd,
            loose.expected_cost_usd
        );
    }

    #[test]
    fn impossible_target_falls_back_to_fastest() {
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let choice = cfg
            .configure(&mut oracle, &JobRequest::sort(20.0).with_target_seconds(1.0))
            .unwrap()
            .unwrap();
        assert!(!choice.meets_target);
        // fastest candidate was chosen
        let fastest = choice
            .candidates
            .iter()
            .map(|c| c.predicted_runtime_s)
            .fold(f64::INFINITY, f64::min);
        assert!((choice.predicted_runtime_s - fastest).abs() < 1e-9);
    }

    #[test]
    fn no_target_minimizes_cost() {
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let choice = cfg
            .configure(&mut oracle, &JobRequest::sort(15.0))
            .unwrap()
            .unwrap();
        let min_cost = choice
            .candidates
            .iter()
            .map(|c| c.predicted_cost_usd)
            .fold(f64::INFINITY, f64::min);
        assert!((choice.expected_cost_usd - min_cost).abs() < 1e-9);
    }

    #[test]
    fn ranking_is_scaleout_stable_for_cpu_bound_job() {
        // Fig. 3's main conclusion: the machine-type cost-efficiency
        // ranking stays static across scale-outs for a given algorithm.
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let spec = JobSpec::sort(15.0);
        let names = |v: &[(String, f64)]| -> Vec<String> {
            v.iter().map(|(m, _)| m.clone()).collect()
        };
        let r4 = names(&cfg.rank_machine_types(&mut oracle, &spec, 4).unwrap());
        let r8 = names(&cfg.rank_machine_types(&mut oracle, &spec, 8).unwrap());
        let r12 = names(&cfg.rank_machine_types(&mut oracle, &spec, 12).unwrap());
        assert_eq!(r4, r8);
        assert_eq!(r8, r12);
    }

    #[test]
    fn memory_hungry_job_prefers_more_ram_at_low_scaleout() {
        // Fig. 3's exception: SGD at scale-out 2 bottlenecks on RAM-lean
        // types, so r5 beats c5 there.
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sgd, 1);
        let spec = JobSpec::sgd(30.0, 100);
        let r2 = cfg.rank_machine_types(&mut oracle, &spec, 2).unwrap();
        let pos = |v: &[(String, f64)], name: &str| {
            v.iter().position(|(m, _)| m == name).unwrap()
        };
        assert!(
            pos(&r2, "r5.xlarge") < pos(&r2, "c5.xlarge"),
            "at n=2 r5.xlarge should rank above c5.xlarge: {r2:?}"
        );
    }

    #[test]
    fn invalid_targets_fail_validation_instead_of_panicking() {
        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
            let req = JobRequest::sort(10.0).with_target_seconds(bad);
            match req.validate() {
                Err(ApiError::InvalidRequest(msg)) => {
                    assert!(msg.contains("runtime target"), "{msg}")
                }
                other => panic!("target {bad} should be invalid, got {other:?}"),
            }
        }
        assert!(JobRequest::sort(10.0).with_target_seconds(60.0).validate().is_ok());
        assert!(JobRequest::sort(10.0).validate().is_ok(), "no target is valid");
    }

    #[test]
    fn non_finite_features_fail_validation() {
        let req = JobRequest::sort(f64::NAN);
        match req.validate() {
            Err(ApiError::InvalidRequest(msg)) => assert!(msg.contains("feature"), "{msg}"),
            other => panic!("NaN feature should be invalid, got {other:?}"),
        }
    }

    #[test]
    fn choose_matches_configure_bitwise() {
        // `configure` = enumerate → score → choose; calling `choose` on
        // the same runtimes must reproduce the decision bit for bit
        // (the coalesced-recommend path in the service relies on this).
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let req = JobRequest::sort(15.0).with_target_seconds(400.0);
        let via_configure = cfg.configure(&mut oracle, &req).unwrap().unwrap();
        let pairs = cfg.enumerate();
        let runtimes: Vec<f64> = {
            let batch =
                QueryBatch::from_candidates(&cloud, &pairs, &req.spec.job_features());
            let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
            oracle.predict_batch(&cloud, &batch).unwrap()
        };
        let via_choose = cfg.choose(&req, &pairs, &runtimes).unwrap();
        assert_eq!(via_configure.machine_type, via_choose.machine_type);
        assert_eq!(via_configure.node_count, via_choose.node_count);
        assert_eq!(
            via_configure.predicted_runtime_s.to_bits(),
            via_choose.predicted_runtime_s.to_bits()
        );
        assert_eq!(
            via_configure.expected_cost_usd.to_bits(),
            via_choose.expected_cost_usd.to_bits()
        );
    }

    #[test]
    fn choice_json_is_scriptable() {
        let cloud = Cloud::aws_like();
        let cfg = Configurator::new(&cloud).with_scaleouts(vec![2, 4]);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let choice = cfg
            .configure(&mut oracle, &JobRequest::sort(12.0))
            .unwrap()
            .unwrap();
        let s = choice.to_json().render();
        assert!(s.contains("\"machine_type\":"), "{s}");
        assert!(s.contains("\"candidates\":["), "{s}");
    }
}
