//! Folk-strategy baselines: what users do without tooling.

use crate::baselines::{ConfigSearch, SearchOutcome};
use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::models::oracle::SimOracle;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};

/// Overprovisioning: the biggest general-purpose cluster on offer —
/// the paper's "users often overprovision resources to meet their
/// performance target, yet often at the cost of overheads".
#[derive(Debug, Clone)]
pub struct NaiveMax {
    pub max_scaleout: u32,
}

impl Default for NaiveMax {
    fn default() -> Self {
        NaiveMax { max_scaleout: 12 }
    }
}

impl ConfigSearch for NaiveMax {
    fn name(&self) -> &'static str {
        "naive-max"
    }

    fn search(
        &mut self,
        cloud: &Cloud,
        _oracle: &mut SimOracle,
        _request: &JobRequest,
    ) -> Result<SearchOutcome> {
        // biggest machine of the general-purpose family, max scale-out
        let machine = cloud
            .machine_types()
            .iter()
            .filter(|m| m.family == crate::cloud::MachineFamily::General)
            .max_by(|a, b| a.vcpus.cmp(&b.vcpus))
            .ok_or_else(|| anyhow!("no general-purpose machines in catalog"))?;
        Ok(SearchOutcome {
            machine: machine.name.clone(),
            scaleout: self.max_scaleout,
            predicted_runtime_s: f64::NAN,
            profiling_runs: 0,
            profiling_cost_usd: 0.0,
            profiling_seconds: 0.0,
        })
    }
}

/// Penny-pinching: the configuration with the lowest hourly rate
/// (ignores that slow clusters can cost *more* in total).
#[derive(Debug, Clone, Default)]
pub struct NaiveCheapest;

impl ConfigSearch for NaiveCheapest {
    fn name(&self) -> &'static str {
        "naive-cheapest"
    }

    fn search(
        &mut self,
        cloud: &Cloud,
        _oracle: &mut SimOracle,
        _request: &JobRequest,
    ) -> Result<SearchOutcome> {
        let machine = cloud
            .machine_types()
            .iter()
            .min_by(|a, b| a.price_usd_hour.partial_cmp(&b.price_usd_hour).unwrap())
            .ok_or_else(|| anyhow!("empty catalog"))?;
        Ok(SearchOutcome {
            machine: machine.name.clone(),
            scaleout: 2,
            predicted_runtime_s: f64::NAN,
            profiling_runs: 0,
            profiling_cost_usd: 0.0,
            profiling_seconds: 0.0,
        })
    }
}

/// Uniform random choice over the candidate grid (the regret floor any
/// informed approach must beat).
#[derive(Debug, Clone)]
pub struct NaiveRandom {
    pub rng: Pcg32,
    pub scaleouts: Vec<u32>,
}

impl NaiveRandom {
    pub fn new(seed: u64) -> Self {
        NaiveRandom {
            rng: Pcg32::new(seed),
            scaleouts: (2..=12).collect(),
        }
    }
}

impl ConfigSearch for NaiveRandom {
    fn name(&self) -> &'static str {
        "naive-random"
    }

    fn search(
        &mut self,
        cloud: &Cloud,
        _oracle: &mut SimOracle,
        _request: &JobRequest,
    ) -> Result<SearchOutcome> {
        let machines = cloud.machine_types();
        if machines.is_empty() {
            return Err(anyhow!("empty catalog"));
        }
        let m = &machines[self.rng.index(machines.len())];
        let n = self.scaleouts[self.rng.index(self.scaleouts.len())];
        Ok(SearchOutcome {
            machine: m.name.clone(),
            scaleout: n,
            predicted_runtime_s: f64::NAN,
            profiling_runs: 0,
            profiling_cost_usd: 0.0,
            profiling_seconds: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::JobKind;

    #[test]
    fn max_picks_biggest_general_purpose() {
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let out = NaiveMax::default()
            .search(&cloud, &mut oracle, &JobRequest::sort(15.0))
            .unwrap();
        assert_eq!(out.machine, "m5.2xlarge");
        assert_eq!(out.scaleout, 12);
        assert_eq!(out.profiling_runs, 0);
    }

    #[test]
    fn cheapest_picks_lowest_rate() {
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let out = NaiveCheapest
            .search(&cloud, &mut oracle, &JobRequest::sort(15.0))
            .unwrap();
        assert_eq!(out.machine, "c5.large"); // $0.085/h
    }

    #[test]
    fn random_is_seeded_and_in_range() {
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 1);
        let mut a = NaiveRandom::new(7);
        let mut b = NaiveRandom::new(7);
        for _ in 0..10 {
            let oa = a.search(&cloud, &mut oracle, &JobRequest::sort(15.0)).unwrap();
            let ob = b.search(&cloud, &mut oracle, &JobRequest::sort(15.0)).unwrap();
            assert_eq!(oa.machine, ob.machine);
            assert_eq!(oa.scaleout, ob.scaleout);
            assert!((2..=12).contains(&oa.scaleout));
        }
    }
}
