//! Micky-style combined profiling (Hsu et al., IEEE CLOUD'18) — §II-A.
//!
//! Micky reduces per-workload profiling overhead by profiling **several
//! workloads simultaneously**: candidate configurations are arms of a
//! multi-armed bandit, each pull runs *one* of the workloads on the arm
//! (round-robin), and the reward is the arm's cost-efficiency for that
//! workload. After the pull budget is spent, the best arm becomes the
//! *one* configuration recommended for the whole workload set — trading
//! per-workload optimality for a much smaller shared profiling bill.
//!
//! We implement UCB1 over a coarse (machine type × scale-out) grid with
//! per-workload reward normalization (log-cost z-scores against a
//! running mean), the trade-off reformulation the paper cites.

use crate::baselines::metered_probe;
use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::models::oracle::SimOracle;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};

/// Outcome of a combined-profiling run.
#[derive(Debug, Clone)]
pub struct CombinedOutcome {
    /// The single configuration recommended for every workload.
    pub machine: String,
    pub scaleout: u32,
    /// Total pulls (profiling executions) across all workloads.
    pub profiling_runs: u64,
    /// Total profiling spend (cluster time + provisioning), USD.
    pub profiling_cost_usd: f64,
    /// Mean pulls per arm (coverage diagnostics).
    pub mean_pulls_per_arm: f64,
}

/// Micky: combined profiling for a *set* of workloads.
#[derive(Debug, Clone)]
pub struct Micky {
    /// Total pull budget across all workloads and arms.
    pub budget: u32,
    /// UCB exploration constant.
    pub exploration: f64,
    /// Scale-outs included in the arm grid (coarse by design).
    pub scaleouts: Vec<u32>,
    /// Provisioning delay charged per pull, seconds.
    pub provisioning_s: f64,
    pub seed: u64,
}

impl Default for Micky {
    fn default() -> Self {
        Micky {
            budget: 24,
            exploration: 1.2,
            scaleouts: vec![4, 10],
            provisioning_s: 7.0 * 60.0,
            seed: 0x111C,
        }
    }
}

impl Micky {
    /// Run combined profiling over `requests` (one or more workloads;
    /// Micky's value shows with several). Lower cost per workload than
    /// profiling each separately, at the price of a single shared
    /// configuration.
    pub fn search_combined(
        &mut self,
        cloud: &Cloud,
        requests: &[JobRequest],
    ) -> Result<CombinedOutcome> {
        if requests.is_empty() {
            return Err(anyhow!("need at least one workload"));
        }
        let mut arms: Vec<(String, u32)> = Vec::new();
        for m in cloud.machine_types() {
            for &n in &self.scaleouts {
                arms.push((m.name.clone(), n));
            }
        }
        if arms.is_empty() {
            return Err(anyhow!("empty arm grid"));
        }
        let mut rng = Pcg32::new(self.seed);
        let mut oracles: Vec<SimOracle> = requests
            .iter()
            .map(|r| SimOracle::new(r.kind(), rng.next_u64()))
            .collect();

        // per-arm statistics
        let mut pulls = vec![0u32; arms.len()];
        let mut reward_sum = vec![0.0f64; arms.len()];
        // running per-workload normalization of log-costs
        let mut wl_mean = vec![0.0f64; requests.len()];
        let mut wl_count = vec![0u32; requests.len()];

        let mut profiling_runs = 0u64;
        let mut profiling_cost = 0.0f64;

        for t in 0..self.budget {
            // pick the arm: each arm once first, then UCB1
            let arm = if let Some(unpulled) = pulls.iter().position(|&p| p == 0) {
                // cheap initial sweep only while budget allows breadth
                if (t as usize) < arms.len().min(self.budget as usize) {
                    unpulled
                } else {
                    0
                }
            } else {
                let total: f64 = pulls.iter().map(|&p| p as f64).sum();
                (0..arms.len())
                    .max_by(|&a, &b| {
                        let ucb = |i: usize| {
                            reward_sum[i] / pulls[i] as f64
                                + self.exploration * (total.ln() / pulls[i] as f64).sqrt()
                        };
                        ucb(a).partial_cmp(&ucb(b)).unwrap()
                    })
                    .unwrap()
            };

            // round-robin workload for this pull
            let w = (t as usize) % requests.len();
            let (machine, n) = &arms[arm];
            let features = requests[w].spec.job_features();
            let (runtime, cost, _held) = metered_probe(
                cloud,
                &mut oracles[w],
                machine,
                *n,
                &features,
                self.provisioning_s,
            )?;
            profiling_runs += 1;
            profiling_cost += cost;

            // reward: negative log run-cost, z-centred per workload so
            // cheap workloads don't drown expensive ones
            let run_cost = cloud.cost_usd(machine, *n, runtime);
            let target_penalty = match requests[w].target_s {
                Some(tt) if runtime > tt => (4.0f64).ln(),
                _ => 0.0,
            };
            let log_cost = run_cost.ln() + target_penalty;
            wl_count[w] += 1;
            wl_mean[w] += (log_cost - wl_mean[w]) / wl_count[w] as f64;
            let reward = -(log_cost - wl_mean[w]);
            pulls[arm] += 1;
            reward_sum[arm] += reward;
        }

        let best = (0..arms.len())
            .filter(|&i| pulls[i] > 0)
            .max_by(|&a, &b| {
                let avg = |i: usize| reward_sum[i] / pulls[i] as f64;
                avg(a).partial_cmp(&avg(b)).unwrap()
            })
            .ok_or_else(|| anyhow!("no arm pulled"))?;

        let (machine, scaleout) = arms[best].clone();
        Ok(CombinedOutcome {
            machine,
            scaleout,
            profiling_runs,
            profiling_cost_usd: profiling_cost,
            mean_pulls_per_arm: profiling_runs as f64 / arms.len() as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{CherryPick, ConfigSearch};

    fn battery() -> Vec<JobRequest> {
        vec![
            JobRequest::sort(15.0).with_target_seconds(600.0),
            JobRequest::grep(12.0, 0.1).with_target_seconds(400.0),
            JobRequest::pagerank(300.0, 0.001).with_target_seconds(500.0),
            JobRequest::sort(18.0).with_target_seconds(700.0),
            JobRequest::grep(16.0, 0.2).with_target_seconds(500.0),
        ]
    }

    #[test]
    fn respects_budget_and_returns_valid_arm() {
        let cloud = Cloud::aws_like();
        let mut micky = Micky::default();
        let out = micky.search_combined(&cloud, &battery()).unwrap();
        assert_eq!(out.profiling_runs, micky.budget as u64);
        assert!(cloud.machine(&out.machine).is_some());
        assert!(micky.scaleouts.contains(&out.scaleout));
        assert!(out.profiling_cost_usd > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cloud = Cloud::aws_like();
        let a = Micky::default().search_combined(&cloud, &battery()).unwrap();
        let b = Micky::default().search_combined(&cloud, &battery()).unwrap();
        assert_eq!(a.machine, b.machine);
        assert_eq!(a.scaleout, b.scaleout);
        assert_eq!(a.profiling_cost_usd, b.profiling_cost_usd);
    }

    #[test]
    fn combined_profiling_is_cheaper_than_per_workload_search() {
        // the paper's §II-A point: Micky cuts profiling overhead vs
        // running an independent search per workload
        let cloud = Cloud::aws_like();
        let reqs = battery();
        let micky_cost = Micky::default()
            .search_combined(&cloud, &reqs)
            .unwrap()
            .profiling_cost_usd;
        let mut separate_cost = 0.0;
        for r in &reqs {
            let mut oracle = SimOracle::deterministic(r.kind(), 9);
            let out = CherryPick::default().search(&cloud, &mut oracle, r).unwrap();
            separate_cost += out.profiling_cost_usd;
        }
        assert!(
            micky_cost < separate_cost,
            "combined ${micky_cost:.2} should beat separate ${separate_cost:.2}"
        );
    }

    #[test]
    fn recommended_arm_is_reasonable() {
        // the shared configuration should not be a regret disaster for
        // the CPU-bound members of the workload set
        let cloud = Cloud::aws_like();
        let reqs = battery();
        let out = Micky {
            budget: 60,
            ..Micky::default()
        }
        .search_combined(&cloud, &reqs)
        .unwrap();
        // measure true cost of the shared choice vs per-workload optimum
        let mut ratio_sum = 0.0;
        let scaleouts = [4u32, 10];
        for r in &reqs {
            let mut oracle = SimOracle::deterministic(r.kind(), 55);
            let q = crate::models::ConfigQuery {
                machine: out.machine.clone(),
                scaleout: out.scaleout,
                job_features: r.spec.job_features(),
            };
            let t = oracle.run_once(&cloud, &q).unwrap();
            let chosen = cloud.cost_usd(&out.machine, out.scaleout, t);
            let mut best = f64::INFINITY;
            for m in cloud.machine_types() {
                for n in scaleouts {
                    let q = crate::models::ConfigQuery {
                        machine: m.name.clone(),
                        scaleout: n,
                        job_features: r.spec.job_features(),
                    };
                    let t = oracle.run_once(&cloud, &q).unwrap();
                    best = best.min(cloud.cost_usd(&m.name, n, t));
                }
            }
            ratio_sum += chosen / best;
        }
        let mean_regret = ratio_sum / reqs.len() as f64;
        assert!(mean_regret < 4.0, "mean regret {mean_regret}");
    }

    #[test]
    fn empty_workload_set_rejected() {
        let cloud = Cloud::aws_like();
        assert!(Micky::default().search_combined(&cloud, &[]).is_err());
    }
}
