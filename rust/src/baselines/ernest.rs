//! Ernest-style parametric performance modeling (Venkataraman et al.,
//! NSDI'16).
//!
//! Ernest profiles the job on **subsampled input data** at a handful of
//! scale-outs, fits the parametric scale-out law
//!
//! ```text
//! t(s, n) = θ₀ + θ₁·(s/n) + θ₂·log(n) + θ₃·n
//! ```
//!
//! (s = data scale, n = nodes) with non-negative least squares, then
//! extrapolates to the full dataset to choose a configuration. We fit one
//! model per machine type (Ernest is scale-out-only; machine choice comes
//! from comparing the fitted models), using ridge-seeded projected
//! gradient for the NNLS constraint.
//!
//! Profiling cost is metered exactly like CherryPick's: subsample runs
//! are cheaper, but they still pay provisioning.

use crate::baselines::{metered_probe, ConfigSearch, SearchOutcome};
use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::models::oracle::SimOracle;
use crate::util::stats::ridge_fit;
use anyhow::{anyhow, Result};

/// The Ernest basis for (data scale `s` in [0,1], nodes `n`).
pub fn ernest_basis(s: f64, n: f64) -> [f64; 4] {
    [1.0, s / n, n.ln(), n]
}

/// Non-negative least squares: ridge seed + projected gradient descent.
pub fn nnls(x: &[f64], rows: usize, cols: usize, y: &[f64]) -> Vec<f64> {
    let mut w = ridge_fit(x, rows, cols, y, 1e-6);
    for v in &mut w {
        *v = v.max(0.0);
    }
    // projected gradient refinement
    let mut lr = 1.0;
    // scale lr by the largest diagonal of XᵀX for stability
    let mut diag_max = 1e-12f64;
    for j in 0..cols {
        let d: f64 = (0..rows).map(|i| x[i * cols + j] * x[i * cols + j]).sum();
        diag_max = diag_max.max(d);
    }
    lr /= diag_max;
    for _ in 0..2000 {
        // grad = Xᵀ(Xw - y)
        let mut grad = vec![0.0; cols];
        for i in 0..rows {
            let row = &x[i * cols..(i + 1) * cols];
            let pred: f64 = row.iter().zip(&w).map(|(a, b)| a * b).sum();
            let err = pred - y[i];
            for j in 0..cols {
                grad[j] += err * row[j];
            }
        }
        let mut moved = 0.0;
        for j in 0..cols {
            let nw = (w[j] - lr * grad[j]).max(0.0);
            moved += (nw - w[j]).abs();
            w[j] = nw;
        }
        if moved < 1e-12 {
            break;
        }
    }
    w
}

/// Ernest configuration search.
#[derive(Debug, Clone)]
pub struct Ernest {
    /// Profiling plan: (data fraction, scale-out) pairs, per machine type.
    pub probe_plan: Vec<(f64, u32)>,
    /// Provisioning delay charged per distinct probe cluster, seconds.
    pub provisioning_s: f64,
}

impl Default for Ernest {
    fn default() -> Self {
        Ernest {
            // Ernest's optimal-experiment-design plans concentrate on
            // small fractions at varied scale-outs.
            probe_plan: vec![(0.06, 2), (0.06, 6), (0.06, 12), (0.12, 4), (0.12, 8)],
            provisioning_s: 7.0 * 60.0,
        }
    }
}

impl ConfigSearch for Ernest {
    fn name(&self) -> &'static str {
        "ernest"
    }

    fn search(
        &mut self,
        cloud: &Cloud,
        oracle: &mut SimOracle,
        request: &JobRequest,
    ) -> Result<SearchOutcome> {
        let full_features = request.spec.job_features();
        if full_features.is_empty() {
            return Err(anyhow!("job without features"));
        }
        let mut profiling_runs = 0u64;
        let mut profiling_cost = 0.0;
        let mut profiling_secs = 0.0;

        // fit one model per machine type
        let mut best: Option<(String, u32, f64, f64)> = None; // machine, n, runtime, cost
        for m in cloud.machine_types() {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for &(frac, n) in &self.probe_plan {
                // feature 0 is always the data scale (GB or MB)
                let mut f = full_features.clone();
                f[0] *= frac;
                let (t, cost, held) =
                    metered_probe(cloud, oracle, &m.name, n, &f, self.provisioning_s)?;
                profiling_runs += 1;
                profiling_cost += cost;
                profiling_secs += held;
                xs.extend_from_slice(&ernest_basis(frac, n as f64));
                ys.push(t);
            }
            let theta = nnls(&xs, ys.len(), 4, &ys);
            // predict full data (s = 1.0) across scale-outs
            for n in 2..=12u32 {
                let b = ernest_basis(1.0, n as f64);
                let t: f64 = b.iter().zip(&theta).map(|(a, w)| a * w).sum();
                let t = t.max(1.0);
                let meets = request.target_s.map_or(true, |tt| t <= tt);
                let cost = cloud.cost_usd(&m.name, n, t);
                let better = match &best {
                    None => true,
                    Some((_, _, bt, bc)) => {
                        let best_meets = request.target_s.map_or(true, |tt| *bt <= tt);
                        match (meets, best_meets) {
                            (true, false) => true,
                            (false, true) => false,
                            _ => cost < *bc,
                        }
                    }
                };
                if better {
                    best = Some((m.name.clone(), n, t, cost));
                }
            }
        }

        let (machine, scaleout, runtime, _) = best.ok_or_else(|| anyhow!("empty catalog"))?;
        Ok(SearchOutcome {
            machine,
            scaleout,
            predicted_runtime_s: runtime,
            profiling_runs,
            profiling_cost_usd: profiling_cost,
            profiling_seconds: profiling_secs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::JobKind;

    #[test]
    fn nnls_recovers_nonnegative_coefficients() {
        // y = 2 + 0*b1 + 3*log(n) on a grid
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for n in 1..=20 {
            let b = ernest_basis(1.0, n as f64);
            xs.extend_from_slice(&b);
            ys.push(2.0 + 3.0 * (n as f64).ln());
        }
        let w = nnls(&xs, 20, 4, &ys);
        assert!(w.iter().all(|&v| v >= 0.0), "{w:?}");
        assert!((w[0] - 2.0).abs() < 0.3, "{w:?}");
        assert!((w[2] - 3.0).abs() < 0.3, "{w:?}");
    }

    #[test]
    fn nnls_clamps_negative_truth() {
        // y = -5 + n : θ0 would want to be negative; NNLS forces 0
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for n in 1..=10 {
            xs.extend_from_slice(&ernest_basis(1.0, n as f64));
            ys.push(-5.0 + n as f64);
        }
        let w = nnls(&xs, 10, 4, &ys);
        assert!(w.iter().all(|&v| v >= 0.0), "{w:?}");
    }

    #[test]
    fn ernest_profiles_and_decides() {
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 5);
        let mut e = Ernest::default();
        let req = JobRequest::sort(15.0).with_target_seconds(600.0);
        let out = e.search(&cloud, &mut oracle, &req).unwrap();
        // 5 probes per machine type × 9 types
        assert_eq!(out.profiling_runs, 45);
        assert!(out.profiling_cost_usd > 0.0);
        assert!(cloud.machine(&out.machine).is_some());
        assert!((2..=12).contains(&out.scaleout));
        assert!(out.predicted_runtime_s > 0.0);
    }

    #[test]
    fn ernest_prediction_is_roughly_calibrated_for_scalable_job() {
        // For Sort (clean scale-out behaviour) the extrapolated runtime
        // should be within 2x of the truth at the chosen config.
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 5);
        let req = JobRequest::sort(15.0);
        let out = Ernest::default().search(&cloud, &mut oracle, &req).unwrap();
        let mut check = SimOracle::deterministic(JobKind::Sort, 5);
        let q = crate::models::ConfigQuery {
            machine: out.machine.clone(),
            scaleout: out.scaleout,
            job_features: req.spec.job_features(),
        };
        let truth = check.run_once(&cloud, &q).unwrap();
        let ratio = out.predicted_runtime_s / truth;
        assert!(
            (0.4..2.5).contains(&ratio),
            "predicted {} vs truth {truth}",
            out.predicted_runtime_s
        );
    }
}
