//! The runtime-data repository — the collaborative core of C3O.
//!
//! The paper's idea (§III): runtime data is shared *alongside the code* of
//! a job, so a new user benefits from every execution anyone ever
//! contributed. This module implements that repository:
//!
//! * [`RuntimeRecord`] — one shared observation: which job, on what
//!   cluster (machine type + scale-out), with which dataset
//!   characteristics and parameters, and the resulting runtime (median of
//!   repetitions, matching the paper's protocol). Records carry the
//!   contributing organization for provenance.
//! * [`RuntimeDataRepo`] — a per-job collection with CSV persistence
//!   (the "runtime data repository" of Fig. 2), deduplication, and
//!   **fork/merge** versioning in the style of DataHub/DVC (§III-C).
//!   [`RuntimeDataRepo::merge`] is the convergence primitive of the
//!   federation layer ([`crate::store`]): duplicate configurations are
//!   resolved by a deterministic total order, so merging is idempotent,
//!   commutative, and associative over record *sets* — independently
//!   gossiping peers converge — and disagreements are surfaced as
//!   structured [`MergeConflict`]s instead of silently dropped.
//! * **Operation logs** — the repo assigns every accepted mutation a
//!   monotone per-organization sequence number and keeps one append-only
//!   op log per org: every op *seen* for that org (applied, or delivered
//!   by a peer and merge-rejected), in sequence order. The log is the
//!   one change-tracking abstraction shared by the WAL
//!   ([`crate::store::segment`], which frames every line with the seqno)
//!   and the sync protocol: [`OrgWatermark`] is the log position
//!   `(seqno, digest)`, [`RuntimeDataRepo::ops_since`] extracts the
//!   record-level delta past a seqno, and [`RuntimeDataRepo::delta_for`]
//!   ships O(changed records) per exchange — falling back to a whole-org
//!   ship only when two logs have genuinely diverged (the digest check).
//!   Merge-rejected sync ops still advance the receiver's log, so blind
//!   duplicate contributions are never re-offered.
//! * [`sampling`] — the paper's proposed mitigation when the shared
//!   dataset grows too large: download only a *coverage-preserving
//!   sample* of bounded size (farthest-point sampling in feature space).
//! * [`featurize`] — turns records into model-ready matrices: job
//!   features + scale-out + machine descriptors, z-scored.

pub mod featurize;
pub mod sampling;

pub use featurize::{FeatureMatrixCache, FeatureSpace, Featurizer};

use crate::util::csv::Table;
use crate::util::hash::fnv1a64_parts;
use crate::workloads::JobKind;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::Path;

/// One shared runtime observation.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeRecord {
    pub job: JobKind,
    /// Contributing organization (provenance; "emulated collaborator").
    pub org: String,
    /// Machine type name, resolvable in the cloud catalog.
    pub machine: String,
    /// Horizontal scale-out (worker count).
    pub scaleout: u32,
    /// Job-specific features, aligned with `JobKind::feature_names()`.
    pub job_features: Vec<f64>,
    /// Median runtime over the repetitions, seconds.
    pub runtime_s: f64,
}

/// Canonical text form of one feature value for [`RuntimeRecord::config_key`].
///
/// Float formatting alone is not a stable identity: `-0.0` and `0.0` are
/// equal grid points but format differently under `{:.6e}`, and the 2^52
/// NaN payloads all denote the same (invalid) point. Normalize before
/// formatting so equal configurations can never produce distinct keys.
fn canonical_feature(f: f64) -> String {
    if f.is_nan() {
        return "nan".to_string();
    }
    let f = if f == 0.0 { 0.0 } else { f }; // collapse -0.0 into 0.0
    format!("{f:.6e}")
}

impl RuntimeRecord {
    /// Stable identity key for deduplication: everything except runtime
    /// and org (two orgs measuring the same configuration are duplicates
    /// of the same grid point). Feature values are canonicalized
    /// (`-0.0` ≡ `0.0`, all NaNs ≡ `nan`) before formatting.
    pub fn config_key(&self) -> String {
        let feats: Vec<String> = self
            .job_features
            .iter()
            .map(|f| canonical_feature(*f))
            .collect();
        format!(
            "{}|{}|{}|{}",
            self.job.name(),
            self.machine,
            self.scaleout,
            feats.join(",")
        )
    }

    /// Stable 64-bit content hash over identity *and* measurement
    /// (config key, org, runtime bits). XOR-combining these hashes gives
    /// the order-independent set digests of [`OrgWatermark`].
    pub fn content_hash(&self) -> u64 {
        fnv1a64_parts(&[
            self.config_key().as_bytes(),
            self.org.as_bytes(),
            &self.runtime_s.to_bits().to_le_bytes(),
        ])
    }

    /// The deterministic merge-priority key: of two records sharing a
    /// configuration, the one with the **smaller** key survives a
    /// merge. Runtimes are validated positive, so the bit order equals
    /// the value order. The rule is arbitrary but *total* and
    /// *order-independent*, which is what makes federated merging
    /// converge regardless of gossip order.
    pub fn merge_priority(&self) -> (u64, &str) {
        (self.runtime_s.to_bits(), self.org.as_str())
    }

    /// The canonical federation ordering key (config key, org, runtime
    /// bits) — the one total order [`RuntimeDataRepo::canonicalize`]
    /// sorts by; converged peers are bitwise-identical *because* they
    /// all sort by this same key.
    pub fn canonical_sort_key(&self) -> (String, String, u64) {
        (self.config_key(), self.org.clone(), self.runtime_s.to_bits())
    }

    /// A copy of the record re-attributed to `org` (e.g. when building
    /// per-organization corpora for federation demos and tests).
    pub fn with_org(&self, org: &str) -> RuntimeRecord {
        RuntimeRecord {
            org: org.to_string(),
            ..self.clone()
        }
    }

    fn wins_over(&self, other: &RuntimeRecord) -> bool {
        self.merge_priority() < other.merge_priority()
    }

    fn validate(&self) -> Result<(), String> {
        if self.scaleout == 0 {
            return Err("scaleout must be >= 1".into());
        }
        // line-oriented persistence (the segment store WAL) frames one
        // record per physical line; reject control characters that
        // would break that framing at the one validation choke point
        // every ingress path shares
        if self.org.contains('\n') || self.org.contains('\r') {
            return Err(format!("org may not contain newlines: {:?}", self.org));
        }
        if self.machine.contains('\n') || self.machine.contains('\r') {
            return Err(format!(
                "machine may not contain newlines: {:?}",
                self.machine
            ));
        }
        if !(self.runtime_s.is_finite() && self.runtime_s > 0.0) {
            return Err(format!("bad runtime {}", self.runtime_s));
        }
        if self.job_features.len() != self.job.feature_names().len() {
            return Err(format!(
                "{}: {} features, expected {}",
                self.job.name(),
                self.job_features.len(),
                self.job.feature_names().len()
            ));
        }
        if self.job_features.iter().any(|f| !f.is_finite()) {
            return Err("non-finite feature".into());
        }
        Ok(())
    }
}

/// Per-organization high-water mark: a position in that organization's
/// operation log. `seqno` is the highest sequence number the repository
/// has *seen* for the org (applied or merge-rejected); `digest` is the
/// XOR of the content hashes of every op through that seqno —
/// order-independent over the op set, so two repos that have seen the
/// same ops agree on the mark regardless of exchange order.
///
/// Watermarks are the unit of the record-level delta-sync protocol
/// (API v3): a peer sends its marks, and [`RuntimeDataRepo::delta_for`]
/// returns exactly the ops past each mark — O(changed records), with a
/// digest check that falls back to a whole-org ship only when two logs
/// have genuinely diverged. Because *seen* (not just applied) ops
/// advance the mark, an org whose blind duplicate contributions a
/// peer's merge rejects is never re-offered.
///
/// `floor` is the acked-floor truncation horizon (API v4): ops
/// `1..=floor` have been folded into the org's base snapshot and are no
/// longer individually replayable. A repo that never truncates carries
/// `floor == 0` everywhere, which is also what [`Default`] yields — the
/// pre-v4 wire meaning is unchanged. A peer whose mark sits *below* a
/// sender's floor cannot be served a suffix; [`RuntimeDataRepo::delta_plan`]
/// falls back to a whole-org [`OrgSnapshot`] instead.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OrgWatermark {
    /// Highest op-log sequence number seen for the organization.
    pub seqno: u64,
    /// XOR of the content hashes of ops 1..=`seqno` (order-independent).
    pub digest: u64,
    /// Highest seqno folded into the org's base snapshot (0 = the full
    /// history is retained as individual ops).
    pub floor: u64,
}

/// The legacy (API v2) per-organization watermark: records *held* for
/// the org, not ops seen. Kept for the v2 compatibility translation of
/// `SyncPullV2`/`SyncPushV2` — the org-granular exchange that re-ships a
/// whole org whenever holdings differ.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OrgWatermarkV2 {
    /// Records attributed to the organization in the holdings.
    pub count: u64,
    /// XOR of the held records' content hashes (order-independent).
    pub digest: u64,
}

/// One sequence-numbered operation of an organization's log, as shipped
/// by the record-level sync protocol. The `seqno` is the *origin*
/// numbering: receivers that apply ops in order keep their log aligned
/// with the sender's, so subsequent exchanges ship only the suffix.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncOp {
    /// Organization whose log this op belongs to (always equals
    /// `record.org`; carried separately for grouping without touching
    /// the record).
    pub org: String,
    /// 1-based position in the org's operation log.
    pub seqno: u64,
    pub record: RuntimeRecord,
}

/// One op appended to an org log by a repository mutation, reported back
/// so the caller (a durable shard) can WAL-frame exactly what happened.
#[derive(Debug, Clone, PartialEq)]
pub struct LoggedOp {
    /// Sequence number the op received in its org's log.
    pub seqno: u64,
    pub record: RuntimeRecord,
    /// Whether the op mutated the holdings (`false`: a sync op that was
    /// seen — advancing the watermark — but rejected by merge dedup).
    pub applied: bool,
}

/// One retained entry of an org's operation log. Within an [`OrgLog`]
/// of floor `f`, entry `k` (0-based) holds seqno `f + k + 1`;
/// `cum_digest` is the XOR of content hashes of ops `1..=f+k+1`
/// (cumulative from genesis, *through* the folded prefix), so a prefix
/// digest is an O(1) lookup.
#[derive(Debug, Clone, PartialEq)]
struct LogEntry {
    record: RuntimeRecord,
    cum_digest: u64,
}

/// One organization's operation log: a folded prefix (`1..=floor`,
/// summarized by `floor_digest` and reconstructible only as current
/// holdings) plus the individually-retained suffix. Truncation
/// ([`RuntimeDataRepo::truncate_org_log`]) moves the floor forward and
/// drops entries; nothing else ever removes an entry, so memory held
/// per org is bounded by the unacked suffix.
#[derive(Debug, Clone, Default, PartialEq)]
struct OrgLog {
    /// Highest seqno folded into the base snapshot (0 = none).
    floor: u64,
    /// XOR of the content hashes of ops `1..=floor`.
    floor_digest: u64,
    /// Retained ops; entry `k` holds seqno `floor + k + 1`.
    entries: Vec<LogEntry>,
}

impl OrgLog {
    /// Log length = the org's watermark seqno (folded + retained).
    fn len(&self) -> u64 {
        self.floor + self.entries.len() as u64
    }

    /// Cumulative digest at the tip of the log.
    fn last_digest(&self) -> u64 {
        self.entries.last().map_or(self.floor_digest, |e| e.cum_digest)
    }

    /// Cumulative digest through `seqno`. `None` below the floor (the
    /// per-op history is folded away) and past the tip.
    fn digest_at(&self, seqno: u64) -> Option<u64> {
        if seqno < self.floor {
            return None;
        }
        if seqno == self.floor {
            return Some(self.floor_digest);
        }
        self.entries
            .get((seqno - self.floor - 1) as usize)
            .map(|e| e.cum_digest)
    }

    /// The retained entry holding `seqno` (`None` when folded or absent).
    fn entry(&self, seqno: u64) -> Option<&LogEntry> {
        if seqno <= self.floor {
            return None;
        }
        self.entries.get((seqno - self.floor - 1) as usize)
    }
}

/// One surfaced merge disagreement: two records shared a configuration
/// key but disagreed on the measured runtime. The deterministic order
/// ([`RuntimeRecord::wins_over`]) decides which survives; the loser is
/// reported here instead of being silently skipped — federated peers
/// need to *see* that their measurement was contested.
#[derive(Debug, Clone, PartialEq)]
pub struct MergeConflict {
    pub config_key: String,
    pub kept_org: String,
    pub kept_runtime_s: f64,
    pub dropped_org: String,
    pub dropped_runtime_s: f64,
}

/// Structured result of a merge.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MergeOutcome {
    /// Records with previously-unknown configurations, appended.
    pub added: usize,
    /// Existing records replaced because the incoming record wins the
    /// deterministic order (in place — the slot index is preserved).
    pub replaced: usize,
    /// Runtime disagreements encountered (whether or not the incoming
    /// side won).
    pub conflicts: Vec<MergeConflict>,
    /// The ops that actually changed the repository (adds and
    /// replacement winners), in application order, each with the org-log
    /// seqno it received. Each advanced the generation by exactly one;
    /// the segment store WAL-frames exactly these.
    pub applied: Vec<LoggedOp>,
}

impl MergeOutcome {
    /// Total mutations (adds + replacements) — how far the generation
    /// advanced.
    pub fn changed(&self) -> usize {
        self.added + self.replaced
    }
}

/// Structured result of applying a record-level sync delta
/// ([`RuntimeDataRepo::apply_sync_ops`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SyncOutcome {
    /// Previously-unknown configurations appended.
    pub added: usize,
    /// Existing records replaced by a deterministically-preferred
    /// incoming record.
    pub replaced: usize,
    /// Ops that changed no holdings: duplicate deliveries of
    /// already-seen ops, in-order seen-but-rejected ops (which still
    /// advance the watermark), and divergent-log ops the holdings
    /// already resolve. Always `ops.len() - changed()`.
    pub skipped: usize,
    /// Runtime disagreements surfaced (whichever side won).
    pub conflicts: Vec<MergeConflict>,
    /// Every op appended to an org log, in order — applied mutations
    /// *and* seen-but-rejected ops (which advance the watermark without
    /// touching the holdings or the generation). The segment store
    /// WAL-frames exactly these.
    pub logged: Vec<LoggedOp>,
}

impl SyncOutcome {
    /// Total holdings mutations (adds + replacements) — how far the
    /// generation advanced.
    pub fn changed(&self) -> usize {
        self.added + self.replaced
    }
}

/// A whole-org fallback shipment: the sender's current *holdings*
/// attributed to the org (canonical order) plus the sender's log
/// position. Shipped instead of per-op suffixes when the receiver's
/// mark sits below the sender's truncation floor — the folded per-op
/// history no longer exists, so the receiver adopts the holdings and
/// the position wholesale ([`RuntimeDataRepo::adopt_org_snapshot`]).
///
/// Adoption assumes the single-homed-org federation model: an org's
/// ops enter through one home repo, so a peer strictly behind the
/// sender's floor holds a strict subset and can take over the sender's
/// numbering. Dual-homed (divergent) orgs never reach this path — a
/// divergent floored org is merged content-level without adopting the
/// position, exactly the v2 cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct OrgSnapshot {
    pub org: String,
    /// Every record currently attributed to the org, canonical order.
    pub records: Vec<RuntimeRecord>,
    /// The sender's log tip for the org; the adopter installs it as
    /// its own mark with the whole prefix folded (`floor = seqno`).
    pub seqno: u64,
    /// Cumulative XOR digest through `seqno`.
    pub digest: u64,
}

/// The full answer to "what is this peer missing": per-op suffixes
/// where the logs are prefix-aligned above the floor, plus whole-org
/// snapshots for orgs whose retained history cannot cover the peer.
/// Produced by [`RuntimeDataRepo::delta_plan`]; an untruncated repo
/// always yields an empty `snapshots` list, so the v3 op-only path is
/// the common case.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SyncPlan {
    pub ops: Vec<SyncOp>,
    pub snapshots: Vec<OrgSnapshot>,
}

impl SyncPlan {
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty() && self.snapshots.is_empty()
    }
}

/// Bit-level record equality: floats compared by `to_bits`, so `-0.0`
/// vs `0.0` (or any payload change invisible to `==`) counts as a
/// change. The comparison [`RuntimeDataRepo::rebase_records`] uses to
/// decide whether a mirror slot must be re-journaled — featurization
/// consumes raw bits, so bit identity is the correct no-op criterion.
fn record_bits_equal(a: &RuntimeRecord, b: &RuntimeRecord) -> bool {
    a.job == b.job
        && a.org == b.org
        && a.machine == b.machine
        && a.scaleout == b.scaleout
        && a.runtime_s.to_bits() == b.runtime_s.to_bits()
        && a.job_features.len() == b.job_features.len()
        && a.job_features
            .iter()
            .zip(&b.job_features)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Outcome of resolving one pre-validated record against the holdings.
enum MergeEffect {
    Added,
    Replaced(Option<MergeConflict>),
    Rejected(Option<MergeConflict>),
}

/// One slot-level change to the record holdings, as recorded in the
/// repo's bounded delta journal. Consumers that mirror the holdings
/// (the incremental feature-matrix cache in [`featurize`]) replay these
/// instead of rebuilding from scratch.
///
/// `Set` carries the record *as written* — replaying against the
/// current holdings would be wrong once later deltas overwrite the
/// slot. `Reordered` carries the permutation applied by
/// [`RuntimeDataRepo::canonicalize`]: `perm[i]` is the old slot of the
/// record now living at slot `i`.
#[derive(Debug, Clone)]
pub enum RepoDelta {
    /// Slot `slot` now holds `record` (an append when `slot` equals the
    /// pre-mutation length, an in-place replacement otherwise).
    Set { slot: usize, record: RuntimeRecord },
    /// The holdings were permuted: new slot `i` holds what was at
    /// `perm[i]`.
    Reordered { perm: Vec<u32> },
}

/// Floor (and default) length of the delta journal. The *effective*
/// retention is the adaptive [`RuntimeDataRepo::journal_horizon`]:
/// mirrors report their refresh cadence via
/// [`RuntimeDataRepo::note_refresh`], and the journal retains at least
/// twice the largest observed between-refresh burst — so a bursty
/// write load that lands more than this floor between two retrains no
/// longer silently knocks its mirror off the incremental path. A repo
/// nobody mirrors never calls `note_refresh` and stays at this floor.
const DELTA_JOURNAL_CAP: usize = 1024;

/// A per-job shared repository of runtime records.
#[derive(Debug, Clone)]
pub struct RuntimeDataRepo {
    job: JobKind,
    records: Vec<RuntimeRecord>,
    /// Monotone generation counter: advances by the number of records a
    /// mutation actually added or replaced, and never moves otherwise.
    /// Consumers (the coordinator shards' model caches) key trained
    /// models on this value, so "the corpus did not change" is
    /// observable as "the generation did not change" — re-merging
    /// already-known data is a guaranteed no-op for retraining.
    generation: u64,
    /// Machine-type refcounts, maintained incrementally so the sorted
    /// observed-machines list is O(machines) per snapshot publish
    /// instead of O(records).
    machines: BTreeMap<String, usize>,
    /// Legacy (v2) per-org holdings watermarks (count + XOR digest),
    /// maintained incrementally — the view the `SyncPullV2` compatibility
    /// translation serves.
    org_marks: BTreeMap<String, OrgWatermarkV2>,
    /// Per-org operation logs: every op seen for the org (applied or
    /// merge-rejected), in sequence order. Append-only except for
    /// acked-floor truncation ([`RuntimeDataRepo::truncate_org_log`]),
    /// which folds a fully-acked prefix into the base snapshot — the
    /// log is the durable change history the WAL and the sync protocol
    /// both replay, bounded by the unacked suffix.
    org_logs: BTreeMap<String, OrgLog>,
    /// Merge-representative slot per configuration key: the slot of
    /// the record with the **smallest** [`RuntimeRecord::merge_priority`]
    /// among same-key records. Using the priority winner (not the first
    /// occurrence) keeps merging idempotent even when the blind
    /// contribute path has appended duplicate configurations: an
    /// incoming record identical to the local best is a no-op rather
    /// than a spurious replacement of a weaker duplicate. Maintained
    /// incrementally so merging `m` records into a repo of `n` is
    /// O(m log n); rebuilt after [`RuntimeDataRepo::canonicalize`]
    /// reorders the records.
    key_index: BTreeMap<String, usize>,
    /// Monotone counter of slot-level holdings changes — one tick per
    /// journaled [`RepoDelta`]. Unlike `generation` it also advances on
    /// canonical reorders (which change slot contents without changing
    /// the record set), so mirrors of the *layout* key on it.
    delta_seq: u64,
    /// The journaled deltas, newest at the back; entry `k` from the
    /// back carries seq `delta_seq - k`. Bounded by `journal_horizon`.
    deltas: VecDeque<RepoDelta>,
    /// Adaptive journal retention: `max(DELTA_JOURNAL_CAP, 2 × largest
    /// observed between-refresh delta burst)`. Grows monotonically with
    /// the observed refresh cadence; see [`RuntimeDataRepo::note_refresh`].
    journal_horizon: usize,
    /// `delta_seq` at the last [`RuntimeDataRepo::note_refresh`] call.
    last_refresh_seq: u64,
    /// Largest `delta_seq` advance observed between two refreshes.
    max_refresh_gap: u64,
}

impl RuntimeDataRepo {
    /// Empty repository for a job.
    pub fn new(job: JobKind) -> Self {
        RuntimeDataRepo {
            job,
            records: Vec::new(),
            generation: 0,
            machines: BTreeMap::new(),
            org_marks: BTreeMap::new(),
            org_logs: BTreeMap::new(),
            key_index: BTreeMap::new(),
            delta_seq: 0,
            deltas: VecDeque::new(),
            journal_horizon: DELTA_JOURNAL_CAP,
            last_refresh_seq: 0,
            max_refresh_gap: 0,
        }
    }

    /// Build from records (e.g. a corpus slice); invalid or foreign-job
    /// records are rejected.
    pub fn from_records<I: IntoIterator<Item = RuntimeRecord>>(job: JobKind, records: I) -> Self {
        let mut repo = RuntimeDataRepo::new(job);
        for r in records {
            repo.contribute(r).expect("invalid record");
        }
        repo
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    pub fn records(&self) -> &[RuntimeRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Current generation: advances by the number of records added or
    /// replaced. A repository whose generation is unchanged is
    /// guaranteed to hold exactly the same data, which is what the
    /// coordinator's model cache keys on.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Legacy alias for [`RuntimeDataRepo::generation`].
    pub fn version(&self) -> u64 {
        self.generation
    }

    /// Restore the generation counter after segment-store recovery. The
    /// generation can run ahead of `len()` (conflict replacements
    /// advance it without growing the repo), so replaying records alone
    /// cannot always reconstruct it. Recovery-only; must be monotone.
    pub(crate) fn restore_generation(&mut self, generation: u64) {
        assert!(
            generation >= self.generation,
            "generation restore must be monotone ({} < {})",
            generation,
            self.generation
        );
        self.generation = generation;
    }

    /// Journal one slot-level holdings change.
    fn delta_push(&mut self, d: RepoDelta) {
        self.delta_seq += 1;
        self.deltas.push_back(d);
        while self.deltas.len() > self.journal_horizon {
            self.deltas.pop_front();
        }
    }

    /// Tell the repo a mirror just refreshed to the current journal
    /// position, so retention can adapt to the observed cadence: the
    /// horizon becomes twice the largest burst of deltas ever seen
    /// between two refreshes (never below [`DELTA_JOURNAL_CAP`]).
    /// Called by the shard after each feature-cache refresh; a bursty
    /// write load thereby widens the journal instead of knocking its
    /// mirror off the incremental path.
    pub fn note_refresh(&mut self) {
        let gap = self.delta_seq - self.last_refresh_seq;
        self.last_refresh_seq = self.delta_seq;
        if gap > self.max_refresh_gap {
            self.max_refresh_gap = gap;
            self.journal_horizon = DELTA_JOURNAL_CAP.max(
                usize::try_from(self.max_refresh_gap.saturating_mul(2)).unwrap_or(usize::MAX),
            );
        }
    }

    /// Current adaptive journal retention (observability/tests).
    pub fn journal_horizon(&self) -> usize {
        self.journal_horizon
    }

    /// Sequence number of the newest journaled delta. Advances on every
    /// slot-level holdings change, *including* canonical reorders.
    pub fn delta_seq(&self) -> u64 {
        self.delta_seq
    }

    /// The journaled deltas past `since`, oldest first — what a mirror
    /// whose state reflects seq `since` must replay to catch up.
    /// `None` when the journal no longer retains that far back (or
    /// `since` is from the future): the mirror must rebuild.
    pub fn deltas_since(&self, since: u64) -> Option<impl Iterator<Item = &RepoDelta> + '_> {
        if since > self.delta_seq {
            return None;
        }
        let missing = (self.delta_seq - since) as usize;
        if missing > self.deltas.len() {
            return None;
        }
        Some(self.deltas.iter().skip(self.deltas.len() - missing))
    }

    fn cache_add(&mut self, r: &RuntimeRecord) {
        *self.machines.entry(r.machine.clone()).or_insert(0) += 1;
        let mark = self.org_marks.entry(r.org.clone()).or_default();
        mark.count += 1;
        mark.digest ^= r.content_hash();
    }

    /// Append one op to its org's operation log, returning the seqno it
    /// received. The log is append-only and independent of the holdings:
    /// replacements and merge rejections never remove entries.
    fn log_append(&mut self, r: &RuntimeRecord) -> u64 {
        let log = self.org_logs.entry(r.org.clone()).or_default();
        let prev = log.last_digest();
        log.entries.push(LogEntry {
            record: r.clone(),
            cum_digest: prev ^ r.content_hash(),
        });
        log.len()
    }

    /// Length of an org's operation log (its watermark seqno) —
    /// folded prefix included.
    pub fn log_len(&self, org: &str) -> u64 {
        self.org_logs.get(org).map_or(0, OrgLog::len)
    }

    /// The org's truncation floor: the highest seqno folded into the
    /// base snapshot (0 when the full history is retained).
    pub fn log_floor(&self, org: &str) -> u64 {
        self.org_logs.get(org).map_or(0, |l| l.floor)
    }

    /// Individually-retained op entries across all orgs — the op-log
    /// memory actually held, which truncation bounds by the unacked
    /// suffix (observability/tests).
    pub fn retained_log_entries(&self) -> usize {
        self.org_logs.values().map(|l| l.entries.len()).sum()
    }

    /// Per-org `(floor, floor_digest)` for every truncated org — what
    /// the segment store persists alongside the oplog sidecar so a
    /// floored log cold-recovers (empty for untruncated repos).
    pub(crate) fn log_floors(&self) -> BTreeMap<String, (u64, u64)> {
        self.org_logs
            .iter()
            .filter(|(_, l)| l.floor > 0)
            .map(|(org, l)| (org.clone(), (l.floor, l.floor_digest)))
            .collect()
    }

    /// Cumulative digest of an org's log through `seqno` (`None` when
    /// the position does not exist or lies below the floor).
    fn log_digest_at(&self, org: &str, seqno: u64) -> Option<u64> {
        if seqno == 0 {
            return None;
        }
        self.org_logs.get(org).and_then(|log| log.digest_at(seqno))
    }

    fn cache_remove(&mut self, r: &RuntimeRecord) {
        if let Some(n) = self.machines.get_mut(&r.machine) {
            *n -= 1;
            if *n == 0 {
                self.machines.remove(&r.machine);
            }
        }
        if let Some(mark) = self.org_marks.get_mut(&r.org) {
            mark.count -= 1;
            mark.digest ^= r.content_hash();
            if mark.count == 0 {
                self.org_marks.remove(&r.org);
            }
        }
    }

    /// Contribute one record (the "capture and save" step of Fig. 1).
    /// Returns the sequence number the op received in its org's log —
    /// the number the WAL frames it with and peers address it by.
    pub fn contribute(&mut self, r: RuntimeRecord) -> Result<u64, String> {
        if r.job != self.job {
            return Err(format!(
                "record for {} contributed to {} repo",
                r.job.name(),
                self.job.name()
            ));
        }
        r.validate()?;
        self.cache_add(&r);
        let seqno = self.log_append(&r);
        let next_slot = self.records.len();
        match self.key_index.entry(r.config_key()) {
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(next_slot);
            }
            std::collections::btree_map::Entry::Occupied(mut e) => {
                // duplicate configuration: the representative stays the
                // merge-priority winner
                if r.merge_priority() < self.records[*e.get()].merge_priority() {
                    e.insert(next_slot);
                }
            }
        }
        self.delta_push(RepoDelta::Set {
            slot: next_slot,
            record: r.clone(),
        });
        self.records.push(r);
        self.generation += 1;
        Ok(seqno)
    }

    /// Distinct contributing organizations.
    pub fn organizations(&self) -> BTreeSet<String> {
        self.org_marks.keys().cloned().collect()
    }

    /// Machine types observed in the shared data, sorted — served from
    /// the incremental refcount cache in O(machines), not O(records).
    pub fn observed_machines(&self) -> Vec<String> {
        self.machines.keys().cloned().collect()
    }

    /// Per-org high-water marks — each org's op-log position `(seqno,
    /// digest)` — what a peer sends to ask "what am I missing?".
    pub fn watermarks(&self) -> BTreeMap<String, OrgWatermark> {
        self.org_logs
            .iter()
            .map(|(org, log)| {
                (
                    org.clone(),
                    OrgWatermark {
                        seqno: log.len(),
                        digest: log.last_digest(),
                        floor: log.floor,
                    },
                )
            })
            .collect()
    }

    /// The legacy (v2) holdings watermarks, for the `SyncPullV2`
    /// compatibility translation.
    pub fn watermarks_v2(&self) -> BTreeMap<String, OrgWatermarkV2> {
        self.org_marks.clone()
    }

    /// Every *retained* op of `org`'s log past `seqno`, in sequence
    /// order — the record-level delta a peer whose mark sits at `seqno`
    /// is missing. Ops at or below the truncation floor are folded away
    /// and cannot be produced; callers that might sit below the floor
    /// go through [`RuntimeDataRepo::delta_plan`], which ships an
    /// [`OrgSnapshot`] instead.
    pub fn ops_since(&self, org: &str, seqno: u64) -> Vec<SyncOp> {
        match self.org_logs.get(org) {
            None => Vec::new(),
            Some(log) => {
                let from = seqno.max(log.floor);
                log.entries
                    .iter()
                    .enumerate()
                    .skip((from - log.floor) as usize)
                    .map(|(i, e)| SyncOp {
                        org: org.to_string(),
                        seqno: log.floor + i as u64 + 1,
                        record: e.record.clone(),
                    })
                    .collect()
            }
        }
    }

    /// Record-level delta extraction by watermark. For each org we hold,
    /// against the peer's claimed mark:
    ///
    /// * **unknown org** — ship the whole log.
    /// * **prefix-aligned** (peer seqno ≤ ours and their digest matches
    ///   our cumulative digest at that seqno) — ship only the ops past
    ///   their mark: **O(changed records)**, the common gossip path.
    /// * **complete** (equal seqno, equal digest) — ship nothing.
    /// * **peer ahead** — ship nothing; the reverse direction of the
    ///   exchange reconciles.
    /// * **divergent** (digest mismatch — the org's ops entered the
    ///   federation through more than one home, or a v2 peer injected
    ///   records) — fall back to shipping the whole log. Merge dedup
    ///   keeps the fallback correct; it costs what v2 always cost.
    ///
    /// `delta_for` is the op-only projection of
    /// [`RuntimeDataRepo::delta_plan`]: on an untruncated repo the two
    /// agree exactly. When a floor has folded history a below-floor
    /// peer needs, the plan's [`OrgSnapshot`] fallback carries it —
    /// this projection *drops* those orgs, so serve paths on
    /// possibly-truncated repos must use `delta_plan`.
    pub fn delta_for(&self, theirs: &BTreeMap<String, OrgWatermark>) -> Vec<SyncOp> {
        self.delta_plan(theirs).ops
    }

    /// Full delta extraction by watermark: per-op suffixes where the
    /// retained log covers the peer, whole-org [`OrgSnapshot`]s where
    /// the truncation floor has folded the history the peer is missing
    /// (unknown org below a floored log, mark below the floor, or a
    /// divergence the folded log can no longer re-ship op-by-op).
    pub fn delta_plan(&self, theirs: &BTreeMap<String, OrgWatermark>) -> SyncPlan {
        let mut plan = SyncPlan::default();
        for (org, log) in &self.org_logs {
            let len = log.len();
            let floor = log.floor;
            // `None`: ship ops from this seqno; `Some(snapshot)` below.
            let ship_from = match theirs.get(org) {
                None => 0,
                Some(m) if m.seqno > len => continue, // peer ahead
                Some(m) if m.seqno == len => {
                    if log.digest_at(len) == Some(m.digest) {
                        continue; // complete
                    }
                    0 // divergent
                }
                Some(m) => {
                    if m.seqno > 0 && log.digest_at(m.seqno) == Some(m.digest) {
                        m.seqno // prefix-aligned: ship the suffix only
                    } else {
                        0 // divergent, below the floor, or empty claim
                    }
                }
            };
            if ship_from < floor {
                // the ops the peer is missing are folded away: fall
                // back to the whole-org holdings + position snapshot
                plan.snapshots.push(self.org_snapshot(org, log));
            } else {
                plan.ops.extend(self.ops_since(org, ship_from));
            }
        }
        plan
    }

    /// Build the whole-org fallback shipment for `org`.
    fn org_snapshot(&self, org: &str, log: &OrgLog) -> OrgSnapshot {
        let mut records: Vec<RuntimeRecord> = self
            .records
            .iter()
            .filter(|r| r.org == org)
            .cloned()
            .collect();
        records.sort_by_cached_key(RuntimeRecord::canonical_sort_key);
        OrgSnapshot {
            org: org.to_string(),
            records,
            seqno: log.len(),
            digest: log.last_digest(),
        }
    }

    /// Apply a whole-org fallback shipment ([`OrgSnapshot`]): merge the
    /// records content-level, then — if the sender's position is ahead
    /// of ours — **adopt** it: the org's log is replaced by a fully
    /// folded log at the sender's `(seqno, digest)`, so the next
    /// exchange is quiescent. A sender position not ahead of ours means
    /// the org is genuinely divergent (dual-homed); the merge still
    /// lands every record but the local log is kept, preserving the
    /// content-dedup reconciliation path.
    ///
    /// Returns the merge outcome and whether the position was adopted.
    /// Adoption changes log state that no WAL line frames — a durable
    /// caller must follow with a snapshot compaction
    /// (`JobStore::compact_rebased`). An `Err` applies nothing.
    pub fn adopt_org_snapshot(
        &mut self,
        snap: &OrgSnapshot,
    ) -> Result<(SyncOutcome, bool), String> {
        for r in &snap.records {
            if r.job != self.job {
                return Err(format!(
                    "org snapshot record for {} pushed to {} repo",
                    r.job.name(),
                    self.job.name()
                ));
            }
            if r.org != snap.org {
                return Err(format!(
                    "org snapshot for {:?} holds a record attributed to {:?}",
                    snap.org, r.org
                ));
            }
            r.validate()?;
        }
        if snap.seqno == 0 {
            return Err("org snapshot seqno must be >= 1".into());
        }
        // Decide adoption against the PRE-merge position: strictly
        // behind the sender means single-homed catch-up (take over the
        // sender's numbering; applied records are covered by the folded
        // prefix, so nothing is logged — the caller's compaction
        // persists the adopted position). Otherwise the org is
        // divergent: applied records get fresh local seqnos, exactly
        // like `merge_records`, so they still propagate onward.
        let adopted = snap.seqno > self.log_len(&snap.org);
        let mut out = SyncOutcome::default();
        for r in &snap.records {
            let (applied, conflict) = match self.merge_one(r) {
                MergeEffect::Added => {
                    out.added += 1;
                    (true, None)
                }
                MergeEffect::Replaced(c) => {
                    out.replaced += 1;
                    (true, c)
                }
                MergeEffect::Rejected(c) => {
                    out.skipped += 1;
                    (false, c)
                }
            };
            out.conflicts.extend(conflict);
            if applied && !adopted {
                let seqno = self.log_append(r);
                out.logged.push(LoggedOp {
                    seqno,
                    record: r.clone(),
                    applied: true,
                });
            }
        }
        if adopted {
            self.org_logs.insert(
                snap.org.clone(),
                OrgLog {
                    floor: snap.seqno,
                    floor_digest: snap.digest,
                    entries: Vec::new(),
                },
            );
        }
        Ok((out, adopted))
    }

    /// Fold the fully-acked prefix `1..=floor` of `org`'s log into the
    /// base snapshot, dropping the retained entries it covers. Holdings,
    /// caches, and the generation are untouched — truncation is a pure
    /// memory/history fold; the watermark keeps its `(seqno, digest)`
    /// and gains the floor. Floors only move forward; a floor at or
    /// below the current one (or past the tip) is clamped. Returns the
    /// number of entries dropped.
    ///
    /// Durability: the WAL has no truncation op — a durable caller
    /// folds the store too by compacting right after
    /// (`JobStore::compact`), which rewrites the oplog sidecar as the
    /// retained suffix plus a floor sidecar. A crash in between merely
    /// recovers the untruncated (superset) log.
    pub fn truncate_org_log(&mut self, org: &str, floor: u64) -> u64 {
        let Some(log) = self.org_logs.get_mut(org) else {
            return 0;
        };
        let target = floor.min(log.len());
        if target <= log.floor {
            return 0;
        }
        let drop = (target - log.floor) as usize;
        log.floor_digest = log.entries[drop - 1].cum_digest;
        log.entries.drain(..drop);
        log.floor = target;
        drop as u64
    }

    /// Legacy (v2) org-granular delta extraction: every *held* record of
    /// each organization whose holdings watermark differs from `theirs`.
    /// A changed org ships whole — O(org corpus) — and an org holding
    /// blind-contributed duplicates a peer's merge never accepts is
    /// re-offered forever. Kept solely to serve v2 peers (and as the
    /// comparison path of `benches/sync_throughput.rs`).
    pub fn delta_for_v2(&self, theirs: &BTreeMap<String, OrgWatermarkV2>) -> Vec<RuntimeRecord> {
        let stale: BTreeSet<&String> = self
            .org_marks
            .iter()
            .filter(|&(org, mark)| theirs.get(org) != Some(mark))
            .map(|(org, _)| org)
            .collect();
        if stale.is_empty() {
            return Vec::new();
        }
        self.records
            .iter()
            .filter(|r| stale.contains(&r.org))
            .cloned()
            .collect()
    }

    /// Order-independent digest of the whole record set (XOR of content
    /// hashes). Two converged peers agree on it; a cheap equality probe
    /// for the `c3o sync` driver and the federation tests. (Exact
    /// duplicate records XOR-cancel — use [`Self::canonical_records`]
    /// for a collision-proof comparison.)
    pub fn content_digest(&self) -> u64 {
        self.records.iter().fold(0u64, |acc, r| acc ^ r.content_hash())
    }

    /// Sort the records into the canonical federation order (config
    /// key, then org, then runtime bits). Two repos holding the same
    /// record *set* become bitwise-identical — including iteration
    /// order, hence identical downstream featurization and training
    /// inputs. Content is unchanged, so the generation does not move.
    /// The sync write path canonicalizes after applying a delta.
    pub fn canonicalize(&mut self) {
        // Sort slot indices by precomputed keys instead of the records
        // themselves: both index sort and `sort_by_cached_key` are
        // stable, so the resulting order is identical — and the index
        // vector *is* the permutation the delta journal needs.
        let keys: Vec<(String, String, u64)> = self
            .records
            .iter()
            .map(RuntimeRecord::canonical_sort_key)
            .collect();
        let mut perm: Vec<u32> = (0..self.records.len() as u32).collect();
        perm.sort_by(|&a, &b| keys[a as usize].cmp(&keys[b as usize]));
        if perm.iter().enumerate().any(|(i, &p)| p as usize != i) {
            let mut slots: Vec<Option<RuntimeRecord>> =
                self.records.drain(..).map(Some).collect();
            self.records = perm
                .iter()
                .map(|&p| slots[p as usize].take().expect("permutation is a bijection"))
                .collect();
            self.delta_push(RepoDelta::Reordered { perm });
        }
        // the reorder invalidated the representative slots; rebuild
        // them as the merge-priority winner per key
        self.key_index.clear();
        for (i, r) in self.records.iter().enumerate() {
            match self.key_index.entry(r.config_key()) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(i);
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    if r.merge_priority() < self.records[*e.get()].merge_priority() {
                        e.insert(i);
                    }
                }
            }
        }
    }

    /// Rebase a *mirror* repository onto a new same-length record list,
    /// journaling one [`RepoDelta::Set`] per slot whose record actually
    /// changed (bit-level comparison, so a slot whose float bits are
    /// untouched replays as a no-op in the feature cache). Built for
    /// the coordinator's sampled-retrain mirror: when the coverage
    /// sample of an over-capacity corpus shifts by a few records, the
    /// mirror's [`FeatureMatrixCache`] refeaturizes only those slots
    /// instead of the whole sample. Returns the number of changed
    /// slots.
    ///
    /// Maintains the holdings, the machine/org caches, the key index,
    /// the generation, and the delta journal — **not** the op logs: a
    /// mirror never federates, which is why this is crate-private.
    ///
    /// # Panics
    /// Panics when `records` has a different length than the holdings
    /// (a resized sample must rebuild its mirror instead).
    pub(crate) fn rebase_records(&mut self, records: &[RuntimeRecord]) -> usize {
        assert_eq!(
            records.len(),
            self.records.len(),
            "rebase requires an equal-length record list"
        );
        let mut changed = 0usize;
        for (slot, r) in records.iter().enumerate() {
            if record_bits_equal(&self.records[slot], r) {
                continue;
            }
            let dropped = self.records[slot].clone();
            self.cache_remove(&dropped);
            self.cache_add(r);
            self.delta_push(RepoDelta::Set {
                slot,
                record: r.clone(),
            });
            self.records[slot] = r.clone();
            self.generation += 1;
            changed += 1;
        }
        if changed > 0 {
            // replaced slots may have moved merge representatives;
            // rebuild the index as the priority winner per key
            self.key_index.clear();
            for (i, r) in self.records.iter().enumerate() {
                match self.key_index.entry(r.config_key()) {
                    std::collections::btree_map::Entry::Vacant(e) => {
                        e.insert(i);
                    }
                    std::collections::btree_map::Entry::Occupied(mut e) => {
                        if r.merge_priority() < self.records[*e.get()].merge_priority() {
                            e.insert(i);
                        }
                    }
                }
            }
        }
        changed
    }

    /// A canonically-ordered clone of the records — the equality form
    /// the federation tests compare peers by.
    pub fn canonical_records(&self) -> Vec<RuntimeRecord> {
        let mut rs = self.records.clone();
        rs.sort_by_cached_key(RuntimeRecord::canonical_sort_key);
        rs
    }

    /// Fork: an independent copy (DataHub/DVC-style).
    pub fn fork(&self) -> RuntimeDataRepo {
        self.clone()
    }

    /// Merge another repository of the same job into this one. See
    /// [`RuntimeDataRepo::merge_records`] for the semantics.
    pub fn merge(&mut self, other: &RuntimeDataRepo) -> Result<MergeOutcome, String> {
        if other.job != self.job {
            return Err("cannot merge repos of different jobs".into());
        }
        self.merge_records(&other.records)
    }

    /// Merge a batch of records (the `SyncPush` application path, and
    /// the body of [`RuntimeDataRepo::merge`]).
    ///
    /// Per incoming record, by [`RuntimeRecord::config_key`]:
    ///
    /// * **unknown configuration** — appended (`added`).
    /// * **known configuration, incoming wins** the deterministic total
    ///   order ([`RuntimeRecord::wins_over`]) — replaces the existing
    ///   record *in place* (`replaced`); a runtime disagreement is also
    ///   reported as a [`MergeConflict`].
    /// * **known configuration, existing wins** — nothing changes; a
    ///   runtime disagreement is still reported.
    ///
    /// The winner rule is order-independent, so merging is idempotent
    /// and commutative over record sets: peers exchanging deltas in any
    /// gossip order converge to the same contents. The generation
    /// advances by `added + replaced` — exactly the records in
    /// [`MergeOutcome::applied`]. An `Err` applies **nothing**: the
    /// batch is validated in full before the first mutation.
    pub fn merge_records(&mut self, incoming: &[RuntimeRecord]) -> Result<MergeOutcome, String> {
        // Validate the WHOLE batch before applying anything: a
        // half-applied delta would advance the generation while the
        // request errors, leaving callers (and any attached segment
        // store, which only logs successful applies) desynced from the
        // repo. Rejecting up front keeps a failed merge side-effect-free.
        for r in incoming {
            if r.job != self.job {
                return Err(format!(
                    "record for {} merged into {} repo",
                    r.job.name(),
                    self.job.name()
                ));
            }
            r.validate()?;
        }
        // The maintained index resolves each incoming record against
        // its merge representative — the priority winner among local
        // same-key records, so a record the repo already holds (even
        // alongside weaker blind-contributed duplicates) merges as a
        // no-op. Applied records are appended to their org's op log
        // with fresh local seqnos (this repo is their federation home).
        let mut out = MergeOutcome::default();
        for r in incoming {
            match self.merge_one(r) {
                MergeEffect::Added => {
                    out.added += 1;
                    let seqno = self.log_append(r);
                    out.applied.push(LoggedOp {
                        seqno,
                        record: r.clone(),
                        applied: true,
                    });
                }
                MergeEffect::Replaced(conflict) => {
                    out.replaced += 1;
                    out.conflicts.extend(conflict);
                    let seqno = self.log_append(r);
                    out.applied.push(LoggedOp {
                        seqno,
                        record: r.clone(),
                        applied: true,
                    });
                }
                MergeEffect::Rejected(conflict) => {
                    out.conflicts.extend(conflict);
                    // identical or losing record: holdings unchanged,
                    // and a locally-shared reject is not logged (it
                    // never entered the federation)
                }
            }
        }
        Ok(out)
    }

    /// Resolve one pre-validated record against the holdings by the
    /// deterministic merge order — the single mutation primitive shared
    /// by [`RuntimeDataRepo::merge_records`] and
    /// [`RuntimeDataRepo::apply_sync_ops`]. Touches the holdings, the
    /// key index, the caches, and the generation; never the op logs
    /// (callers decide what to log, and with which seqno).
    fn merge_one(&mut self, r: &RuntimeRecord) -> MergeEffect {
        let key = r.config_key();
        match self.key_index.get(&key).copied() {
            None => {
                self.key_index.insert(key, self.records.len());
                self.cache_add(r);
                self.delta_push(RepoDelta::Set {
                    slot: self.records.len(),
                    record: r.clone(),
                });
                self.records.push(r.clone());
                self.generation += 1;
                MergeEffect::Added
            }
            Some(slot) => {
                let existing = &self.records[slot];
                let disagrees = existing.runtime_s.to_bits() != r.runtime_s.to_bits();
                if r.wins_over(existing) {
                    let conflict = disagrees.then(|| MergeConflict {
                        config_key: key,
                        kept_org: r.org.clone(),
                        kept_runtime_s: r.runtime_s,
                        dropped_org: existing.org.clone(),
                        dropped_runtime_s: existing.runtime_s,
                    });
                    let dropped = self.records[slot].clone();
                    self.cache_remove(&dropped);
                    self.cache_add(r);
                    self.delta_push(RepoDelta::Set {
                        slot,
                        record: r.clone(),
                    });
                    self.records[slot] = r.clone();
                    self.generation += 1;
                    MergeEffect::Replaced(conflict)
                } else {
                    MergeEffect::Rejected(disagrees.then(|| MergeConflict {
                        config_key: key,
                        kept_org: existing.org.clone(),
                        kept_runtime_s: existing.runtime_s,
                        dropped_org: r.org.clone(),
                        dropped_runtime_s: r.runtime_s,
                    }))
                }
            }
        }
    }

    /// Apply a record-level sync delta (the `SyncPush` body). Per op,
    /// against the op's org log:
    ///
    /// * **already seen** (seqno within the log, same content) — skipped
    ///   outright; re-delivery is a no-op.
    /// * **in-order extension** (seqno == log length + 1) — the op is
    ///   appended to the log *with the origin's numbering*, keeping this
    ///   log a prefix of the sender's, and merged into the holdings.
    ///   A merge-rejected op (e.g. a blind duplicate the dedup order
    ///   refuses) is still logged as *seen*: the watermark advances, so
    ///   the op is never offered to us again — without moving the
    ///   generation.
    /// * **divergent** (a different op already sits at that seqno, or
    ///   the seqno leaves a gap) — the op falls back to content-level
    ///   dedup: an applied record is logged with a fresh local seqno,
    ///   a rejected one is skipped. Divergent orgs keep exchanging at
    ///   v2 (whole-org) cost but never lose data.
    ///
    /// An `Err` applies **nothing**: the batch is validated in full
    /// before the first mutation, like [`RuntimeDataRepo::merge_records`].
    pub fn apply_sync_ops(&mut self, ops: &[SyncOp]) -> Result<SyncOutcome, String> {
        for op in ops {
            if op.record.job != self.job {
                return Err(format!(
                    "sync op for {} pushed to {} repo",
                    op.record.job.name(),
                    self.job.name()
                ));
            }
            if op.seqno == 0 {
                return Err("sync op seqno must be >= 1".into());
            }
            if op.org != op.record.org {
                return Err(format!(
                    "sync op org {:?} does not match its record's org {:?}",
                    op.org, op.record.org
                ));
            }
            op.record.validate()?;
        }
        let mut out = SyncOutcome::default();
        for op in ops {
            let len = self.log_len(&op.org);
            // A seqno at or below the truncation floor has no retained
            // entry to compare against (`OrgLog::entry` yields `None`);
            // such an op falls through to content-level merge dedup,
            // which resolves it exactly like the divergent path.
            if op.seqno <= len {
                if let Some(entry) = self
                    .org_logs
                    .get(&op.org)
                    .and_then(|log| log.entry(op.seqno))
                {
                    if entry.record.content_hash() == op.record.content_hash() {
                        out.skipped += 1; // duplicate delivery of a seen op
                        continue;
                    }
                }
            }
            let in_order = op.seqno == len + 1;
            let (applied, conflict) = match self.merge_one(&op.record) {
                MergeEffect::Added => {
                    out.added += 1;
                    (true, None)
                }
                MergeEffect::Replaced(c) => {
                    out.replaced += 1;
                    (true, c)
                }
                MergeEffect::Rejected(c) => (false, c),
            };
            out.conflicts.extend(conflict);
            if in_order {
                let seqno = self.log_append(&op.record);
                debug_assert_eq!(seqno, op.seqno, "in-order append keeps origin numbering");
                if !applied {
                    out.skipped += 1; // seen: watermark advances, holdings don't
                }
                out.logged.push(LoggedOp {
                    seqno,
                    record: op.record.clone(),
                    applied,
                });
            } else if applied {
                // divergent log: keep the record, renumber locally
                let seqno = self.log_append(&op.record);
                out.logged.push(LoggedOp {
                    seqno,
                    record: op.record.clone(),
                    applied: true,
                });
            } else {
                out.skipped += 1; // divergent and already resolved
            }
        }
        Ok(out)
    }

    /// Replay one *seen* (merge-rejected) sync op during segment-store
    /// recovery: append it to its org's log without touching the
    /// holdings or the generation. Returns the seqno it received.
    pub(crate) fn replay_seen(&mut self, record: RuntimeRecord) -> Result<u64, String> {
        if record.job != self.job {
            return Err(format!(
                "seen op for {} replayed into {} repo",
                record.job.name(),
                self.job.name()
            ));
        }
        record.validate()?;
        Ok(self.log_append(&record))
    }

    /// Replace the op logs wholesale with recovered history (the
    /// `oplog-<gen>.csv` snapshot sidecar, plus the `floor-<gen>.csv`
    /// truncation floors). Recovery-only: the default logs built while
    /// loading a holdings snapshot know nothing of replaced,
    /// seen-but-rejected, or folded ops, which only the sidecars (or
    /// the WAL) preserve. Per-org records must arrive in sequence
    /// order, each org's first retained record at `floor + 1`.
    pub(crate) fn restore_org_logs(
        &mut self,
        floors: BTreeMap<String, (u64, u64)>,
        logs: BTreeMap<String, Vec<RuntimeRecord>>,
    ) -> Result<(), String> {
        self.org_logs.clear();
        for (org, (floor, floor_digest)) in floors {
            self.org_logs.insert(
                org,
                OrgLog {
                    floor,
                    floor_digest,
                    entries: Vec::new(),
                },
            );
        }
        for (org, records) in logs {
            for r in records {
                if r.org != org {
                    return Err(format!(
                        "op log for {org:?} holds a record attributed to {:?}",
                        r.org
                    ));
                }
                self.log_append(&r);
            }
        }
        Ok(())
    }

    /// CSV header for this job's schema.
    fn header(&self) -> Vec<String> {
        let mut h = vec![
            "job".to_string(),
            "org".to_string(),
            "machine".to_string(),
            "scaleout".to_string(),
        ];
        h.extend(self.job.feature_names().iter().map(|s| s.to_string()));
        h.push("runtime_s".to_string());
        h
    }

    /// Serialize to a CSV [`Table`] (the on-disk sharing format).
    pub fn to_table(&self) -> Table {
        let header = self.header();
        let mut t = Table {
            header,
            rows: Vec::new(),
        };
        for r in &self.records {
            let mut row = vec![
                r.job.name().to_string(),
                r.org.clone(),
                r.machine.clone(),
                r.scaleout.to_string(),
            ];
            row.extend(r.job_features.iter().map(|f| format!("{f}")));
            row.push(format!("{}", r.runtime_s));
            t.push(row);
        }
        t
    }

    /// Persist to CSV.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        self.to_table().save(path)
    }

    /// Load from CSV; rejects schema mismatches.
    pub fn load(job: JobKind, path: &Path) -> Result<RuntimeDataRepo, String> {
        let t = Table::load(path).map_err(|e| e.to_string())?;
        Self::from_table(job, &t)
    }

    /// Parse from a CSV table.
    pub fn from_table(job: JobKind, t: &Table) -> Result<RuntimeDataRepo, String> {
        let mut repo = RuntimeDataRepo::new(job);
        let expect = repo.header();
        if t.header != expect {
            return Err(format!(
                "schema mismatch: got {:?}, want {:?}",
                t.header, expect
            ));
        }
        let nf = job.feature_names().len();
        for row in &t.rows {
            let parse_f = |s: &str| -> Result<f64, String> {
                s.parse().map_err(|_| format!("bad number {s:?}"))
            };
            let rec = RuntimeRecord {
                job: JobKind::parse(&row[0]).ok_or_else(|| format!("bad job {:?}", row[0]))?,
                org: row[1].clone(),
                machine: row[2].clone(),
                scaleout: row[3].parse().map_err(|_| "bad scaleout".to_string())?,
                job_features: row[4..4 + nf]
                    .iter()
                    .map(|s| parse_f(s))
                    .collect::<Result<_, _>>()?,
                runtime_s: parse_f(&row[4 + nf])?,
            };
            repo.contribute(rec)?;
        }
        Ok(repo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(org: &str, machine: &str, scaleout: u32, gb: f64, runtime: f64) -> RuntimeRecord {
        RuntimeRecord {
            job: JobKind::Sort,
            org: org.into(),
            machine: machine.into(),
            scaleout,
            job_features: vec![gb],
            runtime_s: runtime,
        }
    }

    #[test]
    fn contribute_and_len() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.is_empty());
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(repo.len(), 1);
        assert_eq!(repo.version(), 1);
    }

    #[test]
    fn delta_journal_records_sets_and_reorders() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert_eq!(repo.delta_seq(), 0);
        repo.contribute(rec("b", "m5.xlarge", 8, 10.0, 50.0)).unwrap();
        repo.contribute(rec("a", "c5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(repo.delta_seq(), 2);
        let ds: Vec<&RepoDelta> = repo.deltas_since(0).unwrap().collect();
        assert_eq!(ds.len(), 2);
        match ds[0] {
            RepoDelta::Set { slot, record } => {
                assert_eq!(*slot, 0);
                assert_eq!(record.org, "b");
            }
            other => panic!("expected Set, got {other:?}"),
        }
        // canonicalize reorders (c5 key sorts before m5) and journals
        // the permutation without moving the generation
        let gen = repo.generation();
        repo.canonicalize();
        assert_eq!(repo.generation(), gen);
        assert_eq!(repo.delta_seq(), 3);
        let ds: Vec<&RepoDelta> = repo.deltas_since(2).unwrap().collect();
        assert_eq!(ds.len(), 1);
        match ds[0] {
            RepoDelta::Reordered { perm } => assert_eq!(perm, &[1, 0]),
            other => panic!("expected Reordered, got {other:?}"),
        }
        // a second canonicalize is a no-op: already in order, nothing journaled
        repo.canonicalize();
        assert_eq!(repo.delta_seq(), 3);
        // a replacement journals a Set at the replaced slot
        let out = repo
            .merge_records(&[rec("c", "c5.xlarge", 4, 10.0, 90.0)])
            .unwrap();
        assert_eq!(out.replaced, 1);
        match repo.deltas_since(3).unwrap().next().unwrap() {
            RepoDelta::Set { slot, record } => {
                assert_eq!(*slot, 0);
                assert_eq!(record.org, "c");
            }
            other => panic!("expected Set, got {other:?}"),
        }
        // future or truncated positions yield None
        assert!(repo.deltas_since(99).is_none());
    }

    #[test]
    fn delta_journal_is_bounded() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        for i in 0..(DELTA_JOURNAL_CAP + 10) {
            repo.contribute(rec("a", "m5.xlarge", 2 + (i as u32 % 30), i as f64 + 1.0, 10.0))
                .unwrap();
        }
        assert_eq!(repo.delta_seq() as usize, DELTA_JOURNAL_CAP + 10);
        assert!(repo.deltas_since(0).is_none(), "oldest deltas were dropped");
        assert!(repo.deltas_since(10).is_some());
        assert_eq!(
            repo.deltas_since(10).unwrap().count(),
            DELTA_JOURNAL_CAP,
            "exactly the cap is retained"
        );
    }

    #[test]
    fn journal_horizon_adapts_to_refresh_cadence() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert_eq!(repo.journal_horizon(), DELTA_JOURNAL_CAP);
        // small bursts between refreshes leave the floor untouched
        for i in 0..10 {
            repo.contribute(rec("a", "m5.xlarge", 2 + i, 1.0 + f64::from(i), 10.0))
                .unwrap();
        }
        repo.note_refresh();
        assert_eq!(repo.journal_horizon(), DELTA_JOURNAL_CAP);
        // a burst beyond the floor widens retention to 2× the burst...
        let burst = DELTA_JOURNAL_CAP + 100;
        for i in 0..burst {
            repo.contribute(rec("b", "m5.xlarge", 2 + (i as u32 % 30), 1e6 + i as f64, 10.0))
                .unwrap();
        }
        repo.note_refresh();
        assert_eq!(repo.journal_horizon(), 2 * burst);
        // ...so an equally large follow-up burst stays fully replayable
        let mark = repo.delta_seq();
        for i in 0..burst {
            repo.contribute(rec("c", "m5.xlarge", 2 + (i as u32 % 30), 2e6 + i as f64, 10.0))
                .unwrap();
        }
        assert_eq!(repo.deltas_since(mark).unwrap().count(), burst);
        // smaller gaps never shrink the horizon back
        repo.note_refresh();
        repo.contribute(rec("d", "m5.xlarge", 2, 3e6, 10.0)).unwrap();
        repo.note_refresh();
        assert_eq!(repo.journal_horizon(), 2 * burst);
    }

    #[test]
    fn rebase_journals_only_changed_slots() {
        let mut mirror = RuntimeDataRepo::from_records(
            JobKind::Sort,
            vec![
                rec("a", "m5.xlarge", 4, 10.0, 100.0),
                rec("a", "c5.xlarge", 8, 10.0, 60.0),
                rec("b", "r5.xlarge", 2, 10.0, 300.0),
            ],
        );
        let seq = mirror.delta_seq();
        // identical list: nothing journaled
        let same: Vec<RuntimeRecord> = mirror.records().to_vec();
        assert_eq!(mirror.rebase_records(&same), 0);
        assert_eq!(mirror.delta_seq(), seq);
        // one slot swapped for a different record: exactly one Set
        let mut next = same.clone();
        next[1] = rec("c", "c5.2xlarge", 6, 12.0, 80.0);
        assert_eq!(mirror.rebase_records(&next), 1);
        assert_eq!(mirror.delta_seq(), seq + 1);
        match mirror.deltas_since(seq).unwrap().next().unwrap() {
            RepoDelta::Set { slot, record } => {
                assert_eq!(*slot, 1);
                assert_eq!(record.org, "c");
            }
            other => panic!("expected Set, got {other:?}"),
        }
        assert_eq!(mirror.records()[1].machine, "c5.2xlarge");
        // the machine refcount cache followed the swap
        assert!(!mirror.observed_machines().contains(&"c5.xlarge".to_string()));
        assert!(mirror.observed_machines().contains(&"c5.2xlarge".to_string()));
    }

    #[test]
    fn rebase_keeps_feature_cache_incremental() {
        use crate::cloud::Cloud;
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let mut mirror = RuntimeDataRepo::from_records(
            JobKind::Sort,
            vec![
                rec("a", "m5.xlarge", 4, 10.0, 100.0),
                rec("a", "c5.xlarge", 8, 10.0, 60.0),
                rec("b", "r5.xlarge", 2, 10.0, 300.0),
            ],
        );
        let mut cache = FeatureMatrixCache::new();
        cache.refresh(&f, &mirror);
        let mut next: Vec<RuntimeRecord> = mirror.records().to_vec();
        next[2] = rec("b", "m5.2xlarge", 6, 11.0, 200.0);
        mirror.rebase_records(&next);
        // only the rebased slot is refeaturized; the rest replay
        assert_eq!(cache.refresh(&f, &mirror), mirror.len() - 1);
        let (_, x, _) = cache.fit(&mirror);
        let (_, want_x, _) = f.fit(&mirror);
        let bits = |m: &crate::util::matrix::MatF32| {
            m.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
        };
        assert_eq!(bits(&x), bits(&want_x));
    }

    #[test]
    fn rejects_wrong_job() {
        let mut repo = RuntimeDataRepo::new(JobKind::Grep);
        let err = repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0));
        assert!(err.is_err());
    }

    #[test]
    fn rejects_invalid_records() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.contribute(rec("a", "m", 0, 10.0, 100.0)).is_err());
        assert!(repo.contribute(rec("a", "m", 4, 10.0, -5.0)).is_err());
        assert!(repo.contribute(rec("a", "m", 4, f64::NAN, 5.0)).is_err());
        let wrong_arity = RuntimeRecord {
            job_features: vec![1.0, 2.0],
            ..rec("a", "m", 4, 10.0, 100.0)
        };
        assert!(repo.contribute(wrong_arity).is_err());
    }

    #[test]
    fn merge_dedups_by_config_and_reports_conflicts() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        let mut b = a.fork();
        b.contribute(rec("orgB", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        // orgB also re-measured orgA's config — duplicate by key, with a
        // disagreeing (and losing: 102 > 100) runtime
        b.contribute(rec("orgB", "m5.xlarge", 4, 10.0, 102.0)).unwrap();
        let out = a.merge(&b).unwrap();
        assert_eq!(out.added, 1, "only the new configuration is merged");
        assert_eq!(out.replaced, 0, "the existing lower runtime wins");
        assert_eq!(a.len(), 2);
        // the disagreement is surfaced, not silently skipped
        assert_eq!(out.conflicts.len(), 1);
        let c = &out.conflicts[0];
        assert_eq!(c.kept_org, "orgA");
        assert_eq!(c.dropped_org, "orgB");
        assert_eq!(c.kept_runtime_s, 100.0);
        assert_eq!(c.dropped_runtime_s, 102.0);
        // merging again changes nothing (the conflict is re-reported)
        let again = a.merge(&b).unwrap();
        assert_eq!(again.changed(), 0);
        assert_eq!(again.conflicts.len(), 1);
    }

    #[test]
    fn merge_replacement_is_deterministic_and_order_independent() {
        // Same configuration measured twice with different runtimes: the
        // deterministic order keeps the smaller (runtime, org) pair on
        // BOTH merge directions, so peers converge.
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 10.0, 102.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("orgB", "m5.xlarge", 4, 10.0, 100.0)).unwrap();

        let mut ab = a.fork();
        let out = ab.merge(&b).unwrap();
        assert_eq!((out.added, out.replaced), (0, 1), "incoming 100.0 wins");
        assert_eq!(out.conflicts.len(), 1);
        assert_eq!(out.applied.len(), 1);
        assert_eq!(ab.len(), 1);
        assert_eq!(ab.records()[0].org, "orgB");
        assert_eq!(ab.generation(), 2, "replacement advances the generation");

        let mut ba = b.fork();
        let out = ba.merge(&a).unwrap();
        assert_eq!((out.added, out.replaced), (0, 0), "existing 100.0 wins");
        assert_eq!(out.conflicts.len(), 1);
        assert_eq!(ba.records(), ab.records(), "both directions converge");
    }

    #[test]
    fn config_key_normalizes_signed_zero_and_nan() {
        // -0.0 and 0.0 are the same grid point; they must share one key.
        let pos = rec("a", "m5.xlarge", 4, 0.0, 100.0);
        let neg = rec("b", "m5.xlarge", 4, -0.0, 102.0);
        assert_eq!(pos.config_key(), neg.config_key());
        // every NaN payload canonicalizes to the same token (config_key
        // must stay total even on records that validation would reject)
        let nan_a = rec("a", "m5.xlarge", 4, f64::NAN, 100.0);
        let nan_b = rec("a", "m5.xlarge", 4, -f64::NAN, 100.0);
        assert_eq!(nan_a.config_key(), nan_b.config_key());
        assert!(nan_a.config_key().contains("nan"));
    }

    #[test]
    fn merge_dedups_signed_zero_grid_points() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 0.0, 100.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("orgB", "m5.xlarge", 4, -0.0, 101.0)).unwrap();
        let out = a.merge(&b).unwrap();
        assert_eq!(out.added, 0, "-0.0 must dedup against 0.0");
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn generation_tracks_records_added() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        assert_eq!(a.generation(), 0);
        a.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(a.generation(), 1);
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("b", "m5.xlarge", 6, 10.0, 90.0)).unwrap();
        b.contribute(rec("b", "m5.xlarge", 8, 10.0, 80.0)).unwrap();
        a.merge(&b).unwrap();
        assert_eq!(a.generation(), 3, "merge advances by records added");
        // idempotent re-merge: no data change, no generation change
        let before = a.generation();
        assert_eq!(a.merge(&b).unwrap().changed(), 0);
        assert_eq!(a.generation(), before);
    }

    #[test]
    fn merge_rejects_cross_job() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        let b = RuntimeDataRepo::new(JobKind::Grep);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn merge_is_idempotent_despite_blind_duplicates() {
        // The submit path appends duplicate configurations blindly; the
        // merge representative must be the priority winner among them,
        // so re-receiving a record the repo already holds is a no-op —
        // not a spurious replacement of the weaker duplicate.
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 90.0)).unwrap(); // dup, better
        let before = repo.records().to_vec();
        let gen = repo.generation();
        // a peer ships back exactly the winner we already hold
        let out = repo
            .merge_records(&[rec("a", "m5.xlarge", 4, 10.0, 90.0)])
            .unwrap();
        assert_eq!(out.changed(), 0, "identical-to-best must be a no-op");
        assert_eq!(repo.records(), &before[..], "no duplication, no swap");
        assert_eq!(repo.generation(), gen);
        // a genuinely better measurement still replaces the winner
        let out = repo
            .merge_records(&[rec("b", "m5.xlarge", 4, 10.0, 80.0)])
            .unwrap();
        assert_eq!(out.replaced, 1);
        assert_eq!(
            repo.records().iter().filter(|r| r.runtime_s == 80.0).count(),
            1
        );
    }

    #[test]
    fn rejects_framing_unsafe_org_and_machine() {
        // the WAL is line-framed: newlines in text fields are rejected
        // at validation, before any repository (or store) mutation
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.contribute(rec("or\ng", "m5.xlarge", 4, 10.0, 1.0)).is_err());
        assert!(repo.contribute(rec("org", "m5\r.xlarge", 4, 10.0, 1.0)).is_err());
        assert!(repo.is_empty());
    }

    #[test]
    fn failed_merge_applies_nothing() {
        // A batch with an invalid record mid-stream must be rejected
        // atomically: no records applied, no generation movement —
        // otherwise a durable shard's store mirror would desync from
        // the half-mutated repo.
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        let gen = repo.generation();
        let batch = vec![
            rec("b", "m5.xlarge", 8, 11.0, 90.0), // valid, would be added
            rec("b", "m5.xlarge", 0, 12.0, 80.0), // invalid scaleout
        ];
        assert!(repo.merge_records(&batch).is_err());
        assert_eq!(repo.len(), 1, "nothing from the failed batch landed");
        assert_eq!(repo.generation(), gen);
        assert_eq!(repo.watermarks().len(), 1);
    }

    #[test]
    fn observed_machines_cache_matches_recompute() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "c5.xlarge", 4, 11.0, 90.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 8, 12.0, 80.0)).unwrap();
        assert_eq!(
            repo.observed_machines(),
            vec!["c5.xlarge".to_string(), "m5.xlarge".to_string()]
        );

        // a replacement reattributes the record: the machine set is
        // unchanged (the config key pins the machine), but the org
        // watermark moves from the loser to the winner
        let mut only = RuntimeDataRepo::new(JobKind::Sort);
        only.contribute(rec("x", "r5.xlarge", 4, 10.0, 100.0)).unwrap();
        let mut winner = RuntimeDataRepo::new(JobKind::Sort);
        winner.contribute(rec("w", "r5.xlarge", 4, 10.0, 50.0)).unwrap();
        let out = only.merge(&winner).unwrap();
        assert_eq!(out.replaced, 1);
        assert_eq!(only.observed_machines(), vec!["r5.xlarge".to_string()]);
        assert_eq!(
            only.organizations().into_iter().collect::<Vec<_>>(),
            vec!["w".to_string()],
            "the dropped org's watermark entry is removed"
        );
    }

    #[test]
    fn watermarks_track_seqnos_and_digests() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        let marks = repo.watermarks();
        assert_eq!(marks.len(), 2);
        assert_eq!(marks["a"].seqno, 2);
        assert_eq!(marks["b"].seqno, 1);
        let v2 = repo.watermarks_v2();
        assert_eq!(v2["a"].count, 2);
        assert_eq!(v2["b"].count, 1);

        // the full-mark digest is order-independent (XOR of the op set):
        // a repo built in another per-org order agrees per org
        let mut other = RuntimeDataRepo::new(JobKind::Sort);
        other.contribute(rec("b", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        other.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        other.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        for (org, mark) in repo.watermarks() {
            assert_eq!(other.watermarks()[&org].seqno, mark.seqno);
            assert_eq!(other.watermarks()[&org].digest, mark.digest);
        }
        assert_eq!(repo.watermarks_v2(), other.watermarks_v2());
        assert_eq!(repo.content_digest(), other.content_digest());
    }

    #[test]
    fn delta_for_ships_only_ops_past_the_peers_marks() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 2, 10.0, 200.0)).unwrap();

        // a fresh peer pulls everything, with origin seqnos
        let mut peer = RuntimeDataRepo::new(JobKind::Sort);
        let delta = repo.delta_for(&peer.watermarks());
        assert_eq!(delta.len(), 3);
        peer.apply_sync_ops(&delta).unwrap();
        assert_eq!(peer.watermarks(), repo.watermarks());

        // one new record on one org: exactly one op ships
        repo.contribute(rec("b", "m5.xlarge", 6, 11.0, 90.0)).unwrap();
        let delta = repo.delta_for(&peer.watermarks());
        assert_eq!(delta.len(), 1, "record-level delta, not the whole org");
        assert_eq!(delta[0].org, "b");
        assert_eq!(delta[0].seqno, 3);

        // a converged peer gets an empty delta in both directions
        peer.apply_sync_ops(&delta).unwrap();
        assert!(repo.delta_for(&peer.watermarks()).is_empty());
        assert!(peer.delta_for(&repo.watermarks()).is_empty());
    }

    #[test]
    fn rejected_sync_ops_advance_the_watermark_and_are_never_reoffered() {
        // the blind-duplicate scenario: org "a" measured one config
        // twice (submit-style history); a peer's merge accepts only the
        // winner, but the loser must still advance the peer's mark
        let mut home = RuntimeDataRepo::new(JobKind::Sort);
        home.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        home.contribute(rec("a", "m5.xlarge", 4, 10.0, 90.0)).unwrap(); // dup, wins

        let mut peer = RuntimeDataRepo::new(JobKind::Sort);
        let delta = home.delta_for(&peer.watermarks());
        assert_eq!(delta.len(), 2);
        let out = peer.apply_sync_ops(&delta).unwrap();
        assert_eq!(out.added, 1, "only the first lands as an add");
        assert_eq!(out.replaced, 1, "the better duplicate replaces it");
        assert_eq!(peer.len(), 1, "holdings dedup to the winner");
        assert_eq!(
            peer.watermarks(),
            home.watermarks(),
            "seen ops advance the mark even when merge rejects them"
        );
        assert!(
            home.delta_for(&peer.watermarks()).is_empty(),
            "nothing is ever re-offered"
        );

        // a genuinely rejected op (peer already holds a better record)
        let mut late = RuntimeDataRepo::new(JobKind::Sort);
        late.contribute(rec("z", "m5.xlarge", 4, 10.0, 50.0)).unwrap();
        let out = late.apply_sync_ops(&delta).unwrap();
        assert_eq!(out.changed(), 0, "local 50.0 beats both");
        assert_eq!(out.skipped, 2, "skipped always equals ops - changed");
        assert_eq!(out.logged.len(), 2, "both ops logged as seen");
        assert!(out.logged.iter().all(|l| !l.applied));
        assert_eq!(late.len(), 1);
        assert!(
            home.delta_for(&late.watermarks()).is_empty(),
            "seen-but-rejected ops are not re-offered either"
        );
        // the v2 view would keep re-offering (holdings differ):
        assert!(!home.delta_for_v2(&late.watermarks_v2()).is_empty());
    }

    #[test]
    fn apply_sync_ops_is_idempotent_and_handles_divergence() {
        let mut home = RuntimeDataRepo::new(JobKind::Sort);
        home.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        home.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        let delta = home.delta_for(&BTreeMap::new());

        let mut peer = RuntimeDataRepo::new(JobKind::Sort);
        peer.apply_sync_ops(&delta).unwrap();
        let marks = peer.watermarks();
        let gen = peer.generation();
        // re-delivering the same ops changes nothing
        let again = peer.apply_sync_ops(&delta).unwrap();
        assert_eq!(again.changed(), 0);
        assert_eq!(again.skipped, 2);
        assert!(again.logged.is_empty());
        assert_eq!(peer.watermarks(), marks);
        assert_eq!(peer.generation(), gen);

        // divergence: a peer whose org-a log holds a *different* op at
        // seqno 1 falls back to content dedup with local renumbering
        let mut divergent = RuntimeDataRepo::new(JobKind::Sort);
        divergent.contribute(rec("a", "c5.xlarge", 2, 12.0, 70.0)).unwrap();
        let out = divergent.apply_sync_ops(&delta).unwrap();
        assert_eq!(out.added, 2, "both foreign configs still land");
        assert_eq!(divergent.len(), 3);
        assert_eq!(divergent.log_len("a"), 3, "divergent ops renumber locally");
        // the divergent peer's log is now numerically ahead, so home
        // ships it nothing; reconciliation flows the other way — the
        // divergent side full-ships its (renumbered) log, and home
        // content-dedups it
        assert!(home.delta_for(&divergent.watermarks()).is_empty());
        let refetch = divergent.delta_for(&home.watermarks());
        assert_eq!(refetch.len(), 3, "divergent org ships whole");
        let out = home.apply_sync_ops(&refetch).unwrap();
        assert_eq!(out.added, 1, "only the genuinely-new record lands");
        assert_eq!(home.canonical_records(), divergent.canonical_records());
        // once both sides have seen the same op SET, the
        // order-independent XOR digests re-align and the exchange goes
        // silent in both directions despite the different log orders
        assert!(home.delta_for(&divergent.watermarks()).is_empty());
        assert!(divergent.delta_for(&home.watermarks()).is_empty());
    }

    #[test]
    fn sync_op_batches_validate_atomically() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        let good = SyncOp {
            org: "a".into(),
            seqno: 1,
            record: rec("a", "m5.xlarge", 4, 10.0, 100.0),
        };
        let bad = SyncOp {
            org: "a".into(),
            seqno: 2,
            record: rec("b", "m5.xlarge", 8, 10.0, 60.0), // org mismatch
        };
        assert!(repo.apply_sync_ops(&[good.clone(), bad]).is_err());
        assert!(repo.is_empty(), "nothing from the failed batch landed");
        assert_eq!(repo.log_len("a"), 0);
        let zero = SyncOp {
            seqno: 0,
            ..good.clone()
        };
        assert!(repo.apply_sync_ops(&[zero]).is_err());
        repo.apply_sync_ops(&[good]).unwrap();
        assert_eq!(repo.len(), 1);
    }

    #[test]
    fn canonicalize_orders_and_preserves_content() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("z", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        a.contribute(rec("a", "c5.xlarge", 4, 11.0, 90.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("a", "c5.xlarge", 4, 11.0, 90.0)).unwrap();
        b.contribute(rec("z", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        assert_ne!(a.records(), b.records(), "insertion orders differ");
        let gen = a.generation();
        a.canonicalize();
        b.canonicalize();
        assert_eq!(a.records(), b.records(), "canonical order is unique");
        assert_eq!(a.generation(), gen, "reordering is not a data change");
        assert_eq!(a.canonical_records(), a.records().to_vec());
    }

    #[test]
    fn csv_round_trip() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("orgA", "m5.xlarge", 4, 12.5, 123.456)).unwrap();
        repo.contribute(rec("orgB", "c5.xlarge", 8, 20.0, 77.7)).unwrap();
        let t = repo.to_table();
        let back = RuntimeDataRepo::from_table(JobKind::Sort, &t).unwrap();
        assert_eq!(back.records(), repo.records());
        assert_eq!(back.watermarks(), repo.watermarks());
        assert_eq!(back.observed_machines(), repo.observed_machines());
    }

    #[test]
    fn csv_schema_mismatch_rejected() {
        let repo = RuntimeDataRepo::new(JobKind::Grep);
        let t = repo.to_table();
        assert!(RuntimeDataRepo::from_table(JobKind::Sort, &t).is_err());
    }

    #[test]
    fn organizations_collected() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("b", "m5.xlarge", 4, 10.0, 1.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 1.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 2, 10.0, 1.0)).unwrap();
        let orgs: Vec<String> = repo.organizations().into_iter().collect();
        assert_eq!(orgs, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn truncation_folds_prefix_and_keeps_watermark() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 6, 10.0, 90.0)).unwrap();
        let before = repo.watermarks();
        assert_eq!(repo.retained_log_entries(), 4);

        // fold a's first two ops: the mark's (seqno, digest) must not
        // move — only the floor does — and memory drops to the suffix
        assert_eq!(repo.truncate_org_log("a", 2), 2);
        assert_eq!(repo.log_floor("a"), 2);
        assert_eq!(repo.log_len("a"), 3);
        assert_eq!(repo.retained_log_entries(), 2);
        let after = repo.watermarks();
        assert_eq!(after["a"].seqno, before["a"].seqno);
        assert_eq!(after["a"].digest, before["a"].digest);
        assert_eq!(after["a"].floor, 2);
        assert_eq!(after["b"], before["b"], "other orgs untouched");

        // idempotent / monotone: re-folding at or below is a no-op,
        // and a floor past the tip clamps to the tip
        assert_eq!(repo.truncate_org_log("a", 2), 0);
        assert_eq!(repo.truncate_org_log("a", 1), 0);
        assert_eq!(repo.truncate_org_log("a", 99), 1);
        assert_eq!(repo.log_floor("a"), 3);
        assert_eq!(repo.watermarks()["a"].seqno, 3);
        assert_eq!(repo.watermarks()["a"].digest, before["a"].digest);

        // appends past a fully-folded log keep the genesis-cumulative
        // digest chain: a never-truncated twin agrees on the mark
        repo.contribute(rec("a", "c5.xlarge", 4, 11.0, 80.0)).unwrap();
        let mut twin = RuntimeDataRepo::new(JobKind::Sort);
        twin.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        twin.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        twin.contribute(rec("a", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        twin.contribute(rec("b", "m5.xlarge", 6, 10.0, 90.0)).unwrap();
        twin.contribute(rec("a", "c5.xlarge", 4, 11.0, 80.0)).unwrap();
        assert_eq!(repo.watermarks()["a"].seqno, twin.watermarks()["a"].seqno);
        assert_eq!(repo.watermarks()["a"].digest, twin.watermarks()["a"].digest);
    }

    #[test]
    fn delta_plan_ships_suffix_above_floor_and_snapshot_below() {
        let mut home = RuntimeDataRepo::new(JobKind::Sort);
        home.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        home.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();

        // a peer holding the full prefix syncs, then home truncates
        let mut peer = RuntimeDataRepo::new(JobKind::Sort);
        peer.apply_sync_ops(&home.delta_for(&peer.watermarks())).unwrap();
        home.contribute(rec("a", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        home.truncate_org_log("a", 2);

        // peer's mark (seqno 2) sits exactly at the floor: the
        // retained suffix still covers it — ops, no snapshot
        let plan = home.delta_plan(&peer.watermarks());
        assert_eq!(plan.ops.len(), 1);
        assert_eq!(plan.ops[0].seqno, 3);
        assert!(plan.snapshots.is_empty());
        peer.apply_sync_ops(&plan.ops).unwrap();
        assert!(home.delta_plan(&peer.watermarks()).is_empty());

        // a fresh peer (unknown org) sits below the floor: snapshot
        let fresh = RuntimeDataRepo::new(JobKind::Sort);
        let plan = home.delta_plan(&fresh.watermarks());
        assert!(plan.ops.is_empty());
        assert_eq!(plan.snapshots.len(), 1);
        let snap = &plan.snapshots[0];
        assert_eq!(snap.org, "a");
        assert_eq!(snap.seqno, 3);
        assert_eq!(snap.records.len(), 3);

        // ...and so does a peer whose mark is below the floor
        let mut behind = RuntimeDataRepo::new(JobKind::Sort);
        behind
            .apply_sync_ops(&[SyncOp {
                org: "a".into(),
                seqno: 1,
                record: rec("a", "m5.xlarge", 4, 10.0, 100.0),
            }])
            .unwrap();
        let plan = home.delta_plan(&behind.watermarks());
        assert!(plan.ops.is_empty());
        assert_eq!(plan.snapshots.len(), 1);

        // delta_for is the op-only projection: it must not invent ops
        // for a snapshot-fallback org
        assert!(home.delta_for(&fresh.watermarks()).is_empty());
    }

    #[test]
    fn adopting_an_org_snapshot_converges_and_goes_quiescent() {
        let mut home = RuntimeDataRepo::new(JobKind::Sort);
        home.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        home.contribute(rec("a", "m5.xlarge", 8, 10.0, 90.0)).unwrap();
        home.contribute(rec("a", "c5.xlarge", 8, 11.0, 70.0)).unwrap();
        home.truncate_org_log("a", 3);

        let mut fresh = RuntimeDataRepo::new(JobKind::Sort);
        let plan = home.delta_plan(&fresh.watermarks());
        let (out, adopted) = fresh.adopt_org_snapshot(&plan.snapshots[0]).unwrap();
        assert!(adopted);
        assert_eq!(out.added, 3);
        assert!(out.logged.is_empty(), "adopted records ride the fold");
        assert_eq!(fresh.log_len("a"), 3);
        assert_eq!(fresh.log_floor("a"), 3);
        assert_eq!(fresh.canonical_records(), home.canonical_records());
        // positions agree exactly, so both directions go quiescent
        assert_eq!(fresh.watermarks(), home.watermarks());
        assert!(home.delta_plan(&fresh.watermarks()).is_empty());
        assert!(fresh.delta_plan(&home.watermarks()).is_empty());
        // re-adoption is a no-op merge and does not re-adopt
        let (again, adopted) = fresh.adopt_org_snapshot(&plan.snapshots[0]).unwrap();
        assert!(!adopted);
        assert_eq!(again.changed(), 0);

        // a divergent peer numerically ahead merges content-level but
        // keeps its own log (no position adoption) — applied records
        // get fresh local seqnos so they still propagate onward
        let mut divergent = RuntimeDataRepo::new(JobKind::Sort);
        for i in 0..5 {
            divergent
                .contribute(rec("a", "r5.xlarge", 2 + i, 20.0 + f64::from(i), 50.0))
                .unwrap();
        }
        let marks = divergent.watermarks();
        let (out, adopted) = divergent.adopt_org_snapshot(&plan.snapshots[0]).unwrap();
        assert!(!adopted);
        assert_eq!(out.added, 3);
        assert_eq!(out.logged.len(), 3, "divergent applies are logged");
        assert_eq!(divergent.watermarks()["a"].seqno, marks["a"].seqno + 3);
        assert_eq!(divergent.log_floor("a"), 0);
    }

    #[test]
    fn sync_ops_below_the_floor_dedup_content_level() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        repo.truncate_org_log("a", 2);
        // a re-delivery of a folded op: no retained entry to compare,
        // merge dedup resolves it as a skip
        let out = repo
            .apply_sync_ops(&[SyncOp {
                org: "a".into(),
                seqno: 1,
                record: rec("a", "m5.xlarge", 4, 10.0, 100.0),
            }])
            .unwrap();
        assert_eq!(out.changed(), 0);
        assert_eq!(out.skipped, 1);
        assert_eq!(repo.log_len("a"), 2, "no log growth on folded dups");
        // a genuinely new record claiming a folded seqno renumbers
        let out = repo
            .apply_sync_ops(&[SyncOp {
                org: "a".into(),
                seqno: 1,
                record: rec("a", "c5.xlarge", 2, 12.0, 70.0),
            }])
            .unwrap();
        assert_eq!(out.added, 1);
        assert_eq!(repo.log_len("a"), 3);
    }

    #[test]
    fn file_round_trip() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("orgA", "m5.xlarge", 4, 12.5, 123.0)).unwrap();
        let dir = std::env::temp_dir().join("c3o_repo_test");
        let path = dir.join("sort.csv");
        repo.save(&path).unwrap();
        let back = RuntimeDataRepo::load(JobKind::Sort, &path).unwrap();
        assert_eq!(back.records(), repo.records());
        let _ = std::fs::remove_dir_all(dir);
    }
}
