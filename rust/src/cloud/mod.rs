//! Simulated public cloud — the Amazon EMR stand-in.
//!
//! The paper runs its 930 experiments on Amazon EMR 6.0.0 clusters built
//! from general-purpose (m5), compute-optimized (c5), and memory-optimized
//! (r5) instances. This module provides the equivalent substrate:
//!
//! * a **machine-type catalog** ([`MachineType`]) with vCPUs, memory, disk
//!   and network bandwidth, and on-demand hourly prices calibrated to the
//!   us-east-1 price book circa 2020;
//! * a **provisioning model** ([`ProvisioningModel`]) reproducing the
//!   seven-plus-minute EMR cluster start-up delay the paper cites as the
//!   reason profiling-based approaches are expensive;
//! * a **cluster lifecycle** ([`Cluster`], [`Cloud::provision`]) with
//!   billing (per-second with a one-minute minimum, like EC2 Linux).
//!
//! Everything downstream (the dataflow simulator, the configurator, the
//! baselines) sees the cloud only through this module, which is exactly
//! the visibility a real C3O deployment would have through its
//! *cloud access manager*.

pub mod catalog;
pub mod cluster;
pub mod pricing;

pub use catalog::{MachineFamily, MachineType};
pub use cluster::{Cluster, ClusterState, ProvisioningModel};
pub use pricing::BillingPolicy;

use crate::util::rng::Pcg32;

/// A simulated public cloud: catalog + provisioning + billing.
#[derive(Debug, Clone)]
pub struct Cloud {
    machine_types: Vec<MachineType>,
    provisioning: ProvisioningModel,
    billing: BillingPolicy,
}

impl Cloud {
    /// A cloud with the AWS-like catalog the paper's experiments span
    /// (m5/c5/r5 families, `.large` … `.2xlarge` sizes).
    pub fn aws_like() -> Self {
        Cloud {
            machine_types: catalog::aws_like_catalog(),
            provisioning: ProvisioningModel::emr_like(),
            billing: BillingPolicy::per_second_with_minimum(60),
        }
    }

    /// A cloud with a custom catalog (used in tests and ablations).
    pub fn with_catalog(machine_types: Vec<MachineType>) -> Self {
        Cloud {
            machine_types,
            provisioning: ProvisioningModel::emr_like(),
            billing: BillingPolicy::per_second_with_minimum(60),
        }
    }

    /// Replace the provisioning model (e.g. zero-delay for unit tests).
    pub fn with_provisioning(mut self, p: ProvisioningModel) -> Self {
        self.provisioning = p;
        self
    }

    /// All machine types offered by this cloud.
    pub fn machine_types(&self) -> &[MachineType] {
        &self.machine_types
    }

    /// Look up a machine type by name.
    pub fn machine(&self, name: &str) -> Option<&MachineType> {
        self.machine_types.iter().find(|m| m.name == name)
    }

    /// The billing policy in force.
    pub fn billing(&self) -> &BillingPolicy {
        &self.billing
    }

    /// Provision a cluster of `count` × `machine`. Returns the cluster with
    /// its (stochastic but seeded) provisioning delay already determined.
    ///
    /// # Panics
    /// Panics if the machine type is not in this cloud's catalog or if
    /// `count == 0`.
    pub fn provision(&self, machine: &str, count: u32, rng: &mut Pcg32) -> Cluster {
        assert!(count > 0, "cannot provision an empty cluster");
        let mt = self
            .machine(machine)
            .unwrap_or_else(|| panic!("unknown machine type {machine:?}"))
            .clone();
        let delay = self.provisioning.sample_delay_s(count, rng);
        Cluster::new(mt, count, delay)
    }

    /// Cost in USD of holding `count` × `machine` for `seconds`.
    pub fn cost_usd(&self, machine: &str, count: u32, seconds: f64) -> f64 {
        let mt = self
            .machine(machine)
            .unwrap_or_else(|| panic!("unknown machine type {machine:?}"));
        self.billing.cost_usd(mt.price_usd_hour, count, seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aws_like_catalog_nonempty_and_unique() {
        let cloud = Cloud::aws_like();
        let names: Vec<_> = cloud.machine_types().iter().map(|m| &m.name).collect();
        assert!(names.len() >= 6, "need several machine types for Fig. 3");
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate machine names");
    }

    #[test]
    fn machine_lookup() {
        let cloud = Cloud::aws_like();
        assert!(cloud.machine("m5.xlarge").is_some());
        assert!(cloud.machine("quantum.42xlarge").is_none());
    }

    #[test]
    fn provision_returns_delay_in_emr_band() {
        let cloud = Cloud::aws_like();
        let mut rng = Pcg32::new(1);
        for _ in 0..50 {
            let c = cloud.provision("m5.xlarge", 4, &mut rng);
            assert!(
                (3.5 * 60.0..20.0 * 60.0).contains(&c.provisioning_delay_s()),
                "delay {}",
                c.provisioning_delay_s()
            );
        }
    }

    #[test]
    #[should_panic(expected = "unknown machine type")]
    fn provision_unknown_type_panics() {
        let cloud = Cloud::aws_like();
        let mut rng = Pcg32::new(1);
        cloud.provision("nope.large", 2, &mut rng);
    }

    #[test]
    #[should_panic(expected = "empty cluster")]
    fn provision_zero_panics() {
        let cloud = Cloud::aws_like();
        let mut rng = Pcg32::new(1);
        cloud.provision("m5.xlarge", 0, &mut rng);
    }

    #[test]
    fn cost_scales_linearly_in_nodes_and_time() {
        let cloud = Cloud::aws_like();
        let c1 = cloud.cost_usd("m5.xlarge", 1, 3600.0);
        let c2 = cloud.cost_usd("m5.xlarge", 2, 3600.0);
        let c4 = cloud.cost_usd("m5.xlarge", 1, 2.0 * 3600.0);
        assert!((c2 - 2.0 * c1).abs() < 1e-9);
        assert!((c4 - 2.0 * c1).abs() < 1e-9);
    }
}
