"""L1 Pallas kernel: tiled weighted squared-distance matrix.

The hot spot of the "pessimistic" (similarity-based) runtime predictor is
computing the weighted distance between every query configuration and
every shared historical execution. This kernel expresses it as MXU-shaped
tiles (see DESIGN.md §Hardware-Adaptation):

    D = ||Q·sqrt(w)||²  −  2 (Q·w) Tᵀ  +  ||T·sqrt(w)||²

so the inner loop of each (TILE_Q × TILE_T) output tile is a
(TILE_Q × F) @ (F × TILE_T) matmul — systolic-array food — instead of a
broadcast-subtract-square reduction, which would be VPU-bound and
materialize a [Q, T, F] intermediate in VMEM.

BlockSpec schedule: the grid is (Q/TILE_Q, T/TILE_T); each instance holds
one query tile (row-resident across the inner T loop), streams train
tiles, and keeps the full feature dimension resident (F ≤ 64 after
padding, so a fp32 tile pair is ≤ 2·128·64·4 B = 64 KiB — far under VMEM).

`interpret=True` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics,
which is what `aot.py` exports and the Rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. 64×64 output tiles: MXU-aligned on real hardware, and small
# enough that interpret-mode tests stay fast.
TILE_Q = 64
TILE_T = 64


def _sqdist_kernel(q_ref, t_ref, w_ref, o_ref):
    """One (TILE_Q, TILE_T) tile of the weighted distance matrix.

    q_ref: [TILE_Q, F] queries           (VMEM-resident)
    t_ref: [TILE_T, F] training rows     (streamed per grid step)
    w_ref: [F]         feature weights
    o_ref: [TILE_Q, TILE_T] output tile
    """
    q = q_ref[...]
    t = t_ref[...]
    w = w_ref[...]
    # Scale by sqrt(w) once; the cross term then needs no extra weighting.
    sw = jnp.sqrt(w)[None, :]
    qs = q * sw  # [TILE_Q, F]
    ts = t * sw  # [TILE_T, F]
    qn = jnp.sum(qs * qs, axis=1, keepdims=True)  # [TILE_Q, 1]
    tn = jnp.sum(ts * ts, axis=1, keepdims=True).T  # [1, TILE_T]
    # MXU tile: [TILE_Q, F] @ [F, TILE_T]
    cross = jax.lax.dot_general(
        qs,
        ts.T,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Clamp tiny negatives from cancellation so downstream 1/d is safe.
    o_ref[...] = jnp.maximum(qn - 2.0 * cross + tn, 0.0)


@functools.partial(
    jax.jit, static_argnames=("interpret", "tile_q", "tile_t")
)
def weighted_sqdist(queries, train, weights, *, interpret=True,
                    tile_q=TILE_Q, tile_t=TILE_T):
    """Tiled weighted squared-distance matrix via `pallas_call`.

    Args:
      queries: [Q, F] float32, Q divisible by tile_q
      train:   [T, F] float32, T divisible by tile_t
      weights: [F]    float32 non-negative
      tile_q/tile_t: output tile shape. The AOT export passes the full
        problem shape (grid collapses to a single kernel instance): in
        interpret mode each grid step costs a dynamic-slice trip, and at
        the production shape (64×512, F=16) even the single-instance
        tile pair is only ~36 KiB — far below VMEM, so one instance is
        also the right TPU schedule. The defaults keep multi-tile
        scheduling exercised by the pytest shape sweeps.

    Returns:
      [Q, T] float32 distance matrix.
    """
    q_n, f = queries.shape
    t_n, f2 = train.shape
    assert f == f2 == weights.shape[0], "feature dims must agree"
    assert q_n % tile_q == 0, f"Q={q_n} must be a multiple of {tile_q}"
    assert t_n % tile_t == 0, f"T={t_n} must be a multiple of {tile_t}"

    grid = (q_n // tile_q, t_n // tile_t)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            # query tile: advances with grid axis 0, full F
            pl.BlockSpec((tile_q, f), lambda i, j: (i, 0)),
            # train tile: advances with grid axis 1, full F
            pl.BlockSpec((tile_t, f), lambda i, j: (j, 0)),
            # weights: shared by every instance
            pl.BlockSpec((f,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q_n, t_n), jnp.float32),
        interpret=interpret,
    )(queries, train, weights)
