//! Dynamic model selection (paper §V-C).
//!
//! "Based on cross-validation, the most accurate model averaged over the
//! test datasets is chosen to predict new data points." — k-fold CV over
//! the shared repository for each model family, pick the lower mean MAPE,
//! retrain the winner on the full data. Retraining happens on the arrival
//! of new runtime data (driven by the coordinator).

use crate::cloud::Cloud;
use crate::models::{ConfigQuery, ModelKind, ModelTrainer, TrainedModel};
use crate::repo::featurize::FeatureMatrixCache;
use crate::repo::RuntimeDataRepo;
use crate::util::rng::Pcg32;
use crate::util::stats;
use anyhow::{bail, Result};

/// Outcome of one dynamic selection round.
#[derive(Debug, Clone)]
pub struct SelectionReport {
    /// Mean CV MAPE (%) per model kind.
    pub cv_mape: Vec<(ModelKind, f64)>,
    pub chosen: ModelKind,
    pub folds: usize,
    pub records: usize,
    /// Wall-clock nanoseconds the cross-validation sweep took (all
    /// model kinds, all folds). Timing only — never feeds a decision.
    pub cv_nanos: u64,
    /// Wall-clock nanoseconds the winner's full-repository fit took.
    pub fit_nanos: u64,
}

impl SelectionReport {
    pub fn mape_of(&self, kind: ModelKind) -> f64 {
        self.cv_mape
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, m)| *m)
            .unwrap_or(f64::NAN)
    }
}

/// Deterministic shuffled k-fold split of record indices.
pub fn kfold_indices(n: usize, folds: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(folds >= 2, "need at least 2 folds");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg32::new(seed);
    rng.shuffle(&mut idx);
    let mut out = vec![Vec::new(); folds];
    for (i, r) in idx.into_iter().enumerate() {
        out[i % folds].push(r);
    }
    out
}

/// Cross-validated MAPE of one model kind on a repository. Works with
/// any [`ModelTrainer`] backend (PJRT predictor or native engine).
pub fn cv_mape(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    kind: ModelKind,
    folds: usize,
    seed: u64,
) -> Result<f64> {
    let n = repo.len();
    if n < folds {
        bail!("repo has {n} records, need at least {folds} for {folds}-fold CV");
    }
    let splits = kfold_indices(n, folds, seed);
    let records = repo.records();
    let mut fold_mapes = Vec::with_capacity(folds);
    for test_idx in &splits {
        let test_set: std::collections::BTreeSet<usize> = test_idx.iter().copied().collect();
        let train = RuntimeDataRepo::from_records(
            repo.job(),
            records
                .iter()
                .enumerate()
                .filter(|(i, _)| !test_set.contains(i))
                .map(|(_, r)| r.clone()),
        );
        let model = predictor.train(cloud, &train, kind)?;
        let queries: Vec<ConfigQuery> = test_idx
            .iter()
            .map(|&i| ConfigQuery {
                machine: records[i].machine.clone(),
                scaleout: records[i].scaleout,
                job_features: records[i].job_features.clone(),
            })
            .collect();
        let truth: Vec<f64> = test_idx.iter().map(|&i| records[i].runtime_s).collect();
        let preds = predictor.predict(&model, cloud, &queries)?;
        fold_mapes.push(stats::mape(&preds, &truth));
    }
    Ok(stats::mean(&fold_mapes))
}

/// Run dynamic selection: CV both families, retrain the winner on the
/// full repository. Works with any [`ModelTrainer`] backend.
pub fn select_and_train(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    folds: usize,
    seed: u64,
) -> Result<(TrainedModel, SelectionReport)> {
    select_and_train_cached(predictor, cloud, repo, folds, seed, None)
}

/// [`select_and_train`] with an optional incremental
/// [`FeatureMatrixCache`] consumed by the winner's full-repository
/// train. The CV folds train on fresh per-fold sub-repos the cache
/// cannot mirror, so they always run from scratch; only the final —
/// and by far largest — fit takes the cached path. Bitwise-identical
/// models either way.
pub fn select_and_train_cached(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    folds: usize,
    seed: u64,
    feat: Option<&mut FeatureMatrixCache>,
) -> Result<(TrainedModel, SelectionReport)> {
    let cv_started = std::time::Instant::now();
    let mut cv = Vec::new();
    for kind in ModelKind::all() {
        let mape = cv_mape(predictor, cloud, repo, kind, folds, seed)?;
        cv.push((kind, mape));
    }
    let cv_nanos = cv_started.elapsed().as_nanos() as u64;
    let chosen = cv
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(k, _)| *k)
        .unwrap();
    let fit_started = std::time::Instant::now();
    let model = predictor.train_cached(cloud, repo, chosen, feat)?;
    Ok((
        model,
        SelectionReport {
            cv_mape: cv,
            chosen,
            folds,
            records: repo.len(),
            cv_nanos,
            fit_nanos: fit_started.elapsed().as_nanos() as u64,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Predictor;
    use crate::runtime::Runtime;
    use crate::workloads::{ExperimentGrid, JobKind};

    #[test]
    fn kfold_partitions_everything_once() {
        let folds = kfold_indices(103, 5, 7);
        assert_eq!(folds.len(), 5);
        let mut all: Vec<usize> = folds.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn kfold_is_seeded() {
        assert_eq!(kfold_indices(50, 5, 1), kfold_indices(50, 5, 1));
        assert_ne!(kfold_indices(50, 5, 1), kfold_indices(50, 5, 2));
    }

    #[test]
    fn selection_runs_and_reports() {
        let dir = Runtime::default_dir();
        if !Runtime::artifacts_available(&dir) {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let cloud = Cloud::aws_like();
        // small sort corpus: dense grid → pessimistic should win or tie
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 3,
        };
        let repo = grid.execute(&cloud, 3).repo_for(JobKind::Sort);
        let mut p = Predictor::new(&dir).unwrap();
        let (model, report) = select_and_train(&mut p, &cloud, &repo, 4, 9).unwrap();
        assert_eq!(model.kind, report.chosen);
        for (_, mape) in &report.cv_mape {
            assert!(mape.is_finite() && *mape > 0.0, "{report:?}");
        }
        // the winner's CV MAPE is the minimum
        let winner = report.mape_of(report.chosen);
        for (_, m) in &report.cv_mape {
            assert!(winner <= *m + 1e-12);
        }
        // on this dense, low-noise grid both models should be usable
        assert!(winner < 30.0, "winner MAPE {winner}");
    }

    #[test]
    fn cv_rejects_tiny_repo() {
        let dir = Runtime::default_dir();
        if !Runtime::artifacts_available(&dir) {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let cloud = Cloud::aws_like();
        let mut p = Predictor::new(&dir).unwrap();
        let repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(cv_mape(&mut p, &cloud, &repo, ModelKind::Pessimistic, 5, 1).is_err());
    }

    #[test]
    fn selection_runs_on_native_backend() {
        // No artifacts required: the native engine serves dynamic
        // selection end to end.
        let cloud = Cloud::aws_like();
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 1,
        };
        let repo = grid.execute(&cloud, 3).repo_for(JobKind::Sort);
        let mut engine = crate::models::native::NativeEngine::default();
        let (model, report) = select_and_train(&mut engine, &cloud, &repo, 4, 9).unwrap();
        assert_eq!(model.kind, report.chosen);
        let winner = report.mape_of(report.chosen);
        for (_, m) in &report.cv_mape {
            assert!(m.is_finite() && *m > 0.0, "{report:?}");
            assert!(winner <= *m + 1e-12);
        }
        assert!(winner < 30.0, "native winner MAPE {winner}");
    }

    #[test]
    fn cv_rejects_tiny_repo_native() {
        let cloud = Cloud::aws_like();
        let mut engine = crate::models::native::NativeEngine::default();
        let repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(cv_mape(&mut engine, &cloud, &repo, ModelKind::Pessimistic, 5, 1).is_err());
    }
}
