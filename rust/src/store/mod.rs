//! Durable persistence + federation: the subsystem that turns the
//! in-memory collaborative repositories into long-lived, *shared*
//! state — the paper's premise that runtime data outlives any one
//! process and flows between organizations.
//!
//! Both halves replay **one abstraction**: the per-(org, job)
//! sequence-numbered operation log the repository maintains
//! ([`crate::repo`]). Every accepted mutation gets a monotone per-org
//! seqno; a [`crate::repo::OrgWatermark`] is a log position
//! `(seqno, digest)`; deltas are the ops past a position. The store and
//! the sync protocol are two consumers of that log, not two parallel
//! change-tracking mechanisms:
//!
//! * [`segment`] — the **durable segment store**: per-[`JobKind`]
//!   append-only WALs whose lines carry both the generation stamp and
//!   the op's org-log seqno (checksummed, torn-tail tolerant), atomic
//!   snapshots paired with an `oplog-<gen>.csv` sidecar, and segment
//!   compaction. A coordinator or service recovers its full corpus —
//!   bitwise, including record order *and* org-log positions — from
//!   [`JobStore::open`] on startup, then warms its model caches from
//!   the recovered generation. Legacy (PR-3 format) WALs and snapshots
//!   still recover: lines without the seqno field get their numbers
//!   assigned during (deterministic) replay.
//! * [`sync`] — the **record-level peer delta-sync protocol** (API
//!   v3/v4): watermark positions drive pull/push exchanges that ship
//!   sequence-numbered [`crate::repo::SyncOp`]s — **O(changed
//!   records)** per exchange on prefix-aligned logs, a digest-checked
//!   whole-org fallback on divergence, and a whole-org
//!   [`crate::repo::OrgSnapshot`] fallback when a peer sits below a
//!   truncation floor. One entry point, [`sync::sync`], with
//!   [`SyncOptions`] choosing scope, detail, and protocol (per-job v3,
//!   batched cross-job v4, legacy v2). Merge-level dedup with
//!   deterministic conflict resolution makes every exchange idempotent
//!   and convergent (any gossip order → bitwise-identical
//!   repositories), and merge-rejected ops are logged as *seen* — the
//!   watermark advances, so blind duplicate contributions transfer once
//!   and are never re-offered.
//! * [`mesh`] — the **gossip mesh**: peer membership with deterministic
//!   FNV-derived IDs, round-based heartbeats and staleness eviction;
//!   anti-entropy scheduling via rotating fanout-k selection over the
//!   live roster ([`MeshDriver`] supersedes the static-peer-list
//!   [`SyncDriver`] loop); and per-peer acked-watermark tracking whose
//!   intersection over live members yields the **acked floor** — the
//!   log prefix every member provably holds, safe to fold into a base
//!   snapshot ([`crate::repo::RuntimeDataRepo::truncate_org_log`]),
//!   bounding op-log memory by the unacked suffix.
//!
//! The write path is layered: a [`JobShard`](crate::coordinator::shard)
//! mutates its repo, WAL-frames exactly the logged ops through its
//! attached [`JobStore`] (applied mutations as `C`/`M` lines, seen
//! rejections as generation-neutral `S` lines), and lets
//! [`JobStore::maybe_compact`] fold the WAL into a snapshot + sidecar
//! (plus a `floor-<gen>.csv` sidecar once truncation has folded
//! history) when it grows. Reads never touch the store.

pub mod mesh;
pub mod segment;
pub mod sync;

pub use mesh::{
    fanout_targets, mesh_peer, mesh_round, peer_id, MeshDriver, MeshRoundReport, MeshState,
    DEFAULT_STALE_AFTER,
};
pub use segment::{
    FsyncPolicy, JobStore, StoreConfig, StoreOp, DEFAULT_COMPACT_THRESHOLD, DEFAULT_SEGMENT_CAP,
};
#[allow(deprecated)]
pub use sync::{
    fold_orgs, sync, sync_all, sync_all_detailed, sync_job, sync_job_detailed, sync_job_v2,
    OrgExchange, OrgExchangeMap, SyncDetail, SyncDriver, SyncOptions, SyncProtocol, SyncScope,
    SyncStats, SyncSummary,
};

use crate::api::ApiError;
use crate::repo::RuntimeDataRepo;
use crate::workloads::JobKind;
use std::path::Path;

/// Open (or create) the per-job stores under `root`, recovering every
/// job's repository — one entry per [`JobKind::all`] kind, in that
/// order.
pub fn open_all(root: &Path) -> Result<Vec<(JobStore, RuntimeDataRepo)>, ApiError> {
    JobKind::all()
        .into_iter()
        .map(|kind| JobStore::open(root, kind))
        .collect()
}
