//! Minimal JSON writer (no parser needed — JSON is export-only: metrics,
//! figure payloads, EXPERIMENTS.md data blocks). Values are built
//! programmatically and rendered with correct string escaping and stable
//! key order (insertion order).

use std::fmt::Write as _;

/// A JSON value. Object keys keep insertion order for diffable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Build an array of strings.
    pub fn strs<S: AsRef<str>>(xs: &[S]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.as_ref().to_string())).collect())
    }

    /// Render compactly.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Render with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kvs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(3.5).render(), "3.5");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(
            Json::Str("a\"b\\c\nd".into()).render(),
            "\"a\\\"b\\\\c\\nd\""
        );
        assert_eq!(Json::Str("\u{1}".into()).render(), "\"\\u0001\"");
    }

    #[test]
    fn nested_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("sort".into())),
            ("runs", Json::nums(&[1.0, 2.5])),
        ]);
        assert_eq!(j.render(), r#"{"name":"sort","runs":[1,2.5]}"#);
    }

    #[test]
    fn pretty_is_indented() {
        let j = Json::obj(vec![("a", Json::Num(1.0))]);
        assert_eq!(j.pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::Obj(vec![]).render(), "{}");
    }
}
