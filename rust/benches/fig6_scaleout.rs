//! Bench: regenerate Fig. 6 (scale-out behavior, incl. the SGD/K-Means
//! memory-bottleneck super-linear speedup and PageRank's poor scaling).

use c3o::cloud::Cloud;
use c3o::figures;
use c3o::sim::{SimConfig, Simulator};
use c3o::util::bench::{black_box, Bench};
use c3o::util::rng::Pcg32;
use c3o::workloads::JobSpec;

fn main() {
    let cloud = Cloud::aws_like();

    let fig = figures::fig6(&cloud, 42);
    println!("{}", fig.render());
    assert!(fig.all_claims_hold(), "Fig. 6 reproduction failed");

    // perf: the iterative jobs dominate simulation cost; measure one each
    let mut b = Bench::new("fig6_scaleout");
    let sim = Simulator::new(SimConfig::default());
    let m = cloud.machine("m5.xlarge").unwrap().clone();
    for (label, spec) in [
        ("simulate_sort_15gb_n4", JobSpec::sort(15.0)),
        ("simulate_sgd_30gb_n4", JobSpec::sgd(30.0, 100)),
        ("simulate_pagerank_330mb_n4", JobSpec::pagerank(330.0, 0.001)),
    ] {
        let stages = spec.stages();
        let mut rng = Pcg32::new(7);
        b.run(label, || black_box(sim.run(&m, 4, &stages, &mut rng).runtime_s));
    }
    b.finish();
}
