//! Fixture: serving zone — `no-panic-serving` (method, macro, index).

pub fn answer(xs: &[u32]) -> u32 {
    let first = xs.first().copied().unwrap();
    if first == 0 {
        unreachable!("zero is filtered upstream");
    }
    xs[1] + first
}

pub fn head(xs: &[u32]) -> u32 {
    // c3o-lint: allow(no-panic-serving) — fixture: in-bounds by the caller contract
    xs[0]
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v = Some(1u32);
        assert_eq!(v.unwrap(), 1);
    }
}
