//! The Table-I experiment grid: 930 unique runtime experiments emulating
//! executions from diverse collaborators.
//!
//! | Job      | Count | Inputs                         | Parameters            |
//! |----------|-------|--------------------------------|-----------------------|
//! | Sort     | 126   | 10–20 GB                       | —                     |
//! | Grep     | 162   | 10–20 GB, keyword ratio        | keyword "Computer"    |
//! | SGD      | 180   | 10–30 GB                       | max iterations 1–100  |
//! | K-Means  | 180   | 10–20 GB                       | 3–9 clusters, conv 1e-3 |
//! | PageRank | 282   | 130–440 MB graphs              | conv 0.01–0.0001      |
//!
//! Every experiment runs on 3 machine types × 6 scale-outs (12, 10, …, 2 —
//! the Fig. 3 axis), is repeated **five times**, and the **median** runtime
//! is recorded — the paper's outlier-control protocol. Each (machine type,
//! scale-out) combination is attributed to one emulated organization, so
//! the corpus has the provenance structure of genuinely collaborative
//! data: no single org covers the whole configuration space.

use crate::cloud::{catalog, Cloud};
use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::sim::{SimConfig, Simulator};
use crate::util::rng::Pcg32;
use crate::util::stats::median;
use crate::workloads::{JobKind, JobSpec};

/// One grid point: a job spec on a concrete cluster configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Experiment {
    pub spec: JobSpec,
    pub machine: String,
    pub scaleout: u32,
}

/// The full experiment plan.
#[derive(Debug, Clone)]
pub struct ExperimentGrid {
    pub experiments: Vec<Experiment>,
    /// Repetitions per experiment (paper: 5, median reported).
    pub repetitions: u32,
}

/// The scale-out axis of Fig. 3 ("Instance count left to right: 12, 10, …").
pub const SCALEOUTS: [u32; 6] = [2, 4, 6, 8, 10, 12];

impl ExperimentGrid {
    /// The paper's exact experiment counts per job (930 total).
    pub fn paper_table1() -> Self {
        let machines = catalog::grid_machine_types();
        let mut experiments = Vec::with_capacity(930);
        let mut push_grid = |specs: &[JobSpec]| {
            for machine in &machines {
                for &scaleout in &SCALEOUTS {
                    for spec in specs {
                        experiments.push(Experiment {
                            spec: spec.clone(),
                            machine: machine.clone(),
                            scaleout,
                        });
                    }
                }
            }
        };

        // Sort: 7 sizes in 10–20 GB → 3·6·7 = 126.
        let sort: Vec<JobSpec> = (0..7)
            .map(|i| JobSpec::sort(10.0 + 10.0 * i as f64 / 6.0))
            .collect();
        push_grid(&sort);

        // Grep: 3 sizes × 3 keyword ratios → 3·6·9 = 162.
        let mut grep = Vec::new();
        for &gb in &[10.0, 15.0, 20.0] {
            for &ratio in &[0.01, 0.1, 0.3] {
                grep.push(JobSpec::grep(gb, ratio));
            }
        }
        push_grid(&grep);

        // SGD: 2 sizes × 5 max-iteration values → 3·6·10 = 180.
        let mut sgd = Vec::new();
        for &gb in &[10.0, 30.0] {
            for &it in &[1u32, 25, 50, 75, 100] {
                sgd.push(JobSpec::sgd(gb, it));
            }
        }
        push_grid(&sgd);

        // K-Means: k ∈ 3–9 at 15 GB, plus 3 sizes at k=5 → 3·6·10 = 180.
        let mut kmeans: Vec<JobSpec> =
            (3..=9).map(|k| JobSpec::kmeans(15.0, k, 0.001)).collect();
        for &gb in &[10.0, 17.5, 20.0] {
            kmeans.push(JobSpec::kmeans(gb, 5, 0.001));
        }
        push_grid(&kmeans);

        // PageRank: 15 (graph, convergence) combos on the full grid (270)
        // plus 12 extra m5.xlarge runs at conv 5e-4 → 282.
        let mut pagerank = Vec::new();
        for &mb in &[130.0, 230.0, 330.0, 440.0] {
            for &conv in &[0.01, 0.001, 0.0001] {
                pagerank.push(JobSpec::pagerank(mb, conv));
            }
        }
        for &mb in &[180.0, 280.0, 380.0] {
            pagerank.push(JobSpec::pagerank(mb, 0.001));
        }
        push_grid(&pagerank);
        for &scaleout in &SCALEOUTS {
            for &mb in &[130.0, 440.0] {
                experiments.push(Experiment {
                    spec: JobSpec::pagerank(mb, 0.0005),
                    machine: "m5.xlarge".to_string(),
                    scaleout,
                });
            }
        }

        ExperimentGrid {
            experiments,
            repetitions: 5,
        }
    }

    /// Number of experiments per job kind.
    pub fn counts(&self) -> Vec<(JobKind, usize)> {
        JobKind::all()
            .into_iter()
            .map(|k| {
                (
                    k,
                    self.experiments.iter().filter(|e| e.spec.kind() == k).count(),
                )
            })
            .collect()
    }

    /// Execute the whole grid on a cloud, producing the shared corpus.
    /// Deterministic given the seed.
    pub fn execute(&self, cloud: &Cloud, seed: u64) -> Corpus {
        self.execute_with(cloud, &SimConfig::default(), seed)
    }

    /// Execute with an explicit simulator configuration.
    pub fn execute_with(&self, cloud: &Cloud, config: &SimConfig, seed: u64) -> Corpus {
        let sim = Simulator::new(config.clone());
        let mut rng = Pcg32::new(seed);
        let mut records = Vec::with_capacity(self.experiments.len());
        for (i, e) in self.experiments.iter().enumerate() {
            let machine = cloud
                .machine(&e.machine)
                .unwrap_or_else(|| panic!("grid machine {} not in catalog", e.machine));
            let stages = e.spec.stages();
            let runs: Vec<f64> = (0..self.repetitions)
                .map(|rep| {
                    let mut r = rng.fork((i as u64) << 8 | rep as u64);
                    // allocation-free fast path (§Perf): same math as
                    // `run`, no per-stage reports
                    sim.run_runtime_only(machine, e.scaleout, &stages, &mut r)
                })
                .collect();
            records.push(RuntimeRecord {
                job: e.spec.kind(),
                org: org_for(&e.machine, e.scaleout),
                machine: e.machine.clone(),
                scaleout: e.scaleout,
                job_features: e.spec.job_features(),
                runtime_s: median(&runs),
            });
        }
        Corpus { records }
    }
}

/// Attribute a configuration to an emulated organization. Each org "owns"
/// one (machine type, scale-out-band) niche — mirroring how real
/// collaborators each run their own preferred setup.
pub fn org_for(machine: &str, scaleout: u32) -> String {
    let fam = machine.split('.').next().unwrap_or("x");
    let band = match scaleout {
        0..=4 => "small",
        5..=8 => "mid",
        _ => "large",
    };
    format!("org-{fam}-{band}")
}

/// The executed corpus: one record per unique experiment.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub records: Vec<RuntimeRecord>,
}

impl Corpus {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records for one job, cloned (feed to `RuntimeDataRepo`).
    pub fn records_for(&self, kind: JobKind) -> Vec<RuntimeRecord> {
        self.records
            .iter()
            .filter(|r| r.job == kind)
            .cloned()
            .collect()
    }

    /// Build the per-job shared repository.
    pub fn repo_for(&self, kind: JobKind) -> RuntimeDataRepo {
        RuntimeDataRepo::from_records(kind, self.records_for(kind))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts_exact() {
        let grid = ExperimentGrid::paper_table1();
        let counts = grid.counts();
        let want = [
            (JobKind::Sort, 126),
            (JobKind::Grep, 162),
            (JobKind::Sgd, 180),
            (JobKind::KMeans, 180),
            (JobKind::PageRank, 282),
        ];
        for (k, n) in want {
            assert_eq!(
                counts.iter().find(|(kk, _)| *kk == k).unwrap().1,
                n,
                "{k:?}"
            );
        }
        assert_eq!(grid.experiments.len(), 930);
        assert_eq!(grid.repetitions, 5);
    }

    #[test]
    fn experiments_are_unique() {
        let grid = ExperimentGrid::paper_table1();
        let mut keys: Vec<String> = grid
            .experiments
            .iter()
            .map(|e| format!("{:?}|{}|{}", e.spec, e.machine, e.scaleout))
            .collect();
        keys.sort();
        let before = keys.len();
        keys.dedup();
        assert_eq!(keys.len(), before, "duplicate experiments in grid");
    }

    #[test]
    fn execute_is_deterministic() {
        let cloud = Cloud::aws_like();
        // a small sub-grid for speed
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1().experiments[..20].to_vec(),
            repetitions: 3,
        };
        let a = grid.execute(&cloud, 42);
        let b = grid.execute(&cloud, 42);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.runtime_s, rb.runtime_s);
        }
        let c = grid.execute(&cloud, 43);
        assert!(a
            .records
            .iter()
            .zip(&c.records)
            .any(|(x, y)| x.runtime_s != y.runtime_s));
    }

    #[test]
    fn corpus_splits_by_job() {
        let cloud = Cloud::aws_like();
        let full = ExperimentGrid::paper_table1();
        // only first rep to keep the test fast
        let grid = ExperimentGrid {
            experiments: full.experiments,
            repetitions: 1,
        };
        let corpus = grid.execute(&cloud, 7);
        assert_eq!(corpus.len(), 930);
        assert_eq!(corpus.records_for(JobKind::Sort).len(), 126);
        assert_eq!(corpus.records_for(JobKind::PageRank).len(), 282);
        let repo = corpus.repo_for(JobKind::KMeans);
        assert_eq!(repo.len(), 180);
        // multiple orgs contributed
        assert!(repo.organizations().len() >= 6, "{:?}", repo.organizations());
    }

    #[test]
    fn org_attribution_is_stable_and_partitioned() {
        assert_eq!(org_for("m5.xlarge", 2), "org-m5-small");
        assert_eq!(org_for("m5.xlarge", 4), "org-m5-small");
        assert_eq!(org_for("m5.xlarge", 8), "org-m5-mid");
        assert_eq!(org_for("c5.xlarge", 12), "org-c5-large");
        assert_ne!(org_for("c5.xlarge", 2), org_for("r5.xlarge", 2));
    }

    #[test]
    fn all_runtimes_positive_and_finite() {
        let cloud = Cloud::aws_like();
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1().experiments[..50].to_vec(),
            repetitions: 3,
        };
        let corpus = grid.execute(&cloud, 5);
        for r in &corpus.records {
            assert!(r.runtime_s.is_finite() && r.runtime_s > 0.0, "{r:?}");
        }
    }
}
