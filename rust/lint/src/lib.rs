//! `c3o-lint` — a repo-specific static-analysis pass over the c3o
//! source tree.
//!
//! The collaborative premise of the reproduced paper rests on *bitwise*
//! guarantees (converged peers train identical models; coalesced
//! batches equal sequential serving; cached fits equal from-scratch
//! fits), and the serving stack adds panic-freedom and a typed error
//! taxonomy on top. Property tests enforce those invariants
//! dynamically; this crate pins them at the source level with five
//! zone-aware lexical rules. See `README.md` for the rule catalogue,
//! the zone map, and the suppression grammar.
//!
//! Library layout:
//! * [`lexer`] — the dependency-free Rust tokenizer.
//! * [`config`] — `lint.toml` (zones, rule tables, lock order).
//! * [`engine`] — the rules + suppression handling.

pub mod config;
pub mod engine;
pub mod lexer;

pub use config::{LintConfig, Zone, RULES};
pub use engine::{scan_source, scan_tree, Finding, ScanResult};

/// Escape a string for inclusion in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a scan result as the `--json` document.
pub fn to_json(result: &ScanResult, list_suppressed: bool) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"files_scanned\": {},\n  \"suppressed\": {},\n",
        result.files_scanned,
        result.suppressed.len()
    ));
    let render = |findings: &[Finding]| -> String {
        let items: Vec<String> = findings
            .iter()
            .map(|f| {
                format!(
                    "    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                    json_escape(&f.file),
                    f.line,
                    json_escape(&f.rule),
                    json_escape(&f.message)
                )
            })
            .collect();
        if items.is_empty() {
            "[]".to_string()
        } else {
            format!("[\n{}\n  ]", items.join(",\n"))
        }
    };
    if list_suppressed {
        out.push_str(&format!(
            "  \"suppressed_findings\": {},\n",
            render(&result.suppressed)
        ));
    }
    out.push_str(&format!("  \"findings\": {}\n}}\n", render(&result.findings)));
    out
}
