"""L1 correctness: the Pallas weighted-distance kernel vs the pure-jnp
oracle, including hypothesis sweeps over shapes and value ranges.

This is the core correctness signal for everything the Rust coordinator
executes: if the kernel matches ref.py here, and aot.py lowers the same
graph, then the PJRT artifacts are correct by construction.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.knn import TILE_Q, TILE_T, weighted_sqdist
from compile.kernels import ref


def _rand(rng, *shape, lo=-3.0, hi=3.0):
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    q = _rand(rng, TILE_Q, 16)
    t = _rand(rng, TILE_T * 2, 16)
    w = rng.uniform(0.0, 2.0, size=16).astype(np.float32)
    got = weighted_sqdist(q, t, w)
    want = ref.weighted_sqdist_ref(jnp.asarray(q), jnp.asarray(t), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_zero_weights_give_zero_distance():
    rng = np.random.default_rng(1)
    q = _rand(rng, TILE_Q, 8)
    t = _rand(rng, TILE_T, 8)
    w = np.zeros(8, np.float32)
    got = np.asarray(weighted_sqdist(q, t, w))
    np.testing.assert_allclose(got, np.zeros_like(got), atol=1e-6)


def test_identical_points_zero_diagonal():
    rng = np.random.default_rng(2)
    x = _rand(rng, TILE_Q, 16)
    w = rng.uniform(0.1, 1.0, size=16).astype(np.float32)
    d = np.asarray(weighted_sqdist(x, x, w))
    np.testing.assert_allclose(np.diag(d), np.zeros(TILE_Q), atol=1e-3)
    # and never negative (the kernel clamps cancellation error)
    assert (d >= 0.0).all()


def test_weight_scaling_linearity():
    rng = np.random.default_rng(3)
    q = _rand(rng, TILE_Q, 4)
    t = _rand(rng, TILE_T, 4)
    w = rng.uniform(0.1, 1.0, size=4).astype(np.float32)
    d1 = np.asarray(weighted_sqdist(q, t, w))
    d3 = np.asarray(weighted_sqdist(q, t, 3.0 * w))
    np.testing.assert_allclose(d3, 3.0 * d1, rtol=1e-4, atol=1e-4)


def test_padded_feature_columns_are_inert():
    # zero-weighted padding columns must not change distances — the
    # contract the Rust featurizer relies on when padding F to 16.
    rng = np.random.default_rng(4)
    q8 = _rand(rng, TILE_Q, 8)
    t8 = _rand(rng, TILE_T, 8)
    w8 = rng.uniform(0.1, 1.0, size=8).astype(np.float32)
    pad_q = np.concatenate([q8, _rand(rng, TILE_Q, 8)], axis=1)
    pad_t = np.concatenate([t8, _rand(rng, TILE_T, 8)], axis=1)
    pad_w = np.concatenate([w8, np.zeros(8, np.float32)])
    d8 = np.asarray(weighted_sqdist(q8, t8, w8))
    d16 = np.asarray(weighted_sqdist(pad_q, pad_t, pad_w))
    np.testing.assert_allclose(d16, d8, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    qt=st.integers(1, 3),
    tt=st.integers(1, 3),
    f=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
)
def test_hypothesis_shapes_and_scales(qt, tt, f, seed, scale):
    """Sweep tile-multiple shapes, feature dims, and value magnitudes."""
    rng = np.random.default_rng(seed)
    q = _rand(rng, qt * TILE_Q, f, lo=-scale, hi=scale)
    t = _rand(rng, tt * TILE_T, f, lo=-scale, hi=scale)
    w = rng.uniform(0.0, 2.0, size=f).astype(np.float32)
    got = np.asarray(weighted_sqdist(q, t, w))
    want = np.asarray(
        ref.weighted_sqdist_ref(jnp.asarray(q), jnp.asarray(t), jnp.asarray(w))
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * scale**2)


def test_non_tile_multiple_rejected():
    rng = np.random.default_rng(5)
    q = _rand(rng, TILE_Q + 1, 4)
    t = _rand(rng, TILE_T, 4)
    w = np.ones(4, np.float32)
    with pytest.raises(AssertionError):
        weighted_sqdist(q, t, w)
