//! The durable segment store: a per-job append-only WAL plus atomic
//! snapshots, so a coordinator recovers its full corpus on startup.
//!
//! On-disk layout, one directory per [`JobKind`] under the store root:
//!
//! ```text
//! <root>/<job>/
//!   snap-00000000000000000126.csv   # atomic snapshot at generation 126
//!   wal-000003.log                  # segment: one checksummed op/line
//!   wal-000004.log                  # current segment
//! ```
//!
//! * **WAL lines.** Every repository mutation is one line:
//!   `gen,op,job,org,machine,scaleout,features,runtime,checksum`. `gen`
//!   is the repo generation *after* the op; `op` is `C` (blind
//!   contribute), `M` (merge-applied add-or-replace), or `K` (canonical
//!   reorder, no content change). The trailing FNV-1a checksum makes a
//!   torn tail write detectable on recovery.
//! * **Segments** rotate at [`JobStore::with_segment_cap`] lines, so
//!   compaction never rewrites unbounded history.
//! * **Snapshots** are whole-repo CSVs written to a temp file and
//!   `rename`d into place (atomic on POSIX), with the generation in the
//!   file name. [`JobStore::compact`] writes one and deletes all
//!   segments — every op they held is ≤ the snapshot generation.
//! * **Recovery** ([`JobStore::open`]) loads the newest snapshot, then
//!   replays segments in order, skipping ops the snapshot already
//!   covers. A checksum-failing or newline-less final line is tolerated
//!   as a crash-torn tail (and the store rotates to a fresh segment so
//!   it never appends after torn bytes); corruption anywhere else is a
//!   hard error. Replay re-applies ops through the same
//!   `contribute`/`merge_records` code the live write path uses, and
//!   cross-checks every line's generation stamp, so a recovered repo is
//!   bitwise-identical to the pre-crash one — including record order.
//!
//! **Durability scope.** Appends flush to the OS (surviving process
//! crashes, the failure mode of the simulated substrate); they do not
//! fsync per batch, so an OS/power failure can lose the tail of the
//! page cache. Snapshots *are* fsynced before the rename publishes
//! them (plus a best-effort directory sync). Per-append fsync (or
//! group-commit batching) is a ROADMAP follow-up for real deployments.

use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::util::csv;
use crate::util::hash::fnv1a64;
use crate::workloads::JobKind;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::fs;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};

/// Default WAL lines per segment before rotation.
pub const DEFAULT_SEGMENT_CAP: usize = 256;
/// Default un-snapshotted ops before [`JobStore::maybe_compact`] fires.
pub const DEFAULT_COMPACT_THRESHOLD: usize = 1024;

/// One durable repository mutation, as logged to (and replayed from)
/// the WAL.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreOp {
    /// Blind append — the contribute path. Replay re-contributes, so
    /// locally-observed duplicate configurations survive recovery.
    Contribute(RuntimeRecord),
    /// Merge-applied record (an add or a deterministic-winner
    /// replacement). Replay re-merges, reproducing the same slot.
    Merge(RuntimeRecord),
    /// Canonical reordering of the whole repo (content unchanged, the
    /// generation does not move). Logged so recovery reproduces record
    /// *order* bitwise, not just content.
    Canonicalize,
}

/// Append-only, generation-stamped record log for one job kind, with
/// atomic snapshot + segment compaction.
pub struct JobStore {
    dir: PathBuf,
    job: JobKind,
    /// Repo generation after the last appended op (mirrors the owning
    /// repo; cross-checked on every append).
    generation: u64,
    /// Generation covered by the newest on-disk snapshot.
    snapshot_generation: u64,
    /// Ops applied since the last snapshot (the compaction trigger).
    pending: usize,
    seg_ordinal: u64,
    seg_records: usize,
    writer: Option<BufWriter<fs::File>>,
    segment_cap: usize,
    compact_threshold: usize,
}

impl JobStore {
    /// Open (or create) the store for `job` under `root` and recover
    /// its repository: newest snapshot + WAL replay.
    pub fn open(root: &Path, job: JobKind) -> Result<(JobStore, RuntimeDataRepo)> {
        let dir = root.join(job.name());
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;

        let mut snaps: Vec<(u64, PathBuf)> = Vec::new();
        let mut segs: Vec<(u64, PathBuf)> = Vec::new();
        for entry in
            fs::read_dir(&dir).with_context(|| format!("reading {}", dir.display()))?
        {
            let entry = entry.with_context(|| format!("reading {}", dir.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if let Some(gen) = name
                .strip_prefix("snap-")
                .and_then(|s| s.strip_suffix(".csv"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                snaps.push((gen, entry.path()));
            } else if let Some(ord) = name
                .strip_prefix("wal-")
                .and_then(|s| s.strip_suffix(".log"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                segs.push((ord, entry.path()));
            }
            // anything else (snap.tmp from an interrupted compaction,
            // foreign files) is ignored
        }
        snaps.sort();
        segs.sort();

        // 1) newest snapshot, if any
        let (mut repo, snap_gen) = match snaps.last() {
            None => (RuntimeDataRepo::new(job), 0u64),
            Some((gen, path)) => {
                let table = csv::Table::load(path)
                    .map_err(|e| anyhow!("loading snapshot {}: {e}", path.display()))?;
                let repo = RuntimeDataRepo::from_table(job, &table)
                    .map_err(anyhow::Error::msg)
                    .with_context(|| format!("parsing snapshot {}", path.display()))?;
                ensure!(
                    *gen >= repo.generation(),
                    "snapshot {} names generation {gen} but holds {} records",
                    path.display(),
                    repo.len()
                );
                let mut repo = repo;
                repo.restore_generation(*gen);
                (repo, *gen)
            }
        };

        // 2) replay segments in order
        let mut pending = 0usize;
        let mut torn_tail = false;
        let mut last_seg_lines = 0usize;
        let nsegs = segs.len();
        for (si, (_ord, path)) in segs.iter().enumerate() {
            let text = fs::read_to_string(path)
                .with_context(|| format!("reading segment {}", path.display()))?;
            let last_seg = si + 1 == nsegs;
            if last_seg && !text.is_empty() && !text.ends_with('\n') {
                // the final line was cut before its newline; even if its
                // content happens to parse, never append after it
                torn_tail = true;
            }
            let lines: Vec<&str> = text.lines().collect();
            let nlines = lines.len();
            if last_seg {
                // remembered so the append path knows how full the
                // segment is without re-reading it
                last_seg_lines = lines.iter().filter(|l| !l.is_empty()).count();
            }
            for (li, line) in lines.iter().enumerate() {
                if line.is_empty() {
                    continue;
                }
                let last_line = last_seg && li + 1 == nlines;
                match parse_wal_line(job, line) {
                    Err(e) => {
                        if last_line {
                            // crash-torn tail: the op never fully landed
                            torn_tail = true;
                            break;
                        }
                        bail!(
                            "corrupt WAL line {} in {}: {e:#}",
                            li + 1,
                            path.display()
                        );
                    }
                    Ok((gen, op)) => {
                        let applied = apply_wal_op(&mut repo, snap_gen, gen, op)
                            .with_context(|| {
                                format!("replaying {} line {}", path.display(), li + 1)
                            })?;
                        if applied {
                            pending += 1;
                        }
                    }
                }
            }
        }

        let last_ord = segs.last().map(|(ord, _)| *ord).unwrap_or(0);
        let (seg_ordinal, seg_records) = if torn_tail || segs.is_empty() {
            (last_ord + 1, 0)
        } else {
            // continue the last segment (its line count bounds rotation)
            (last_ord.max(1), last_seg_lines)
        };

        let store = JobStore {
            dir,
            job,
            generation: repo.generation(),
            snapshot_generation: snap_gen,
            pending,
            seg_ordinal,
            seg_records,
            writer: None,
            segment_cap: DEFAULT_SEGMENT_CAP,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
        };
        Ok((store, repo))
    }

    /// Override the per-segment line cap (tests, benches).
    pub fn with_segment_cap(mut self, cap: usize) -> Self {
        self.segment_cap = cap.max(1);
        self
    }

    /// Override the auto-compaction threshold (tests, benches).
    pub fn with_compact_threshold(mut self, threshold: usize) -> Self {
        self.compact_threshold = threshold.max(1);
        self
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    /// Directory this job's segments and snapshots live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Repo generation after the last appended op.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Generation covered by the newest snapshot (0 if none yet).
    pub fn snapshot_generation(&self) -> u64 {
        self.snapshot_generation
    }

    /// Ops appended (or replayed) since the last snapshot.
    pub fn pending_ops(&self) -> usize {
        self.pending
    }

    /// Durably log a batch of ops. `repo_generation_after` is the owning
    /// repository's generation after the batch — the store stamps each
    /// op itself and cross-checks the result, so a store/repo desync is
    /// an error instead of silent corruption.
    pub fn append(&mut self, ops: &[StoreOp], repo_generation_after: u64) -> Result<()> {
        // Render against a local generation cursor: nothing in the
        // store's state moves until the batch is fully written, so a
        // rejected or failed append leaves the mirror exactly where it
        // was (no compounding drift across retries).
        let mut gen = self.generation;
        let mut lines = String::new();
        for op in ops {
            let line = render_op(self.job, &mut gen, op)?;
            lines.push_str(&line);
            lines.push('\n');
        }
        ensure!(
            gen == repo_generation_after,
            "store/repo generation desync after append: store {gen}, repo {repo_generation_after}"
        );
        if ops.is_empty() {
            return Ok(());
        }
        if self.seg_records >= self.segment_cap {
            self.rotate();
        }
        let writer = self.writer()?;
        writer.write_all(lines.as_bytes())?;
        writer.flush()?;
        self.generation = gen;
        self.seg_records += ops.len();
        self.pending += ops.len();
        Ok(())
    }

    /// Write an atomic snapshot of `repo` (temp file + rename), then
    /// delete every segment and superseded snapshot — all their ops are
    /// ≤ the snapshot generation.
    pub fn compact(&mut self, repo: &RuntimeDataRepo) -> Result<()> {
        ensure!(
            repo.generation() == self.generation,
            "compacting against a desynced repo: store {}, repo {}",
            self.generation,
            repo.generation()
        );
        let gen = self.generation;
        let final_path = self.dir.join(format!("snap-{gen:020}.csv"));
        let tmp = self.dir.join("snap.tmp");
        {
            let mut file = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            file.write_all(repo.to_table().to_csv().as_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            // snapshots supersede segments, so they must actually be on
            // disk before the rename publishes them
            file.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        fs::rename(&tmp, &final_path)
            .with_context(|| format!("publishing {}", final_path.display()))?;
        // best-effort directory sync so the rename itself is durable
        // (not supported on every platform; recovery tolerates a lost
        // rename by falling back to the previous snapshot + segments)
        if let Ok(dir_handle) = fs::File::open(&self.dir) {
            let _ = dir_handle.sync_all();
        }
        // drop the open segment handle before unlinking segments
        self.writer = None;
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let superseded_snap = name.starts_with("snap-")
                && name.ends_with(".csv")
                && entry.path() != final_path;
            let segment = name.starts_with("wal-") && name.ends_with(".log");
            if superseded_snap || segment {
                fs::remove_file(entry.path())
                    .with_context(|| format!("removing {}", name))?;
            }
        }
        self.seg_ordinal += 1;
        self.seg_records = 0;
        self.pending = 0;
        self.snapshot_generation = gen;
        Ok(())
    }

    /// Compact when the un-snapshotted op count crosses the threshold.
    /// Returns whether a compaction ran.
    pub fn maybe_compact(&mut self, repo: &RuntimeDataRepo) -> Result<bool> {
        if self.pending >= self.compact_threshold {
            self.compact(repo)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn rotate(&mut self) {
        self.writer = None; // BufWriter flushed on every append already
        self.seg_ordinal += 1;
        self.seg_records = 0;
    }

    fn writer(&mut self) -> Result<&mut BufWriter<fs::File>> {
        if self.writer.is_none() {
            let path = self.dir.join(format!("wal-{:06}.log", self.seg_ordinal));
            let file = fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening segment {}", path.display()))?;
            self.writer = Some(BufWriter::new(file));
        }
        Ok(self.writer.as_mut().expect("just set"))
    }

}

/// Render one op to its sealed WAL line, advancing the caller's
/// generation cursor for record ops (pure with respect to the store —
/// [`JobStore::append`] commits the cursor only after the batch hits
/// the file).
fn render_op(job: JobKind, gen: &mut u64, op: &StoreOp) -> Result<String> {
    let fields = match op {
        StoreOp::Contribute(r) | StoreOp::Merge(r) => {
            // defense in depth: RuntimeRecord::validate already rejects
            // these at every ingress, but a framing break would corrupt
            // the WAL, so re-check at the last line of defense
            ensure!(
                framing_safe(&r.org) && framing_safe(&r.machine),
                "org/machine may not contain newlines (WAL framing): {:?}/{:?}",
                r.org,
                r.machine
            );
            ensure!(
                r.job == job,
                "{} record appended to {} store",
                r.job.name(),
                job.name()
            );
            *gen += 1;
            let code = if matches!(op, StoreOp::Contribute(_)) { "C" } else { "M" };
            vec![
                gen.to_string(),
                code.to_string(),
                r.job.name().to_string(),
                r.org.clone(),
                r.machine.clone(),
                r.scaleout.to_string(),
                r.job_features
                    .iter()
                    .map(|f| format!("{f}"))
                    .collect::<Vec<_>>()
                    .join(";"),
                format!("{}", r.runtime_s),
            ]
        }
        StoreOp::Canonicalize => vec![
            gen.to_string(),
            "K".to_string(),
            job.name().to_string(),
            String::new(),
            String::new(),
            "0".to_string(),
            String::new(),
            "0".to_string(),
        ],
    };
    let body = csv::render_line(&fields);
    let sum = fnv1a64(body.as_bytes());
    Ok(format!("{body},{sum:016x}"))
}

fn framing_safe(s: &str) -> bool {
    !s.contains('\n') && !s.contains('\r')
}

/// Parse one sealed WAL line back into its generation stamp and op.
fn parse_wal_line(job: JobKind, line: &str) -> Result<(u64, StoreOp)> {
    let (body, sum_hex) = line.rsplit_once(',').context("missing checksum")?;
    let sum = u64::from_str_radix(sum_hex, 16).context("bad checksum field")?;
    ensure!(sum == fnv1a64(body.as_bytes()), "checksum mismatch");
    let fields = csv::parse_line(body).map_err(|e| anyhow!("bad WAL row: {e}"))?;
    ensure!(fields.len() == 8, "expected 8 fields, got {}", fields.len());
    let gen: u64 = fields[0].parse().context("bad generation")?;
    let op = match fields[1].as_str() {
        "K" => StoreOp::Canonicalize,
        "C" | "M" => {
            ensure!(
                fields[2] == job.name(),
                "foreign job {:?} in {} store",
                fields[2],
                job.name()
            );
            let job_features: Vec<f64> = if fields[6].is_empty() {
                Vec::new()
            } else {
                fields[6]
                    .split(';')
                    .map(|s| s.parse::<f64>().map_err(|_| anyhow!("bad feature {s:?}")))
                    .collect::<Result<_>>()?
            };
            let record = RuntimeRecord {
                job,
                org: fields[3].clone(),
                machine: fields[4].clone(),
                scaleout: fields[5].parse().context("bad scaleout")?,
                job_features,
                runtime_s: fields[7]
                    .parse()
                    .map_err(|_| anyhow!("bad runtime {:?}", fields[7]))?,
            };
            if fields[1] == "C" {
                StoreOp::Contribute(record)
            } else {
                StoreOp::Merge(record)
            }
        }
        other => bail!("unknown WAL op {other:?}"),
    };
    Ok((gen, op))
}

/// Replay one op against the recovering repo. Ops the snapshot already
/// covers are skipped; everything else must advance the generation in
/// exact sequence. Returns whether the op was applied.
fn apply_wal_op(
    repo: &mut RuntimeDataRepo,
    snap_gen: u64,
    gen: u64,
    op: StoreOp,
) -> Result<bool> {
    match op {
        StoreOp::Contribute(r) => {
            if gen <= snap_gen {
                return Ok(false);
            }
            ensure!(
                gen == repo.generation() + 1,
                "WAL generation gap: line stamped {gen}, repo at {}",
                repo.generation()
            );
            repo.contribute(r).map_err(anyhow::Error::msg)?;
            Ok(true)
        }
        StoreOp::Merge(r) => {
            if gen <= snap_gen {
                return Ok(false);
            }
            ensure!(
                gen == repo.generation() + 1,
                "WAL generation gap: line stamped {gen}, repo at {}",
                repo.generation()
            );
            let out = repo
                .merge_records(std::slice::from_ref(&r))
                .map_err(anyhow::Error::msg)?;
            ensure!(
                out.changed() == 1,
                "WAL merge line replayed as a no-op at generation {gen}"
            );
            Ok(true)
        }
        StoreOp::Canonicalize => {
            if gen < snap_gen {
                return Ok(false);
            }
            ensure!(
                gen == repo.generation(),
                "canonicalize stamped {gen} but repo is at {}",
                repo.generation()
            );
            repo.canonicalize();
            Ok(true)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(org: &str, scaleout: u32, gb: f64, runtime: f64) -> RuntimeRecord {
        RuntimeRecord {
            job: JobKind::Sort,
            org: org.into(),
            machine: "m5.xlarge".into(),
            scaleout,
            job_features: vec![gb],
            runtime_s: runtime,
        }
    }

    fn temp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "c3o_segstore_{}_{name}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    /// Drive a (repo, store) pair through the same motions a shard does.
    fn apply(
        repo: &mut RuntimeDataRepo,
        store: &mut JobStore,
        op: StoreOp,
    ) {
        match &op {
            StoreOp::Contribute(r) => repo.contribute(r.clone()).unwrap(),
            StoreOp::Merge(r) => {
                let out = repo.merge_records(std::slice::from_ref(r)).unwrap();
                assert_eq!(out.changed(), 1, "test op must change the repo");
            }
            StoreOp::Canonicalize => repo.canonicalize(),
        }
        store.append(std::slice::from_ref(&op), repo.generation()).unwrap();
    }

    #[test]
    fn append_and_reopen_round_trip() {
        let root = temp_store("round_trip");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("a", 4, 10.0, 100.0)));
        apply(&mut repo, &mut store, StoreOp::Merge(rec("b", 8, 10.0, 60.0)));
        apply(&mut repo, &mut store, StoreOp::Canonicalize);
        drop(store);

        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records(), "bitwise incl. order");
        assert_eq!(repo2.generation(), repo.generation());
        assert_eq!(store2.generation(), repo.generation());
        assert_eq!(store2.pending_ops(), 3);
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn compaction_supersedes_segments() {
        let root = temp_store("compact");
        let (store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        let mut store = store.with_segment_cap(2);
        for i in 0..5u32 {
            apply(
                &mut repo,
                &mut store,
                StoreOp::Contribute(rec("a", 2 + i, 10.0 + i as f64, 100.0)),
            );
        }
        store.compact(&repo).unwrap();
        assert_eq!(store.pending_ops(), 0);
        assert_eq!(store.snapshot_generation(), 5);
        let names: Vec<String> = fs::read_dir(store.dir())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(names.iter().all(|n| !n.starts_with("wal-")), "{names:?}");
        assert_eq!(names.iter().filter(|n| n.starts_with("snap-")).count(), 1);

        // appends continue after compaction; reopen sees snapshot + tail
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("a", 9, 21.0, 90.0)));
        drop(store);
        let (store2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records());
        assert_eq!(repo2.generation(), 6);
        assert_eq!(store2.pending_ops(), 1, "only the post-snapshot op is pending");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn torn_tail_is_ignored_and_never_appended_after() {
        let root = temp_store("torn");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("a", 4, 10.0, 100.0)));
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("a", 8, 10.0, 60.0)));
        drop(store);

        // simulate a crash mid-append: half a line, no newline
        let seg = fs::read_dir(root.join("sort"))
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| p.to_string_lossy().contains("wal-"))
            .unwrap();
        let mut bytes = fs::read(&seg).unwrap();
        bytes.extend_from_slice(b"3,C,sort,org-x,m5.xl");
        fs::write(&seg, bytes).unwrap();

        let (mut store2, mut repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.len(), 2, "complete records survive, torn op is dropped");
        assert_eq!(repo2.generation(), 2);

        // new appends land in a fresh segment, then everything recovers
        apply(&mut repo2, &mut store2, StoreOp::Contribute(rec("b", 2, 12.0, 200.0)));
        drop(store2);
        let (_store3, repo3) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo3.records(), repo2.records());
        assert_eq!(repo3.generation(), 3);
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn corruption_before_the_tail_is_a_hard_error() {
        let root = temp_store("corrupt");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("a", 4, 10.0, 100.0)));
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("a", 8, 10.0, 60.0)));
        drop(store);
        let seg = fs::read_dir(root.join("sort"))
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| p.to_string_lossy().contains("wal-"))
            .unwrap();
        let text = fs::read_to_string(&seg).unwrap();
        // flip a byte in the FIRST line: mid-file corruption, not a torn tail
        let mangled = text.replacen("m5.xlarge", "m5.xlargX", 1);
        assert_ne!(text, mangled);
        fs::write(&seg, mangled).unwrap();
        let err = JobStore::open(&root, JobKind::Sort).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        let _ = fs::remove_dir_all(root);
    }

    #[test]
    fn merge_replacements_replay_bitwise() {
        let root = temp_store("replace");
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        apply(&mut repo, &mut store, StoreOp::Contribute(rec("z", 4, 10.0, 100.0)));
        // a deterministic-winner replacement (smaller runtime) + reorder
        apply(&mut repo, &mut store, StoreOp::Merge(rec("a", 4, 10.0, 90.0)));
        apply(&mut repo, &mut store, StoreOp::Canonicalize);
        assert_eq!(repo.len(), 1);
        assert_eq!(repo.generation(), 2, "replacement advanced the generation");
        drop(store);
        let (_s, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
        assert_eq!(repo2.records(), repo.records());
        assert_eq!(repo2.generation(), 2);
        let _ = fs::remove_dir_all(root);
    }
}
