//! Fixture: `float-order` fold form and the `allow-fn` suppression.

pub fn fold_total(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, b| a + b)
}

// c3o-lint: allow-fn(float-order) — fixture: whole-fn suppression; order fixed by slice iteration
pub fn fn_scoped(xs: &[f32]) -> f32 {
    let head = xs.iter().take(2).sum::<f32>();
    let tail = xs.iter().skip(2).sum::<f32>();
    head + tail
}
