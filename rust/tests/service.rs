//! Integration tests of the sharded multi-worker coordinator service:
//! concurrent clients across shards, per-request reply integrity, and
//! generation-gated retraining. All of these run without PJRT artifacts
//! (native model engines).

use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::{CoordinatorService, Organization, ServiceConfig, ShardPolicy};
use c3o::workloads::{Corpus, ExperimentGrid, JobKind};

const KINDS: [JobKind; 4] = [JobKind::Sort, JobKind::Grep, JobKind::Sgd, JobKind::KMeans];

fn corpus(cloud: &Cloud, seed: u64) -> Corpus {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| KINDS.contains(&e.spec.kind()))
            .collect(),
        repetitions: 1,
    }
    .execute(cloud, seed)
}

fn request_for(kind: JobKind, salt: usize) -> JobRequest {
    let gb = 10.0 + (salt % 10) as f64;
    match kind {
        JobKind::Sort => JobRequest::sort(gb),
        JobKind::Grep => JobRequest::grep(gb, 0.1),
        JobKind::Sgd => JobRequest::sgd(gb, 60),
        JobKind::KMeans => JobRequest::kmeans(gb, 5, 0.001),
        JobKind::PageRank => JobRequest::pagerank(25.0 * gb, 0.001),
    }
}

#[test]
fn eight_concurrent_clients_across_four_shards() {
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 5);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(4).with_seed(17),
    );
    let mut seeded: u64 = 0;
    for kind in KINDS {
        let added = service.share(corpus.repo_for(kind)).unwrap();
        assert!(added > 0, "{kind:?} corpus must contribute records");
        seeded += added as u64;
    }

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 3;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let client = service.client();
            handles.push(scope.spawn(move || {
                let org = Organization::new(&format!("client-{c}"));
                let mut outcomes = Vec::new();
                for j in 0..PER_CLIENT {
                    // interleave kinds so shards serve concurrently
                    let kind = KINDS[(c + j) % KINDS.len()];
                    let req = request_for(kind, c * PER_CLIENT + j).with_target_seconds(5000.0);
                    outcomes.push((kind, client.submit(&org, req).unwrap()));
                }
                (c, outcomes)
            }));
        }
        for handle in handles {
            let (c, outcomes) = handle.join().unwrap();
            for (j, (expected_kind, outcome)) in outcomes.into_iter().enumerate() {
                // per-request reply channels: every client gets exactly
                // its own job back, regardless of interleaving
                assert_eq!(
                    outcome.job, expected_kind,
                    "client {c} job {j} got a reply for the wrong request"
                );
                assert_eq!(outcome.org, format!("client-{c}"));
                assert!(
                    outcome.model_used.is_some(),
                    "client {c} job {j} should be model-served from the corpus"
                );
                assert!(outcome.actual_runtime_s > 0.0);
            }
        }
    });

    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.submissions, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(metrics.targets_given, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(metrics.fallbacks, 0, "all shards were seeded");
    assert!(metrics.retrains >= KINDS.len() as u64, "each shard trained once");
    assert!(metrics.mean_prediction_error_pct().is_finite());

    // every submission contributed its run back to its shard: the summed
    // shard generations advanced by exactly seeded records + submissions
    let contributed: u64 = KINDS.iter().map(|&k| service.generation(k)).sum();
    assert_eq!(contributed, seeded + (CLIENTS * PER_CLIENT) as u64);
    service.shutdown();
}

#[test]
fn service_retraining_is_gated_by_generation() {
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 9);
    let policy = ShardPolicy {
        retrain_every: 1_000, // far beyond this test's contributions
        ..ShardPolicy::default()
    };
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default()
            .with_workers(2)
            .with_seed(23)
            .with_policy(policy),
    );
    service.share(corpus.repo_for(JobKind::Sort)).unwrap();
    let org = Organization::new("steady");

    // first submission trains; the trained generation is recorded
    service
        .submit(&org, request_for(JobKind::Sort, 0))
        .unwrap();
    assert_eq!(service.metrics().unwrap().retrains, 1);
    let trained_at = service.trained_at_generation(JobKind::Sort).unwrap();

    // re-sharing the identical corpus adds nothing: generation frozen
    let gen_before = service.generation(JobKind::Sort);
    assert_eq!(service.share(corpus.repo_for(JobKind::Sort)).unwrap(), 0);
    assert_eq!(service.generation(JobKind::Sort), gen_before);

    // repeated submissions with no new shared data: zero further
    // retrains, asserted via Metrics (the acceptance criterion)
    for i in 1..=6 {
        let outcome = service
            .submit(&org, request_for(JobKind::Sort, i))
            .unwrap();
        assert!(outcome.model_used.is_some());
    }
    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.retrains, 1, "generation gate failed: {metrics:?}");
    assert_eq!(metrics.cache_hits, 6);
    assert_eq!(
        service.trained_at_generation(JobKind::Sort).unwrap(),
        trained_at,
        "cached model must still be the original training"
    );
    service.shutdown();
}

#[test]
fn shares_and_submits_interleave_across_clients() {
    // One client streams shares while another streams submissions of a
    // different kind: neither blocks the other's replies (the ordered
    // session could interleave these only in lockstep).
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 13);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(2).with_seed(31),
    );
    service.share(corpus.repo_for(JobKind::Grep)).unwrap();
    let sort_added = service.share(corpus.repo_for(JobKind::Sort)).unwrap() as u64;

    std::thread::scope(|scope| {
        let sharer = service.client();
        let submitter = service.client();
        let sort_repo = corpus.repo_for(JobKind::Sort);
        scope.spawn(move || {
            // idempotent re-shares: valid traffic that changes nothing
            for _ in 0..5 {
                assert_eq!(sharer.share(sort_repo.clone()).unwrap(), 0);
            }
        });
        scope.spawn(move || {
            let org = Organization::new("interleaved");
            for i in 0..4 {
                let o = submitter
                    .submit(&org, request_for(JobKind::Grep, i))
                    .unwrap();
                assert_eq!(o.job, JobKind::Grep);
            }
        });
    });

    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.submissions, 4);
    // the five redundant re-shares moved the sort generation not at all
    assert_eq!(service.generation(JobKind::Sort), sort_added);
    service.shutdown();
}
