//! Minimal, offline-vendored subset of the `anyhow` API.
//!
//! The real `anyhow` crate is not in the offline vendor set, so this
//! crate provides the slice of its surface the workspace actually uses:
//!
//! * [`Error`] — a context-chain error (outermost context first), with
//!   `{}` printing the outermost message and `{:#}` the full chain.
//! * [`Result<T>`] with the `Error` default.
//! * [`anyhow!`] / [`bail!`] macros.
//! * The [`Context`] extension trait for `Result` and `Option`.
//! * A blanket `From<E: std::error::Error>` so `?` converts foreign
//!   errors (possible because `Error` itself deliberately does not
//!   implement `std::error::Error`, exactly like the real crate).

use std::fmt;

/// Context-chain error: `chain[0]` is the outermost (most recent) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full context chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// `Error` intentionally does NOT implement `std::error::Error`; that is
// what makes this blanket conversion coherent (mirroring real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — like `std::result::Result` with `Error` default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Assert-or-error.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading").unwrap_err();
        assert_eq!(format!("{e:#}"), "loading: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        let name = "knn";
        let e = anyhow!("compile {name} failed");
        assert_eq!(format!("{e}"), "compile knn failed");
        let msg = String::from("prebuilt message");
        let e = anyhow!(msg);
        assert_eq!(format!("{e}"), "prebuilt message");
        fn bails() -> Result<()> {
            bail!("nope {}", 3);
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "nope 3");
    }
}
