//! # C3O — Collaborative Cluster Configuration Optimization
//!
//! A reproduction of *"Towards Collaborative Optimization of Cluster
//! Configurations for Distributed Dataflow Jobs"* (Will, Bader, Thamsen —
//! IEEE BigData 2020) as a three-layer Rust + JAX + Pallas system.
//!
//! The library lets many *organizations* share historical runtime data of
//! distributed dataflow jobs (Sort, Grep, SGD, K-Means, PageRank on a
//! simulated Spark/EMR substrate), trains black-box runtime prediction
//! models on the shared corpus (a similarity-weighted kNN "pessimistic"
//! model and a factorized "optimistic" model, both executed as AOT-compiled
//! XLA artifacts via PJRT), and uses them to pick the cheapest cluster
//! configuration (machine type × scale-out) that meets a runtime target —
//! without any profiling runs.
//!
//! ## The protocol and its read/write split
//!
//! All serving goes through one **typed, versioned protocol** ([`api`]):
//! a [`Request`](api::Request)/[`Response`](api::Response) pair with a
//! structured [`ApiError`](api::ApiError) taxonomy, behind the
//! deployment-agnostic [`Client`](api::Client) trait. The protocol
//! mirrors the paper's asymmetry — many cheap reads, few writes:
//!
//! * **Reads** — `Recommend` (the configurator step as a standalone
//!   query: score all candidates, return the decision, run nothing),
//!   `SnapshotInfo`, `Metrics`, `Watermarks`/`WatermarksAll`,
//!   `SyncPull`/`SyncPullAll`, `MeshRoster`. Reads never train or
//!   mutate.
//! * **Writes** — `Submit` (decide → provision + run → contribute),
//!   `Contribute` (record an externally-observed run), `Share`
//!   (bulk-merge a repository), `SyncPush`/`SyncPushAll` (apply a
//!   federated peer's delta), `MeshHello` (gossip membership; a
//!   self-hello ticks anti-entropy and may truncate acked op-log
//!   prefixes). Writes refresh the generation-stamped model that reads
//!   are served from — and persist through the segment store in durable
//!   deployments.
//!
//! Three deployments implement [`Client`](api::Client) with identical
//! decisions on identical inputs: the sequential
//! [`Coordinator`](coordinator::Coordinator), the ordered single-worker
//! [`session`](coordinator::session), and the concurrent
//! [`service`](coordinator::service) — where the split becomes a locking
//! discipline: writes take their shard's mutex, while reads are served
//! lock-free from published immutable
//! [`ModelSnapshot`](coordinator::shard::ModelSnapshot)s (with
//! cross-request coalescing of same-kind `Recommend` *and* `Submit`
//! batches and pipelined `submit_nowait` tickets — a drained write
//! group is pre-scored as one predict batch before its serialized
//! contribute steps, with identical decisions to sequential serving).
//!
//! ## Persistence and federation: one operation log
//!
//! The collaborative corpus is long-lived, shared state, and every
//! notion of "what changed" flows through **one abstraction**: the
//! per-(org, job) sequence-numbered operation log maintained by the
//! repository ([`repo`]). Each accepted mutation gets a monotone
//! per-org seqno; [`repo::OrgWatermark`] is a log position
//! `(seqno, digest)`; [`RuntimeDataRepo::ops_since`](repo::RuntimeDataRepo::ops_since)
//! extracts record-level deltas. The WAL and the sync protocol replay
//! the *same* log:
//!
//! * The **durable segment store** ([`store::segment`]) gives every job
//!   an append-only WAL of generation- and seqno-stamped, checksummed
//!   ops plus atomic snapshots (with an op-log sidecar) and segment
//!   compaction. A deployment opened over a store
//!   ([`Coordinator::open_with_store`](coordinator::Coordinator::open_with_store),
//!   [`ServiceConfig::with_store_dir`](coordinator::ServiceConfig::with_store_dir))
//!   recovers its corpus bitwise — including record order and org-log
//!   positions — and warms its model caches before serving.
//! * The **peer delta-sync protocol** ([`store::sync`], API v3/v4)
//!   ships sequence-numbered [`SyncOp`](repo::SyncOp)s past the peer's
//!   watermarks: **O(changed records)** per exchange when logs are
//!   prefix-aligned (the gossip steady state), with a digest-checked
//!   whole-org fallback on genuine divergence. Merge-rejected ops still
//!   advance the receiver's watermark (logged as *seen*), so an org's
//!   blind duplicate contributions are shipped once and never
//!   re-offered. Merge-level dedup with a deterministic conflict order
//!   makes the exchange idempotent and convergent: peers gossiping in
//!   any order end up with bitwise-identical repositories serving
//!   bitwise-identical recommendations, and runtime disagreements
//!   surface as structured [`MergeConflict`](repo::MergeConflict)s.
//!   One entry point — [`store::sync::sync`] with
//!   [`SyncOptions`](store::SyncOptions) — selects scope (one job /
//!   some / all), detail, and protocol: per-job v3, the batched v4
//!   cross-job exchange (`WatermarksAll`/`SyncPullAll`/`SyncPushAll`,
//!   one round trip covering every [`workloads::JobKind`]), or the
//!   legacy v2 translation (org-granular, O(org corpus) per changed
//!   org), which lives quarantined in [`api::compat`].
//! * The **gossip mesh** ([`store::mesh`], API v4) turns the static
//!   peer list into *membership*: deployments exchange
//!   [`MeshHello`](api::MeshHello)s carrying roster gossip and
//!   per-peer acked watermarks, evict peers that miss heartbeats, and
//!   schedule anti-entropy with a deterministic rotating fanout-k
//!   selection over the live roster
//!   ([`MeshDriver`](store::MeshDriver)). The intersection of live
//!   members' acks yields the **acked floor**: the log prefix every
//!   member provably holds is folded into a per-org base snapshot
//!   ([`repo::RuntimeDataRepo::truncate_org_log`]), bounding op-log
//!   memory by the unacked suffix — a peer pulling from below the
//!   floor falls back to whole-org
//!   [`OrgSnapshot`](repo::OrgSnapshot) adoption, and convergence
//!   stays bitwise with truncation active.
//!
//! ## Incremental training: retrain cost scales with the delta
//!
//! The same "what changed" discipline drives training cost. The
//! repository keeps a bounded **delta journal** (slot-level
//! `Set`/`Reordered` events with a monotone `delta_seq`), and each
//! serving shard pairs its repo with a
//! [`FeatureMatrixCache`](repo::FeatureMatrixCache): the raw featurized
//! rows and log-targets, maintained through every mutation choke point
//! (contribute, merge, sync replay, canonical reorder). A steady-state
//! retrain therefore replays O(changed records) instead of
//! re-featurizing the whole corpus — and the standardized matrices the
//! cache hands to [`models::ModelTrainer::train_cached`] are **bitwise
//! identical** to the from-scratch path (property-tested across random
//! contribute/merge/sync/reorder sequences), so cached and uncached
//! retrains produce interchangeable models. When the journal is
//! truncated or the cache has never been primed, it silently rebuilds
//! from scratch; correctness never depends on cache freshness.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the coordination system: simulated cloud
//!   ([`cloud`]), dataflow simulator ([`sim`]), workloads ([`workloads`]),
//!   runtime-data repository ([`repo`], with a monotone **generation
//!   counter** that keys all model caching, plus per-org watermarks and
//!   the convergent merge), durable persistence + federation
//!   ([`store`]), prediction models ([`models`]), cluster configurator
//!   ([`configurator`], which scores every `machine × scaleout`
//!   candidate of a request as **one featurized batch**), search/model
//!   baselines ([`baselines`]), the public protocol ([`api`]), and the
//!   sharded multi-org collaboration runtime ([`coordinator`]).
//! * **L2 (python/compile/model.py)** — JAX graphs for the prediction
//!   models, AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/knn.py)** — Pallas kernel for the
//!   weighted distance matrix at the core of the pessimistic model.
//!
//! The [`runtime`] module loads the HLO artifacts via the PJRT C API and is
//! the only bridge between L3 and L2/L1; Python never runs on the request
//! path. Model execution is backend-agnostic behind
//! [`models::ModelTrainer`]: workers that own a PJRT runtime serve the
//! compiled artifacts, and every other context (including a bare
//! `cargo test` without artifacts) runs the bit-compatible pure-Rust
//! engines in [`models::native`] — trained model state is padded to one
//! fixed layout, so models interchange freely between backends.
//!
//! ## Observability: the server captures runtime data about itself
//!
//! The collaborative premise — systems improve by capturing runtime
//! data about their executions — is applied to the serving stack
//! itself by [`obs`]:
//!
//! * **Span taxonomy** — every service request carries an
//!   [`obs::Trace`] of monotonic [`obs::Stage`] spans: `queue_wait`,
//!   `coalesce_assembly`, `shard_lock_wait`, the retrain split
//!   (`featurize` / `cross_validate` / `winner_fit`), `predict`,
//!   `wal_append`, `fsync`, `reply`, plus a sealed end-to-end `total`.
//!   Finished traces land in per-worker lock-free ring buffers
//!   ([`obs::Ring`], overwrite-oldest, allocation-free on the hot
//!   path) and are drained when a report is requested.
//! * **Bucket scheme** — latency aggregates are log-bucketed
//!   histograms over fixed power-of-2 buckets (bucket `i` holds
//!   `[2^(i-1), 2^i)` nanoseconds; [`obs::hist`]), keyed request kind
//!   × stage in a plain-array [`obs::LatencyMatrix`]. Merging any
//!   partition of samples is bitwise order-independent, and
//!   p50/p95/p99 are exact given the bucketing — which is why the
//!   histogram math lives in the lint's deterministic zone.
//! * **Export formats** — `c3o serve --trace-out FILE` writes Chrome
//!   trace-event JSON (Perfetto / `chrome://tracing`); `c3o serve
//!   --json` gains a `latency` block (per-kind/per-stage percentiles +
//!   the K slowest span breakdowns per kind); `c3o sync --json`
//!   surfaces per-exchange pull/push timings.
//!
//! Tracing is behaviorally inert: all three deployments produce
//! bitwise-identical decisions with tracing enabled or disabled
//! (asserted in `tests/client_suite.rs`), and `benches/serve_throughput`
//! records the overhead of enabling it.
//!
//! ## Threading model
//!
//! Concurrency lives in exactly two places, and neither is allowed to
//! change a single result bit:
//!
//! * **The worker pool** ([`coordinator::service`]) — `N` long-lived
//!   worker threads pull from a two-lane request queue (reads vs
//!   shard-mutating writes, with per-worker lane affinity and
//!   empty-lane stealing). Writes serialize per [`JobKind`] shard
//!   mutex; reads are served lock-free from published immutable
//!   snapshots.
//! * **The compute pool** ([`compute`]) — one shared
//!   [`compute::ComputePool`] of *scoped, per-call* helper threads for
//!   data-parallel model math: retrains fan their `folds ×`
//!   [`models::ModelKind`] cross-validation tasks, and large predict
//!   batches split into row chunks. Every fan uses an **ordered
//!   reduction** — results land in a task-indexed buffer and are folded
//!   in serial task order — so fold MAPEs, winner selection, and
//!   predictions are bitwise-identical to single-threaded execution at
//!   any pool width (property-tested across widths 1/2/8). A global
//!   permit budget keeps concurrent fans from oversubscribing the host;
//!   a fan that gets no permits runs inline, serially, with the same
//!   bits.
//!
//! Lock discipline: the queue mutex and the pool's internal task mutex
//! are leaves (`shard -> pool` is a declared order in the lint's lock
//! table; neither is ever held while serving). The PJRT engine is
//! thread-pinned and never crosses the compute pool — PJRT workers
//! simply train serially, bit-identically.
//!
//! ## Invariant zones & static checks
//!
//! The guarantees above are pinned at the source level by `c3o-lint`
//! (the `rust/lint` workspace member — see its `README.md` for the
//! rule catalogue and suppression grammar). `rust/lint/lint.toml` maps
//! each top-level module into an invariant zone:
//!
//! * **deterministic** ([`repo`], [`models`], [`store`],
//!   [`configurator`], [`obs`], [`compute`]) — anything feeding
//!   converged-peer or cached-vs-scratch bitwise equality, the
//!   histogram math whose folds must be order-independent, and the
//!   compute pool's ordered reductions. No `HashMap`/`HashSet`
//!   (iteration order varies per process), no unannotated float
//!   reductions (summation order changes bits).
//! * **serving** ([`api`], [`coordinator`]) — the request path. No
//!   panics (`unwrap`/`expect`/panic macros/raw indexing): failures
//!   speak the typed [`api::ApiError`] taxonomy, and poisoned locks
//!   recover through [`util::sync`] instead of unwrapping. The same
//!   zone promotes `clippy::unwrap_used` via module attributes.
//! * **boundary** (everything else) — CLI, benches, experiment
//!   drivers; only the signature and suppression rules apply.
//!
//! Across all zones, `pub fn` signatures outside the documented
//! internal-engine modules must not leak `anyhow` (fold errors in via
//! [`api::ApiError::internal`]/[`api::ApiError::store`]), and lock
//! acquisitions are checked against the declared lock order
//! (`shard -> snapshot`, `shard -> store`, `shard -> pool`). CI runs
//! `cargo run -p c3o-lint -- --json`; the `lint_self_clean` test
//! enforces the same gate inside `cargo test`.

// Index-based loops throughout mirror the reference kernels' math and
// keep the padded-layout arithmetic explicit; iterator-chain rewrites
// would obscure the column/row correspondence with the XLA graphs.
#![allow(clippy::needless_range_loop)]
// Debug prints must never reach the request path or the figure
// pipeline; CI denies warnings, so a stray `dbg!` fails the build.
#![warn(clippy::dbg_macro)]

pub mod api;
pub mod baselines;
pub mod cloud;
pub mod compute;
pub mod configurator;
pub mod coordinator;
pub mod figures;
pub mod models;
pub mod obs;
pub mod repo;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod util;
pub mod workloads;

/// Convenience re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::api::{
        ApiError, Client, Contribution, MeshHello, MeshPeer, MeshPeerStatus, MeshView,
        Recommendation, Request, Response, SnapshotInfo, SyncDelta, SyncDeltaV2, SyncReport,
        SyncReportAll, WatermarkSet, WatermarkSetV2, API_VERSION,
    };
    pub use crate::cloud::{Cloud, MachineType};
    pub use crate::configurator::{ClusterChoice, Configurator, JobRequest};
    pub use crate::coordinator::{
        Coordinator, CoordinatorService, JobOutcome, ModelSnapshot, Organization, ServiceClient,
        ServiceConfig, ShardPolicy, SubmitTicket,
    };
    pub use crate::models::{
        ConfigQuery, Engine, ModelKind, ModelTrainer, Predictor, QueryBatch, RuntimeModel,
        TrainedModel,
    };
    pub use crate::repo::{
        FeatureMatrixCache, LoggedOp, MergeConflict, MergeOutcome, OrgSnapshot, OrgWatermark,
        OrgWatermarkV2, RuntimeDataRepo, RuntimeRecord, SyncOp, SyncOutcome, SyncPlan,
    };
    pub use crate::sim::SimulationResult;
    pub use crate::store::{
        mesh_round, JobStore, MeshDriver, MeshRoundReport, MeshState, StoreOp, SyncDetail,
        SyncDriver, SyncOptions, SyncProtocol, SyncScope, SyncStats, SyncSummary,
    };
    pub use crate::util::rng::Pcg32;
    pub use crate::workloads::{ExperimentGrid, JobKind, JobSpec};
}
