//! Differential tests: the PJRT-executed artifacts (L1 Pallas kernel +
//! L2 graphs) must agree with the pure-Rust native re-implementations.
//! This validates the entire AOT bridge — Python lowering, HLO text
//! round-trip, PJRT execution, Rust-side padding/masking — end to end.

use c3o::cloud::Cloud;
use c3o::models::native::{NativeKnn, NativeOptimistic};
use c3o::models::{ConfigQuery, ModelKind, ModelState, Predictor, RuntimeModel};
use c3o::repo::{RuntimeDataRepo, RuntimeRecord};
use c3o::runtime::Runtime;
use c3o::util::rng::Pcg32;
use c3o::workloads::JobKind;

macro_rules! require_artifacts {
    () => {{
        let dir = Runtime::default_dir();
        if !Runtime::artifacts_available(&dir) {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        dir
    }};
}

fn random_repo(kind: JobKind, n: usize, seed: u64) -> RuntimeDataRepo {
    let mut rng = Pcg32::new(seed);
    let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge", "m5.2xlarge"];
    let nf = kind.feature_names().len();
    let recs = (0..n).map(|_| {
        let features: Vec<f64> = (0..nf)
            .map(|i| {
                if i == 0 {
                    rng.range_f64(10.0, 30.0)
                } else {
                    rng.range_f64(0.5, 5.0)
                }
            })
            .collect();
        RuntimeRecord {
            job: kind,
            org: format!("org{}", rng.index(5)),
            machine: machines[rng.index(machines.len())].to_string(),
            scaleout: rng.range_u64(2, 12) as u32,
            job_features: features,
            runtime_s: rng.range_f64(30.0, 3000.0),
        }
    });
    RuntimeDataRepo::from_records(kind, recs)
}

fn random_queries(kind: JobKind, n: usize, seed: u64) -> Vec<ConfigQuery> {
    let mut rng = Pcg32::new(seed);
    let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge", "m5.2xlarge"];
    let nf = kind.feature_names().len();
    (0..n)
        .map(|_| ConfigQuery {
            machine: machines[rng.index(machines.len())].to_string(),
            scaleout: rng.range_u64(2, 12) as u32,
            job_features: (0..nf)
                .map(|i| {
                    if i == 0 {
                        rng.range_f64(10.0, 30.0)
                    } else {
                        rng.range_f64(0.5, 5.0)
                    }
                })
                .collect(),
        })
        .collect()
}

#[test]
fn pjrt_knn_matches_native_knn() {
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut predictor = Predictor::new(&dir).unwrap();
    // several random repos across job kinds and sizes
    for (kind, n, seed) in [
        (JobKind::Sort, 30, 1u64),
        (JobKind::Grep, 120, 2),
        (JobKind::KMeans, 250, 3),
        (JobKind::PageRank, 500, 4),
    ] {
        let repo = random_repo(kind, n, seed);
        let model = predictor.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
        let mut native = NativeKnn::fit(&cloud, &repo, 5).unwrap();
        let queries = random_queries(kind, 100, seed + 100);
        let pjrt = predictor.predict(&model, &cloud, &queries).unwrap();
        let nat = native.predict(&cloud, &queries).unwrap();
        for (i, (a, b)) in pjrt.iter().zip(&nat).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1e-9);
            assert!(
                rel < 5e-3,
                "{kind:?} query {i}: pjrt {a} native {b} (rel {rel})"
            );
        }
    }
}

#[test]
fn pjrt_optimistic_matches_native_forward() {
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut predictor = Predictor::new(&dir).unwrap();
    let repo = random_repo(JobKind::Grep, 150, 9);
    let model = predictor.train(&cloud, &repo, ModelKind::Optimistic).unwrap();
    let ModelState::Opt {
        mins,
        spans,
        y_mean,
        y_sd,
        params,
        ..
    } = &model.state
    else {
        panic!("wrong state")
    };
    let mut native = NativeOptimistic::from_state(
        mins,
        spans,
        *y_mean,
        *y_sd,
        params,
        2 + 6, // grep features + cluster features
    );
    let queries = random_queries(JobKind::Grep, 200, 10);
    let pjrt = predictor.predict(&model, &cloud, &queries).unwrap();
    let nat = native.predict(&cloud, &queries).unwrap();
    for (i, (a, b)) in pjrt.iter().zip(&nat).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-9);
        assert!(rel < 1e-3, "query {i}: pjrt {a} native {b} (rel {rel})");
    }
}

#[test]
fn pjrt_batch_boundaries_are_seamless() {
    // predictions must not depend on where the batch boundary falls
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut predictor = Predictor::new(&dir).unwrap();
    let repo = random_repo(JobKind::Sort, 80, 21);
    let model = predictor.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
    // 150 queries: spans multiple 64-query batches
    let queries = random_queries(JobKind::Sort, 150, 22);
    let all = predictor.predict(&model, &cloud, &queries).unwrap();
    // predict them one at a time
    for (i, q) in queries.iter().enumerate().step_by(17) {
        let single = predictor
            .predict(&model, &cloud, std::slice::from_ref(q))
            .unwrap();
        let rel = (single[0] - all[i]).abs() / all[i].abs().max(1e-9);
        assert!(rel < 1e-5, "query {i}: batched {} single {}", all[i], single[0]);
    }
}

#[test]
fn knn_prediction_in_training_runtime_range() {
    // kNN predictions are convex-ish combinations of training runtimes:
    // they must stay within the observed range
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut predictor = Predictor::new(&dir).unwrap();
    let repo = random_repo(JobKind::Sgd, 200, 31);
    let (lo, hi) = repo.records().iter().fold((f64::MAX, 0.0f64), |(l, h), r| {
        (l.min(r.runtime_s), h.max(r.runtime_s))
    });
    let model = predictor.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
    let queries = random_queries(JobKind::Sgd, 200, 32);
    let preds = predictor.predict(&model, &cloud, &queries).unwrap();
    for p in preds {
        assert!(
            p >= lo * 0.95 && p <= hi * 1.05,
            "prediction {p} outside training range [{lo}, {hi}]"
        );
    }
}
