//! Minimal CSV reading and writing (RFC-4180 quoting subset) for the
//! runtime-data repository and the figure exports.
//!
//! Supports quoted fields containing commas/newlines/escaped quotes, which
//! is all the repository schema needs; no serde in the vendor set.

use std::fmt;
use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A parsed CSV table: a header row plus data rows.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Create an empty table with the given header.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Index of a column by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Push a row of stringified fields; panics if the width mismatches.
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Serialize to CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())
    }

    /// Parse CSV text (first row is the header).
    pub fn parse(text: &str) -> Result<Table, CsvError> {
        let mut rows = parse_rows(text)?;
        if rows.is_empty() {
            return Ok(Table::default());
        }
        let header = rows.remove(0);
        for (i, row) in rows.iter().enumerate() {
            if row.len() != header.len() {
                return Err(CsvError::RaggedRow {
                    row: i + 2,
                    got: row.len(),
                    want: header.len(),
                });
            }
        }
        Ok(Table { header, rows })
    }

    /// Load a table from a file.
    pub fn load(path: &Path) -> Result<Table, CsvError> {
        let text = fs::read_to_string(path).map_err(|e| CsvError::Io(e.to_string()))?;
        Table::parse(&text)
    }
}

/// Render a single CSV row (quoting as needed) **without** a trailing
/// newline — the segment store's line-framed WAL needs exactly one row
/// per physical line. Fields containing a newline would break that
/// framing; the store rejects them before rendering.
pub fn render_line(fields: &[String]) -> String {
    let mut out = String::new();
    write_row(&mut out, fields);
    out.pop(); // drop the '\n' write_row appends
    out
}

/// Parse a single CSV line into its fields (the inverse of
/// [`render_line`]).
pub fn parse_line(line: &str) -> Result<Vec<String>, CsvError> {
    let mut rows = parse_rows(line)?;
    match rows.len() {
        0 => Ok(Vec::new()),
        1 => Ok(rows.remove(0)),
        n => Err(CsvError::Io(format!("expected one CSV row, got {n}"))),
    }
}

/// CSV parse errors. (Display/Error are hand-implemented — `thiserror`
/// is not in the offline vendor set.)
#[derive(Debug, PartialEq)]
pub enum CsvError {
    RaggedRow { row: usize, got: usize, want: usize },
    UnterminatedQuote { at: usize },
    Io(String),
}

impl fmt::Display for CsvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CsvError::RaggedRow { row, got, want } => {
                write!(f, "row {row}: has {got} fields, header has {want}")
            }
            CsvError::UnterminatedQuote { at } => {
                write!(f, "unterminated quoted field starting near byte {at}")
            }
            CsvError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for CsvError {}

fn needs_quoting(field: &str) -> bool {
    field.contains(',') || field.contains('"') || field.contains('\n') || field.contains('\r')
}

fn write_row(out: &mut String, fields: &[String]) {
    for (i, field) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(field) {
            out.push('"');
            for ch in field.chars() {
                if ch == '"' {
                    out.push('"');
                }
                out.push(ch);
            }
            out.push('"');
        } else {
            let _ = write!(out, "{field}");
        }
    }
    out.push('\n');
}

fn parse_rows(text: &str) -> Result<Vec<Vec<String>>, CsvError> {
    let bytes = text.as_bytes();
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut i = 0usize;
    let mut in_field = false; // have we consumed any content for the current row?

    while i < bytes.len() {
        let c = bytes[i];
        match c {
            b'"' => {
                // quoted field
                let start = i;
                i += 1;
                loop {
                    if i >= bytes.len() {
                        return Err(CsvError::UnterminatedQuote { at: start });
                    }
                    if bytes[i] == b'"' {
                        if i + 1 < bytes.len() && bytes[i + 1] == b'"' {
                            field.push('"');
                            i += 2;
                        } else {
                            i += 1;
                            break;
                        }
                    } else {
                        // multi-byte safe: push raw char
                        let ch_start = i;
                        let ch_len = utf8_len(bytes[i]);
                        field.push_str(std::str::from_utf8(&bytes[ch_start..ch_start + ch_len]).unwrap());
                        i += ch_len;
                    }
                }
                in_field = true;
            }
            b',' => {
                row.push(std::mem::take(&mut field));
                in_field = true;
                i += 1;
            }
            b'\r' => {
                i += 1; // swallow; \n handles row end
            }
            b'\n' => {
                if in_field || !field.is_empty() {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                in_field = false;
                i += 1;
            }
            _ => {
                let ch_len = utf8_len(c);
                field.push_str(std::str::from_utf8(&bytes[i..i + ch_len]).unwrap());
                i += ch_len;
                in_field = true;
            }
        }
    }
    if in_field || !field.is_empty() {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

#[inline]
fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_simple() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        t.push(vec!["x".into(), "y".into()]);
        let parsed = Table::parse(&t.to_csv()).unwrap();
        assert_eq!(parsed.header, t.header);
        assert_eq!(parsed.rows, t.rows);
    }

    #[test]
    fn round_trip_quoting() {
        let mut t = Table::new(&["name", "note"]);
        t.push(vec!["a,b".into(), "say \"hi\"".into()]);
        t.push(vec!["multi\nline".into(), "".into()]);
        let parsed = Table::parse(&t.to_csv()).unwrap();
        assert_eq!(parsed.rows, t.rows);
    }

    #[test]
    fn ragged_row_rejected() {
        let err = Table::parse("a,b\n1,2,3\n").unwrap_err();
        assert!(matches!(err, CsvError::RaggedRow { .. }));
    }

    #[test]
    fn unterminated_quote_rejected() {
        let err = Table::parse("a\n\"oops\n").unwrap_err();
        assert!(matches!(err, CsvError::UnterminatedQuote { .. }));
    }

    #[test]
    fn crlf_handled() {
        let t = Table::parse("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(t.rows, vec![vec!["1".to_string(), "2".to_string()]]);
    }

    #[test]
    fn empty_trailing_field() {
        let t = Table::parse("a,b\n1,\n").unwrap();
        assert_eq!(t.rows[0], vec!["1".to_string(), "".to_string()]);
    }

    #[test]
    fn unicode_fields() {
        let mut t = Table::new(&["x"]);
        t.push(vec!["héllo → wörld".into()]);
        let parsed = Table::parse(&t.to_csv()).unwrap();
        assert_eq!(parsed.rows, t.rows);
    }

    #[test]
    fn line_round_trip() {
        let fields = vec!["12".to_string(), "a,b".to_string(), "say \"hi\"".to_string()];
        let line = render_line(&fields);
        assert!(!line.contains('\n'));
        assert_eq!(parse_line(&line).unwrap(), fields);
        assert_eq!(parse_line("").unwrap(), Vec::<String>::new());
    }

    #[test]
    fn col_lookup() {
        let t = Table::new(&["job", "runtime"]);
        assert_eq!(t.col("runtime"), Some(1));
        assert_eq!(t.col("nope"), None);
    }
}
