//! Threaded coordinator session: the **legacy single-worker** deployment
//! shape, kept as the baseline the sharded [`super::service`] is
//! benchmarked against (`benches/serve_throughput.rs`).
//!
//! One dedicated worker thread owns a whole [`Coordinator`] (and its
//! model engine — the PJRT client is not `Send`); clients talk to it
//! through a strictly-ordered request/reply channel pair. That ordering
//! is the shape's scalability ceiling: every client's reply waits behind
//! every earlier request, across *all* job kinds. The service replaces
//! this with per-kind shards and per-request reply channels.

use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::coordinator::{Coordinator, JobOutcome, Metrics, Organization};
use crate::repo::RuntimeDataRepo;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Requests accepted by the session worker.
pub enum Event {
    /// Merge shared runtime data into the coordinator's repositories.
    Share(RuntimeDataRepo),
    /// Submit a job for an organization.
    Submit(Organization, JobRequest),
    /// Snapshot the metrics.
    GetMetrics,
    /// Stop the worker.
    Shutdown,
}

/// Replies from the worker (one per event, in order).
pub enum Reply {
    Shared(Result<usize>),
    Submitted(Box<Result<JobOutcome>>),
    Metrics(Metrics),
    ShuttingDown,
}

/// Handle to a running session.
pub struct Session {
    tx: mpsc::Sender<Event>,
    rx: mpsc::Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl Session {
    /// Spawn the worker thread. It constructs the coordinator (and the
    /// PJRT client) on its own thread; construction errors surface on the
    /// first request.
    pub fn spawn(cloud: Cloud, artifacts_dir: PathBuf, seed: u64) -> Session {
        let (tx, worker_rx) = mpsc::channel::<Event>();
        let (worker_tx, rx) = mpsc::channel::<Reply>();
        let handle = std::thread::spawn(move || {
            // Construction is infallible: `Engine::auto` falls back to the
            // native model engines when PJRT artifacts are absent or
            // unloadable, so there is no error path to serve here.
            let mut coord = Coordinator::new(cloud, &artifacts_dir, seed)
                .expect("coordinator construction is infallible (native fallback)");
            while let Ok(event) = worker_rx.recv() {
                match event {
                    Event::Share(repo) => {
                        let _ = worker_tx.send(Reply::Shared(coord.share(&repo)));
                    }
                    Event::Submit(org, request) => {
                        let _ = worker_tx
                            .send(Reply::Submitted(Box::new(coord.submit(&org, &request))));
                    }
                    Event::GetMetrics => {
                        let _ = worker_tx.send(Reply::Metrics(coord.metrics().clone()));
                    }
                    Event::Shutdown => {
                        let _ = worker_tx.send(Reply::ShuttingDown);
                        break;
                    }
                }
            }
        });
        Session {
            tx,
            rx,
            handle: Some(handle),
        }
    }

    /// Share runtime data; blocks for the worker's reply.
    pub fn share(&self, repo: RuntimeDataRepo) -> Result<usize> {
        self.tx
            .send(Event::Share(repo))
            .map_err(|_| anyhow!("session worker gone"))?;
        match self.rx.recv() {
            Ok(Reply::Shared(r)) => r,
            _ => Err(anyhow!("unexpected session reply")),
        }
    }

    /// Submit a job; blocks for the outcome.
    pub fn submit(&self, org: &Organization, request: JobRequest) -> Result<JobOutcome> {
        self.tx
            .send(Event::Submit(org.clone(), request))
            .map_err(|_| anyhow!("session worker gone"))?;
        match self.rx.recv() {
            Ok(Reply::Submitted(r)) => *r,
            _ => Err(anyhow!("unexpected session reply")),
        }
    }

    /// Fetch a metrics snapshot.
    pub fn metrics(&self) -> Result<Metrics> {
        self.tx
            .send(Event::GetMetrics)
            .map_err(|_| anyhow!("session worker gone"))?;
        match self.rx.recv() {
            Ok(Reply::Metrics(m)) => Ok(m),
            _ => Err(anyhow!("unexpected session reply")),
        }
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(handle) = self.handle.take() {
            let _ = self.tx.send(Event::Shutdown);
            // drain until the worker acknowledges or hangs up
            loop {
                match self.rx.recv() {
                    Ok(Reply::ShuttingDown) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
            let _ = handle.join();
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::workloads::{ExperimentGrid, JobKind};

    #[test]
    fn session_round_trip() {
        // Runs with or without PJRT artifacts: the coordinator falls
        // back to the native model engines when they are absent.
        let dir = Runtime::default_dir();
        let cloud = Cloud::aws_like();
        // share a corpus slice, then submit through the thread boundary
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 1,
        };
        let repo = grid.execute(&cloud, 5).repo_for(JobKind::Sort);

        let session = Session::spawn(cloud, dir, 9);
        let added = session.share(repo).unwrap();
        assert_eq!(added, 126);
        let org = Organization::new("threaded-org");
        let outcome = session
            .submit(&org, JobRequest::sort(15.0).with_target_seconds(1000.0))
            .unwrap();
        assert!(outcome.model_used.is_some());
        let metrics = session.metrics().unwrap();
        assert_eq!(metrics.submissions, 1);
        session.shutdown();
    }

    #[test]
    fn session_falls_back_to_native_without_artifacts() {
        // A missing artifacts directory is not fatal: the coordinator
        // serves the full loop on the native model engines.
        let cloud = Cloud::aws_like();
        let session = Session::spawn(cloud, PathBuf::from("/nonexistent/artifacts"), 1);
        let org = Organization::new("o");
        let outcome = session.submit(&org, JobRequest::sort(10.0)).unwrap();
        assert!(outcome.model_used.is_none(), "cold start overprovisions");
        assert!(outcome.actual_runtime_s > 0.0);
        let metrics = session.metrics().unwrap();
        assert_eq!(metrics.submissions, 1);
        assert_eq!(metrics.fallbacks, 1);
        session.shutdown();
    }
}
