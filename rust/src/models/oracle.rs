//! Simulator-backed ground-truth "model".
//!
//! [`SimOracle`] answers [`ConfigQuery`]s by actually running the dataflow
//! simulator (median of repetitions). It is *not* available to the
//! configurator in any honest experiment — it exists to
//!
//! * compute **regret** in the benches (how far is the chosen
//!   configuration from the true optimum), and
//! * serve profiling runs for the iterative-search baselines
//!   (CherryPick/Ernest *do* get to execute candidate configurations;
//!   that's exactly their cost).

use crate::cloud::Cloud;
use crate::models::{ConfigQuery, RuntimeModel};
use crate::sim::{SimConfig, Simulator};
use crate::util::rng::Pcg32;
use crate::util::stats::median;
use crate::workloads::{JobKind, JobSpec};
use anyhow::{anyhow, Result};

/// Ground truth via simulation.
#[derive(Debug, Clone)]
pub struct SimOracle {
    pub job: JobKind,
    pub sim: Simulator,
    pub repetitions: u32,
    pub seed: u64,
    /// Count of simulated runs served (profiling-cost accounting for the
    /// search baselines).
    pub runs_served: u64,
    /// Total simulated seconds served (the wall-clock a profiling-based
    /// approach would have burned).
    pub seconds_served: f64,
}

impl SimOracle {
    pub fn new(job: JobKind, seed: u64) -> Self {
        SimOracle {
            job,
            sim: Simulator::new(SimConfig::default()),
            repetitions: 5,
            seed,
            runs_served: 0,
            seconds_served: 0.0,
        }
    }

    /// Noise-free oracle (for exact-optimum computation in benches).
    pub fn deterministic(job: JobKind, seed: u64) -> Self {
        SimOracle {
            sim: Simulator::new(SimConfig::deterministic()),
            repetitions: 1,
            ..SimOracle::new(job, seed)
        }
    }

    /// Reconstruct the [`JobSpec`] from a feature vector (the inverse of
    /// `JobSpec::job_features`).
    pub fn spec_from_features(job: JobKind, f: &[f64]) -> Result<JobSpec> {
        let need = job.feature_names().len();
        if f.len() != need {
            return Err(anyhow!(
                "{}: {} features given, {need} expected",
                job.name(),
                f.len()
            ));
        }
        Ok(match job {
            JobKind::Sort => JobSpec::sort(f[0]),
            JobKind::Grep => JobSpec::grep(f[0], f[1]),
            JobKind::Sgd => JobSpec::sgd(f[0], f[1].round() as u32),
            // convergence features are stored as -log10(conv)
            JobKind::KMeans => {
                JobSpec::kmeans(f[0], f[1].round() as u32, 10f64.powf(-f[2]))
            }
            JobKind::PageRank => JobSpec::pagerank(f[0], 10f64.powf(-f[1])),
        })
    }

    /// True (median) runtime of one configuration.
    pub fn run_once(&mut self, cloud: &Cloud, q: &ConfigQuery) -> Result<f64> {
        let spec = Self::spec_from_features(self.job, &q.job_features)?;
        let machine = cloud
            .machine(&q.machine)
            .ok_or_else(|| anyhow!("unknown machine {}", q.machine))?;
        let stages = spec.stages();
        let mut runs = Vec::with_capacity(self.repetitions as usize);
        for rep in 0..self.repetitions {
            let mut rng = Pcg32::new_stream(
                self.seed ^ (self.runs_served.wrapping_mul(0x9E3779B97F4A7C15)),
                ((q.scaleout as u64) << 32) | rep as u64 | 1,
            );
            runs.push(self.sim.run_runtime_only(machine, q.scaleout, &stages, &mut rng));
        }
        let t = median(&runs);
        self.runs_served += self.repetitions as u64;
        // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
        self.seconds_served += runs.iter().sum::<f64>();
        Ok(t)
    }
}

impl RuntimeModel for SimOracle {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        queries.iter().map(|q| self.run_once(cloud, q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trip_all_jobs() {
        let specs = [
            JobSpec::sort(12.0),
            JobSpec::grep(15.0, 0.1),
            JobSpec::sgd(30.0, 75),
            JobSpec::kmeans(15.0, 7, 0.001),
            JobSpec::pagerank(330.0, 0.0001),
        ];
        for spec in specs {
            let f = spec.job_features();
            let back = SimOracle::spec_from_features(spec.kind(), &f).unwrap();
            // round-trip through features must preserve the spec (floats
            // may wobble at 1e-12 for the convergence log transform)
            match (&spec, &back) {
                (
                    JobSpec::KMeans { convergence: a, .. },
                    JobSpec::KMeans { convergence: b, .. },
                ) => assert!((a - b).abs() / a < 1e-9),
                (
                    JobSpec::PageRank { convergence: a, .. },
                    JobSpec::PageRank { convergence: b, .. },
                ) => assert!((a - b).abs() / a < 1e-9),
                _ => assert_eq!(spec, back),
            }
        }
    }

    #[test]
    fn wrong_arity_rejected() {
        assert!(SimOracle::spec_from_features(JobKind::Grep, &[1.0]).is_err());
    }

    #[test]
    fn oracle_counts_profiling_cost() {
        let cloud = Cloud::aws_like();
        let mut o = SimOracle::new(JobKind::Sort, 1);
        let q = ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![15.0],
        };
        let t = o.run_once(&cloud, &q).unwrap();
        assert!(t > 0.0);
        assert_eq!(o.runs_served, 5);
        assert!(o.seconds_served > t);
    }

    #[test]
    fn deterministic_oracle_is_stable() {
        let cloud = Cloud::aws_like();
        let q = ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 6,
            job_features: vec![15.0],
        };
        let mut a = SimOracle::deterministic(JobKind::Sort, 7);
        let mut b = SimOracle::deterministic(JobKind::Sort, 7);
        assert_eq!(a.run_once(&cloud, &q).unwrap(), b.run_once(&cloud, &q).unwrap());
    }
}
