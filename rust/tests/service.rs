//! Integration tests of the sharded multi-worker coordinator service:
//! concurrent clients across shards, per-request reply integrity,
//! generation-gated retraining, and the read/write split (lock-free
//! `Recommend` serving, pipelined tickets, coalesced read batches). All
//! of these run without PJRT artifacts (native model engines).

use c3o::api::ApiError;
use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::{CoordinatorService, Organization, ServiceConfig, ShardPolicy};
use c3o::workloads::{Corpus, ExperimentGrid, JobKind};
use std::time::Duration;

const KINDS: [JobKind; 4] = [JobKind::Sort, JobKind::Grep, JobKind::Sgd, JobKind::KMeans];

fn corpus(cloud: &Cloud, seed: u64) -> Corpus {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| KINDS.contains(&e.spec.kind()))
            .collect(),
        repetitions: 1,
    }
    .execute(cloud, seed)
}

fn request_for(kind: JobKind, salt: usize) -> JobRequest {
    let gb = 10.0 + (salt % 10) as f64;
    match kind {
        JobKind::Sort => JobRequest::sort(gb),
        JobKind::Grep => JobRequest::grep(gb, 0.1),
        JobKind::Sgd => JobRequest::sgd(gb, 60),
        JobKind::KMeans => JobRequest::kmeans(gb, 5, 0.001),
        JobKind::PageRank => JobRequest::pagerank(25.0 * gb, 0.001),
    }
}

#[test]
fn eight_concurrent_clients_across_four_shards() {
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 5);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(4).with_seed(17),
    );
    let mut seeded: u64 = 0;
    for kind in KINDS {
        let shared = service.share(corpus.repo_for(kind)).unwrap();
        assert!(shared.added > 0, "{kind:?} corpus must contribute records");
        seeded += shared.added as u64;
    }

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 3;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let client = service.client();
            handles.push(scope.spawn(move || {
                let org = Organization::new(&format!("client-{c}"));
                let mut outcomes = Vec::new();
                for j in 0..PER_CLIENT {
                    // interleave kinds so shards serve concurrently
                    let kind = KINDS[(c + j) % KINDS.len()];
                    let req = request_for(kind, c * PER_CLIENT + j).with_target_seconds(5000.0);
                    outcomes.push((kind, client.submit(&org, req).unwrap()));
                }
                (c, outcomes)
            }));
        }
        for handle in handles {
            let (c, outcomes) = handle.join().unwrap();
            for (j, (expected_kind, outcome)) in outcomes.into_iter().enumerate() {
                // per-request reply channels: every client gets exactly
                // its own job back, regardless of interleaving
                assert_eq!(
                    outcome.job, expected_kind,
                    "client {c} job {j} got a reply for the wrong request"
                );
                assert_eq!(outcome.org, format!("client-{c}"));
                assert!(
                    outcome.model_used.is_some(),
                    "client {c} job {j} should be model-served from the corpus"
                );
                assert!(outcome.actual_runtime_s > 0.0);
            }
        }
    });

    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.submissions, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(metrics.targets_given, (CLIENTS * PER_CLIENT) as u64);
    assert_eq!(metrics.fallbacks, 0, "all shards were seeded");
    assert!(metrics.retrains >= KINDS.len() as u64, "each shard trained at share");
    assert!(metrics.mean_prediction_error_pct().is_finite());

    // every submission contributed its run back to its shard: the summed
    // shard generations advanced by exactly seeded records + submissions
    let contributed: u64 = KINDS.iter().map(|&k| service.generation(k)).sum();
    assert_eq!(contributed, seeded + (CLIENTS * PER_CLIENT) as u64);
    service.shutdown();
}

#[test]
fn service_retraining_is_gated_by_generation() {
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 9);
    let policy = ShardPolicy {
        retrain_every: 1_000, // far beyond this test's contributions
        ..ShardPolicy::default()
    };
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default()
            .with_workers(2)
            .with_seed(23)
            .with_policy(policy),
    );
    // sharing is the write that trains; the trained generation is recorded
    service.share(corpus.repo_for(JobKind::Sort)).unwrap();
    assert_eq!(service.metrics().unwrap().retrains, 1);
    let trained_at = service.trained_at_generation(JobKind::Sort).unwrap();
    let org = Organization::new("steady");

    // re-sharing the identical corpus adds nothing: generation frozen
    let gen_before = service.generation(JobKind::Sort);
    assert_eq!(
        service.share(corpus.repo_for(JobKind::Sort)).unwrap().added,
        0
    );
    assert_eq!(service.generation(JobKind::Sort), gen_before);

    // repeated submissions with no new shared data past the threshold:
    // zero further retrains, asserted via Metrics (the acceptance
    // criterion) — every decision is a cache hit
    for i in 0..7 {
        let outcome = service
            .submit(&org, request_for(JobKind::Sort, i))
            .unwrap();
        assert!(outcome.model_used.is_some());
    }
    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.retrains, 1, "generation gate failed: {metrics:?}");
    assert_eq!(metrics.cache_hits, 7);
    assert_eq!(
        service.trained_at_generation(JobKind::Sort).unwrap(),
        trained_at,
        "cached model must still be the original training"
    );
    service.shutdown();
}

#[test]
fn shares_and_submits_interleave_across_clients() {
    // One client streams shares while another streams submissions of a
    // different kind: neither blocks the other's replies (the ordered
    // session could interleave these only in lockstep).
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 13);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(2).with_seed(31),
    );
    service.share(corpus.repo_for(JobKind::Grep)).unwrap();
    let sort_added = service.share(corpus.repo_for(JobKind::Sort)).unwrap().added as u64;

    std::thread::scope(|scope| {
        let sharer = service.client();
        let submitter = service.client();
        let sort_repo = corpus.repo_for(JobKind::Sort);
        scope.spawn(move || {
            // idempotent re-shares: valid traffic that changes nothing
            for _ in 0..5 {
                assert_eq!(sharer.share(sort_repo.clone()).unwrap().added, 0);
            }
        });
        scope.spawn(move || {
            let org = Organization::new("interleaved");
            for i in 0..4 {
                let o = submitter
                    .submit(&org, request_for(JobKind::Grep, i))
                    .unwrap();
                assert_eq!(o.job, JobKind::Grep);
            }
        });
    });

    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.submissions, 4);
    // the five redundant re-shares moved the sort generation not at all
    assert_eq!(service.generation(JobKind::Sort), sort_added);
    service.shutdown();
}

#[test]
fn recommend_completes_while_a_writer_holds_the_shard_lock() {
    // THE read/write-split acceptance test: grab the Sort shard's write
    // mutex (as a long submit/retrain would), then prove that
    //  * a same-kind `Recommend` still completes (served from the
    //    published snapshot, no shard lock), while
    //  * a same-kind `Submit` blocks until the lock is released.
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 19);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(2).with_seed(37),
    );
    service.share(corpus.repo_for(JobKind::Sort)).unwrap();

    let guard = service.hold_shard_for_tests(JobKind::Sort);

    // a write must block behind the held lock: dispatch it first so one
    // worker is provably stuck in the write path...
    let blocked = service
        .client()
        .submit_nowait(&Organization::new("w"), request_for(JobKind::Sort, 0))
        .unwrap();

    // ...while the read completes on the other worker
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let reader = service.client();
    let read_thread = std::thread::spawn(move || {
        let rec = reader.recommend(request_for(JobKind::Sort, 1));
        let _ = done_tx.send(rec);
    });
    let rec = done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("Recommend must complete while the shard write lock is held")
        .expect("recommendation served from the snapshot");
    assert!(rec.choice.predicted_runtime_s > 0.0);
    read_thread.join().unwrap();

    // the write is still pending (poll without blocking)
    let mut blocked = blocked;
    assert!(
        !blocked.is_ready(),
        "a same-kind write must wait for the shard lock"
    );

    // release the lock: the blocked write now completes normally
    drop(guard);
    let outcome = blocked.wait().unwrap();
    assert_eq!(outcome.job, JobKind::Sort);
    assert!(outcome.model_used.is_some());
    service.shutdown();
}

#[test]
fn pipelined_tickets_resolve_to_their_own_outcomes() {
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 29);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(4).with_seed(41),
    );
    for kind in KINDS {
        service.share(corpus.repo_for(kind)).unwrap();
    }
    let client = service.client();
    let org = Organization::new("pipeliner");
    // dispatch a burst across all kinds without waiting...
    let tickets: Vec<_> = (0..8)
        .map(|i| {
            let kind = KINDS[i % KINDS.len()];
            (
                kind,
                client.submit_nowait(&org, request_for(kind, i)).unwrap(),
            )
        })
        .collect();
    // ...then collect; every ticket resolves to its own request's kind
    for (kind, ticket) in tickets {
        let outcome = ticket.wait().unwrap();
        assert_eq!(outcome.job, kind);
        assert_eq!(outcome.org, "pipeliner");
        assert!(outcome.model_used.is_some());
    }
    assert_eq!(service.metrics().unwrap().submissions, 8);
    service.shutdown();
}

#[test]
fn concurrent_reads_coalesce_and_match_sequential_decisions() {
    // Fire a burst of same-kind recommends from many threads while the
    // workers drain a deliberately small pool, so the queue backs up and
    // coalescing kicks in; every reply must carry that request's own
    // decision (same as served sequentially).
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 43);
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(1).with_seed(47),
    );
    service.share(corpus.repo_for(JobKind::Sort)).unwrap();

    // sequential ground truth
    let expected: Vec<u64> = (0..12)
        .map(|i| {
            service
                .recommend(request_for(JobKind::Sort, i))
                .unwrap()
                .choice
                .predicted_runtime_s
                .to_bits()
        })
        .collect();

    // concurrent burst
    let actual: Vec<(usize, u64)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..12 {
            let client = service.client();
            handles.push(scope.spawn(move || {
                let rec = client.recommend(request_for(JobKind::Sort, i)).unwrap();
                (i, rec.choice.predicted_runtime_s.to_bits())
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, bits) in actual {
        assert_eq!(
            bits, expected[i],
            "request {i} got a different decision under coalescing"
        );
    }
    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.recommends, 24, "12 sequential + 12 concurrent");
    service.shutdown();
}

#[test]
fn coalesced_writes_decide_bitwise_identically_to_sequential() {
    // THE write-coalescing acceptance test. Two identically-seeded
    // single-worker services serve the same submit stream: one strictly
    // sequentially (each submit blocks, so every group has one member),
    // one with the whole stream pipelined while the shard lock is held —
    // so the queue backs up and the worker drains the submits into a
    // pre-scored group. Every outcome must match bitwise: the decision
    // (pre-scored as one batch) and the simulated run (same shard RNG
    // stream — pre-deciding must consume no randomness).
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 43);
    let org = Organization::new("writer");
    const SUBMITS: usize = 8;
    let policy = ShardPolicy {
        retrain_every: 4, // force mid-stream retrains: a retrain inside
        // the coalesced group must invalidate the rest of the group's
        // pre-scored decisions (they re-decide against the new model,
        // exactly as sequential serving would)
        ..ShardPolicy::default()
    };
    let config = || {
        ServiceConfig::default()
            .with_workers(1)
            .with_seed(59)
            .with_policy(policy.clone())
            // native engines on both services: its kNN capacity (512)
            // covers the sort corpus, so retrains take the cached path
            .with_pjrt_workers(0)
    };

    // sequential ground truth
    let seq = CoordinatorService::spawn(cloud.clone(), config());
    seq.share(corpus.repo_for(JobKind::Sort)).unwrap();
    let expected: Vec<_> = (0..SUBMITS)
        .map(|i| {
            let o = seq.submit(&org, request_for(JobKind::Sort, i)).unwrap();
            assert!(o.model_used.is_some(), "submit {i} must be model-served");
            (
                o.machine.clone(),
                o.scaleout,
                o.predicted_runtime_s.to_bits(),
                o.actual_runtime_s.to_bits(),
            )
        })
        .collect();
    seq.shutdown();

    // coalesced replay: hold the shard lock, pipeline the whole stream,
    // then release — the single worker drains the queued submits into a
    // same-kind group and pre-scores them as one batch
    let coal = CoordinatorService::spawn(cloud, config());
    coal.share(corpus.repo_for(JobKind::Sort)).unwrap();
    let guard = coal.hold_shard_for_tests(JobKind::Sort);
    let client = coal.client();
    let tickets: Vec<_> = (0..SUBMITS)
        .map(|i| client.submit_nowait(&org, request_for(JobKind::Sort, i)).unwrap())
        .collect();
    drop(guard);
    for (i, ticket) in tickets.into_iter().enumerate() {
        let o = ticket.wait().unwrap();
        let actual = (
            o.machine.clone(),
            o.scaleout,
            o.predicted_runtime_s.to_bits(),
            o.actual_runtime_s.to_bits(),
        );
        assert_eq!(
            actual, expected[i],
            "submit {i} diverged under write coalescing"
        );
    }
    let metrics = coal.metrics().unwrap();
    assert_eq!(metrics.submissions, SUBMITS as u64);
    assert!(
        metrics.coalesced_write_batches >= 1,
        "the pipelined stream must have been pre-scored as a group: {metrics:?}"
    );
    assert!(
        metrics.featurized_rows_reused > 0,
        "mid-stream retrains must reuse cached feature rows: {metrics:?}"
    );
    coal.shutdown();
}

#[test]
fn affinity_routed_workers_serve_a_retrain_heavy_mixed_workload() {
    // Request-class affinity: read-class workers drain the read lane
    // first and steal write work only when no reads are queued. Under a
    // retrain-heavy 50:50 read/write mix the service must stay fully
    // correct (every reply matches its own request) and the steal
    // counters must stay within the number of served requests.
    let cloud = Cloud::aws_like();
    let corpus = corpus(&cloud, 61);
    let policy = ShardPolicy {
        retrain_every: 2, // retrain-heavy: every other write retrains
        ..ShardPolicy::default()
    };
    let service = CoordinatorService::spawn(
        cloud.clone(),
        ServiceConfig::default()
            .with_workers(4)
            .with_pjrt_workers(0)
            .with_seed(61)
            .with_policy(policy),
    );
    service.share(corpus.repo_for(JobKind::Sort)).unwrap();
    service.share(corpus.repo_for(JobKind::Grep)).unwrap();

    const CLIENTS: usize = 6;
    const OPS: usize = 4;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let client = service.client();
            handles.push(scope.spawn(move || {
                let org = Organization::new(&format!("mixed-{c}"));
                for j in 0..OPS {
                    let kind = if (c + j) % 2 == 0 {
                        JobKind::Sort
                    } else {
                        JobKind::Grep
                    };
                    if j % 2 == 0 {
                        let o = client
                            .submit(&org, request_for(kind, c * OPS + j))
                            .unwrap();
                        assert_eq!(o.job, kind, "client {c} op {j}: wrong reply");
                        assert!(o.model_used.is_some());
                    } else {
                        let r = client
                            .recommend(request_for(kind, c * OPS + j))
                            .unwrap();
                        assert!(r.choice.predicted_runtime_s > 0.0);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    let metrics = service.metrics().unwrap();
    assert_eq!(metrics.submissions, (CLIENTS * OPS / 2) as u64);
    assert_eq!(metrics.recommends, (CLIENTS * OPS / 2) as u64);
    assert!(
        metrics.retrains >= 4,
        "retrain-heavy policy must retrain repeatedly: {metrics:?}"
    );
    let (reads_stolen, writes_stolen) = service.queue_steals();
    // 2 shares + the ops + the metrics read is everything ever queued
    let ceiling = (CLIENTS * OPS) as u64 + 3;
    assert!(
        reads_stolen + writes_stolen <= ceiling,
        "steals ({reads_stolen}, {writes_stolen}) must account only for queued requests"
    );
    service.shutdown();

    // Deterministic cross-lane steal: a single-worker deployment has
    // only the read-class worker 0, so write requests can be served
    // only by stealing them from the write lane.
    let lone = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default()
            .with_workers(1)
            .with_pjrt_workers(0)
            .with_seed(67),
    );
    lone.share(corpus.repo_for(JobKind::Sort)).unwrap();
    let o = lone
        .submit(&Organization::new("stolen"), request_for(JobKind::Sort, 0))
        .unwrap();
    assert!(o.model_used.is_some());
    let (_, lone_writes_stolen) = lone.queue_steals();
    assert!(
        lone_writes_stolen >= 2,
        "a single read-class worker serves share + submit only by stealing"
    );
    lone.shutdown();
}

#[test]
fn cold_recommend_errors_while_cold_submit_falls_back() {
    // The API's asymmetry: a cold `Submit` has the overprovisioning
    // fallback, a cold `Recommend` is a typed `ColdStart` error.
    let cloud = Cloud::aws_like();
    let service = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default().with_workers(1).with_seed(53),
    );
    let err = service.recommend(request_for(JobKind::Grep, 0)).unwrap_err();
    assert!(matches!(
        err,
        ApiError::ColdStart {
            job: JobKind::Grep,
            ..
        }
    ));
    let outcome = service
        .submit(&Organization::new("cold"), request_for(JobKind::Grep, 0))
        .unwrap();
    assert!(outcome.model_used.is_none());
    service.shutdown();
}
