//! Featurization: turn shared [`RuntimeRecord`]s into model-ready
//! matrices.
//!
//! The paper (§IV) lists the runtime-influencing factors a black-box model
//! must see: the machine type and scale-out of the cluster, key dataset
//! characteristics, and algorithm parameters. Machine types are encoded
//! by their *descriptors* (vCPUs, memory, relative core speed, disk and
//! network bandwidth) rather than one-hot names, so a model trained on
//! collaboratively shared data can generalize to machine types that no
//! contributor has measured — the heterogeneous-context requirement of §V.
//!
//! All features and the target are standardized; runtimes are modeled in
//! log space (multiplicative errors, matching MAPE evaluation).
//!
//! Featurization is the per-retrain cost that scales with the corpus:
//! every raw row resolves a machine descriptor against the catalog and
//! converts features, per record, per fit. [`FeatureMatrixCache`]
//! removes that cost from the steady state — it mirrors the raw rows
//! and targets incrementally by replaying the repo's bounded
//! [`RepoDelta`](crate::repo::RepoDelta) journal, so a fit after `k`
//! new contributions refeaturizes `k` rows, not the whole corpus. The
//! cached fit is **bitwise-identical** to [`Featurizer::fit`] because
//! both run the same standardization helpers over the same raw bits.

use crate::cloud::Cloud;
use crate::repo::{RepoDelta, RuntimeDataRepo, RuntimeRecord};
use crate::util::matrix::MatF32;
use crate::workloads::JobKind;

/// Fitted feature-space metadata: column names and z-scoring parameters,
/// learned from a training repo and applied to queries.
#[derive(Debug, Clone)]
pub struct FeatureSpace {
    pub names: Vec<String>,
    pub mean: Vec<f32>,
    pub sd: Vec<f32>,
    /// Mean/sd of log-runtime (target scaling).
    pub y_mean: f32,
    pub y_sd: f32,
}

impl FeatureSpace {
    /// Number of feature columns.
    pub fn dim(&self) -> usize {
        self.names.len()
    }

    /// Map a standardized log-runtime prediction back to seconds.
    pub fn unscale_runtime(&self, y_std: f32) -> f64 {
        ((y_std * self.y_sd + self.y_mean) as f64).exp()
    }

    /// Map a runtime in seconds to the standardized log target.
    pub fn scale_runtime(&self, runtime_s: f64) -> f32 {
        ((runtime_s.ln() as f32) - self.y_mean) / self.y_sd
    }
}

/// Builds feature matrices from records, resolving machine descriptors
/// against a cloud catalog.
#[derive(Debug, Clone)]
pub struct Featurizer<'a> {
    cloud: &'a Cloud,
}

/// Machine-descriptor column names appended after the job features.
pub const CLUSTER_FEATURES: [&str; 6] = [
    "scaleout",
    "m_vcpus",
    "m_memory_gib",
    "m_cpu_perf",
    "m_disk_mb_s",
    "m_net_mb_s",
];

/// Standardize a raw feature matrix in place; returns the per-column
/// `(mean, sd)`. Spans are clamped at `1e-6` (mirroring the `y_sd`
/// clamp) so a near-constant column — one whose sd squeaks past the
/// `col_stats` exact-constant guard but is still denormal-tiny —
/// cannot blow standardized values up to inf and poison downstream
/// reciprocal bases. The one shared x-standardization path: both
/// [`Featurizer::fit`] and [`FeatureMatrixCache`] call it, which is
/// what makes the cached fit bitwise-identical by construction.
fn standardize_x(x: &mut MatF32) -> (Vec<f32>, Vec<f32>) {
    let (mean, mut sd) = x.col_stats();
    for s in &mut sd {
        *s = s.max(1e-6);
    }
    x.standardize(&mean, &sd);
    (mean, sd)
}

/// Standardize log-runtime targets; returns `(y_mean, y_sd, y)`. The
/// shared y-standardization path of [`Featurizer::fit`] and
/// [`FeatureMatrixCache::fit`].
fn standardize_y(log_y: &[f32]) -> (f32, f32, Vec<f32>) {
    // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
    let y_mean = log_y.iter().sum::<f32>() / log_y.len() as f32;
    // c3o-lint: allow(float-order) — sequential in-order slice reduction; summation order is fixed
    let y_var = log_y.iter().map(|y| (y - y_mean).powi(2)).sum::<f32>() / log_y.len() as f32;
    let y_sd = y_var.sqrt().max(1e-6);
    let y = log_y.iter().map(|v| (v - y_mean) / y_sd).collect();
    (y_mean, y_sd, y)
}

/// Feature-column names for a job: its own features, then the cluster
/// descriptor columns.
fn feature_names(job: JobKind) -> Vec<String> {
    let mut names: Vec<String> = job.feature_names().iter().map(|s| s.to_string()).collect();
    names.extend(CLUSTER_FEATURES.iter().map(|s| s.to_string()));
    names
}

impl<'a> Featurizer<'a> {
    pub fn new(cloud: &'a Cloud) -> Self {
        Featurizer { cloud }
    }

    /// Raw (unscaled) feature row for a record-shaped query.
    ///
    /// # Panics
    /// Panics if the machine type is not in the catalog.
    pub fn raw_row(&self, machine: &str, scaleout: u32, job_features: &[f64]) -> Vec<f32> {
        let m = self
            .cloud
            .machine(machine)
            .unwrap_or_else(|| panic!("unknown machine type {machine:?}"));
        let mut row: Vec<f32> = job_features.iter().map(|&f| f as f32).collect();
        row.extend_from_slice(&[
            scaleout as f32,
            m.vcpus as f32,
            m.memory_gib as f32,
            m.cpu_perf as f32,
            m.disk_mb_s as f32,
            m.net_mb_s as f32,
        ]);
        row
    }

    /// Fit a [`FeatureSpace`] on a repo and return the standardized
    /// feature matrix + standardized log-runtime targets.
    ///
    /// # Panics
    /// Panics on an empty repo.
    pub fn fit(&self, repo: &RuntimeDataRepo) -> (FeatureSpace, MatF32, Vec<f32>) {
        assert!(!repo.is_empty(), "cannot featurize an empty repo");
        let rows: Vec<Vec<f32>> = repo
            .records()
            .iter()
            .map(|r| self.raw_row(&r.machine, r.scaleout, &r.job_features))
            .collect();
        let mut x = MatF32::from_rows(&rows);
        let (mean, sd) = standardize_x(&mut x);

        let log_y: Vec<f32> = repo
            .records()
            .iter()
            .map(|r| r.runtime_s.ln() as f32)
            .collect();
        let (y_mean, y_sd, y) = standardize_y(&log_y);

        (
            FeatureSpace {
                names: feature_names(repo.job()),
                mean,
                sd,
                y_mean,
                y_sd,
            },
            x,
            y,
        )
    }

    /// Standardize a query row with an existing feature space.
    pub fn transform(
        &self,
        space: &FeatureSpace,
        machine: &str,
        scaleout: u32,
        job_features: &[f64],
    ) -> Vec<f32> {
        let mut row = self.raw_row(machine, scaleout, job_features);
        assert_eq!(row.len(), space.dim(), "feature arity mismatch");
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - space.mean[i]) / space.sd[i];
        }
        row
    }

    /// Transform a batch of record-shaped queries.
    pub fn transform_records(&self, space: &FeatureSpace, records: &[RuntimeRecord]) -> MatF32 {
        let rows: Vec<Vec<f32>> = records
            .iter()
            .map(|r| self.transform(space, &r.machine, r.scaleout, &r.job_features))
            .collect();
        MatF32::from_rows(&rows)
    }
}

/// Bitwise row equality. Plain `f32` equality is too weak here:
/// `-0.0 == 0.0` holds while the bits differ, and a bit-level change
/// can shift downstream f32 accumulation order results.
fn rows_bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Memoized zero-padded KNN feature block (see
/// [`FeatureMatrixCache::padded_x`]).
#[derive(Debug, Clone)]
struct KnnPad {
    rows_cap: usize,
    dim_cap: usize,
    raw_epoch: u64,
    x: MatF32,
}

/// Incremental mirror of a repo's featurized training inputs.
///
/// The cache tracks the repo's delta journal
/// ([`RuntimeDataRepo::deltas_since`]): [`FeatureMatrixCache::refresh`]
/// replays only the slots that changed since the last refresh,
/// re-featurizing the delta instead of the corpus, and recomputes the
/// standardized matrix only when some raw row's *bits* actually moved
/// (a replacement that changes only the runtime leaves the x side —
/// and the memoized KNN padding — untouched). A cache that has fallen
/// past the journal's retention window rebuilds from scratch, so it is
/// never wrong, only occasionally cold.
///
/// [`FeatureMatrixCache::fit`] then returns exactly what
/// [`Featurizer::fit`] would: the same helper code runs over the same
/// raw bits, making the result bitwise-identical by construction (and
/// property-tested in `tests/proptests.rs`).
#[derive(Debug, Clone)]
pub struct FeatureMatrixCache {
    /// Journal position the mirrored rows reflect.
    seq: u64,
    /// False until the first rebuild; an unprimed cache always rebuilds.
    primed: bool,
    /// Raw featurized rows, slot-aligned with the repo's records.
    raw: Vec<Vec<f32>>,
    /// Log-runtime targets, slot-aligned.
    log_y: Vec<f32>,
    /// Bumped whenever raw row content changes (append, bit-level
    /// replacement, reorder) — the staleness key of the standardized
    /// state and the KNN padding. Target-only changes do not bump it.
    raw_epoch: u64,
    /// `raw_epoch` the standardized state below reflects.
    std_epoch: u64,
    x_std: MatF32,
    mean: Vec<f32>,
    sd: Vec<f32>,
    knn_pad: Option<KnnPad>,
}

impl Default for FeatureMatrixCache {
    fn default() -> Self {
        FeatureMatrixCache {
            seq: 0,
            primed: false,
            raw: Vec::new(),
            log_y: Vec::new(),
            raw_epoch: 0,
            std_epoch: 0,
            x_std: MatF32::zeros(0, 0),
            mean: Vec::new(),
            sd: Vec::new(),
            knn_pad: None,
        }
    }
}

impl FeatureMatrixCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bring the mirror up to date with `repo`, replaying the delta
    /// journal where possible and rebuilding from scratch otherwise.
    /// Returns how many already-featurized rows were *reused* (i.e. not
    /// re-run through [`Featurizer::raw_row`]) — the
    /// `featurized_rows_reused` metric.
    pub fn refresh(&mut self, featurizer: &Featurizer, repo: &RuntimeDataRepo) -> usize {
        let target = repo.delta_seq();
        if !self.primed {
            return self.rebuild(featurizer, repo);
        }
        let mut featurized = 0usize;
        match repo.deltas_since(self.seq) {
            None => return self.rebuild(featurizer, repo),
            Some(deltas) => {
                for d in deltas {
                    match d {
                        RepoDelta::Set { slot, record } => {
                            let row = featurizer.raw_row(
                                &record.machine,
                                record.scaleout,
                                &record.job_features,
                            );
                            featurized += 1;
                            let ly = record.runtime_s.ln() as f32;
                            if *slot == self.raw.len() {
                                self.raw.push(row);
                                self.log_y.push(ly);
                                self.raw_epoch += 1;
                            } else if *slot < self.raw.len() {
                                if !rows_bits_equal(&self.raw[*slot], &row) {
                                    self.raw[*slot] = row;
                                    self.raw_epoch += 1;
                                }
                                self.log_y[*slot] = ly;
                            } else {
                                return self.rebuild(featurizer, repo);
                            }
                        }
                        RepoDelta::Reordered { perm } => {
                            if perm.len() != self.raw.len() {
                                return self.rebuild(featurizer, repo);
                            }
                            let mut old: Vec<Option<Vec<f32>>> =
                                self.raw.drain(..).map(Some).collect();
                            let mut raw = Vec::with_capacity(perm.len());
                            let mut log_y = Vec::with_capacity(perm.len());
                            for &p in perm {
                                raw.push(old[p as usize].take().expect("bijective permutation"));
                                log_y.push(self.log_y[p as usize]);
                            }
                            self.raw = raw;
                            self.log_y = log_y;
                            self.raw_epoch += 1;
                        }
                    }
                }
            }
        }
        if self.raw.len() != repo.len() {
            // the journal and the holdings disagree (e.g. the cache was
            // pointed at a different repo) — never serve a skewed mirror
            return self.rebuild(featurizer, repo);
        }
        self.seq = target;
        if self.std_epoch != self.raw_epoch {
            self.rebuild_std();
        }
        repo.len().saturating_sub(featurized)
    }

    /// Full rebuild: featurize every record. Returns 0 rows reused.
    fn rebuild(&mut self, featurizer: &Featurizer, repo: &RuntimeDataRepo) -> usize {
        self.raw = repo
            .records()
            .iter()
            .map(|r| featurizer.raw_row(&r.machine, r.scaleout, &r.job_features))
            .collect();
        self.log_y = repo
            .records()
            .iter()
            .map(|r| r.runtime_s.ln() as f32)
            .collect();
        self.raw_epoch += 1;
        self.primed = true;
        self.seq = repo.delta_seq();
        self.rebuild_std();
        0
    }

    /// Recompute the standardized matrix and column stats from the raw
    /// mirror — the exact code path of [`Featurizer::fit`].
    fn rebuild_std(&mut self) {
        let mut x = MatF32::from_rows(&self.raw);
        let (mean, sd) = standardize_x(&mut x);
        self.x_std = x;
        self.mean = mean;
        self.sd = sd;
        self.std_epoch = self.raw_epoch;
    }

    /// The cached equivalent of [`Featurizer::fit`]: bitwise-identical
    /// output, O(records) float work (target standardization) instead
    /// of O(records) featurization.
    ///
    /// # Panics
    /// Panics on an empty repo, or when the cache was not
    /// [`refresh`](FeatureMatrixCache::refresh)ed to the repo's current
    /// journal position.
    pub fn fit(&self, repo: &RuntimeDataRepo) -> (FeatureSpace, MatF32, Vec<f32>) {
        assert!(!repo.is_empty(), "cannot featurize an empty repo");
        assert!(self.is_fresh(repo), "feature cache is stale: refresh() before fit()");
        debug_assert_eq!(self.std_epoch, self.raw_epoch);
        let (y_mean, y_sd, y) = standardize_y(&self.log_y);
        (
            FeatureSpace {
                names: feature_names(repo.job()),
                mean: self.mean.clone(),
                sd: self.sd.clone(),
                y_mean,
                y_sd,
            },
            self.x_std.clone(),
            y,
        )
    }

    /// Whether the mirror reflects `repo`'s current journal position
    /// and holdings size.
    pub fn is_fresh(&self, repo: &RuntimeDataRepo) -> bool {
        self.primed && self.seq == repo.delta_seq() && self.raw.len() == repo.len()
    }

    /// Raw featurized rows, slot-aligned with the repo.
    pub fn raw_rows(&self) -> &[Vec<f32>] {
        &self.raw
    }

    /// Log-runtime targets, slot-aligned with the repo.
    pub fn log_y(&self) -> &[f32] {
        &self.log_y
    }

    /// The standardized rows zero-padded into a `rows_cap × dim_cap`
    /// block — the KNN train matrix layout. Memoized on the raw epoch:
    /// a refresh that changed only targets serves the previous padding
    /// without copying a single row.
    pub fn padded_x(&mut self, rows_cap: usize, dim_cap: usize) -> &MatF32 {
        debug_assert_eq!(self.std_epoch, self.raw_epoch, "refresh() before padded_x()");
        let fresh = matches!(
            &self.knn_pad,
            Some(p) if p.rows_cap == rows_cap && p.dim_cap == dim_cap && p.raw_epoch == self.raw_epoch
        );
        if !fresh {
            let d = self.x_std.cols;
            let mut x = MatF32::zeros(rows_cap, dim_cap);
            for r in 0..self.x_std.rows {
                x.row_mut(r)[..d].copy_from_slice(self.x_std.row(r));
            }
            self.knn_pad = Some(KnnPad {
                rows_cap,
                dim_cap,
                raw_epoch: self.raw_epoch,
                x,
            });
        }
        &self.knn_pad.as_ref().expect("just ensured").x
    }

    /// Whether the memoized KNN padding for these caps is already
    /// current (test/metrics hook).
    pub fn knn_pad_is_warm(&self, rows_cap: usize, dim_cap: usize) -> bool {
        matches!(
            &self.knn_pad,
            Some(p) if p.rows_cap == rows_cap && p.dim_cap == dim_cap && p.raw_epoch == self.raw_epoch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::RuntimeRecord;
    use crate::workloads::JobKind;

    fn small_repo() -> RuntimeDataRepo {
        let recs = vec![
            RuntimeRecord {
                job: JobKind::Grep,
                org: "a".into(),
                machine: "m5.xlarge".into(),
                scaleout: 4,
                job_features: vec![10.0, 0.1],
                runtime_s: 100.0,
            },
            RuntimeRecord {
                job: JobKind::Grep,
                org: "a".into(),
                machine: "c5.xlarge".into(),
                scaleout: 8,
                job_features: vec![20.0, 0.3],
                runtime_s: 80.0,
            },
            RuntimeRecord {
                job: JobKind::Grep,
                org: "b".into(),
                machine: "r5.xlarge".into(),
                scaleout: 2,
                job_features: vec![15.0, 0.01],
                runtime_s: 300.0,
            },
        ];
        RuntimeDataRepo::from_records(JobKind::Grep, recs)
    }

    #[test]
    fn dimensions_and_names() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let (space, x, y) = f.fit(&small_repo());
        assert_eq!(space.dim(), 2 + 6); // grep features + cluster features
        assert_eq!(x.rows, 3);
        assert_eq!(x.cols, 8);
        assert_eq!(y.len(), 3);
        assert_eq!(space.names[0], "data_gb");
        assert_eq!(space.names[2], "scaleout");
    }

    #[test]
    fn standardization_round_trip() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let repo = small_repo();
        let (space, _, y) = f.fit(&repo);
        // unscale(scale(t)) == t
        for (i, r) in repo.records().iter().enumerate() {
            let back = space.unscale_runtime(y[i]);
            assert!(
                (back - r.runtime_s).abs() / r.runtime_s < 1e-3,
                "{} vs {}",
                back,
                r.runtime_s
            );
            let fwd = space.scale_runtime(r.runtime_s);
            assert!((fwd - y[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn transform_matches_fit_columns() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let repo = small_repo();
        let (space, x, _) = f.fit(&repo);
        let r0 = &repo.records()[0];
        let q = f.transform(&space, &r0.machine, r0.scaleout, &r0.job_features);
        for c in 0..x.cols {
            assert!((q[c] - x.at(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    fn near_constant_column_span_is_clamped() {
        // sd small enough to slip past col_stats' exact-constant guard
        // (1e-9) but tiny enough to explode z-scores without the clamp
        let mut x = MatF32::from_rows(&[vec![0.0], vec![1e-7]]);
        let (_, sd) = standardize_x(&mut x);
        assert_eq!(sd[0], 1e-6);
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn constant_column_fit_stays_finite() {
        // every record shares data_gb: a constant feature column must
        // not produce NaN/inf anywhere in the standardized outputs
        let recs: Vec<RuntimeRecord> = [("m5.xlarge", 4u32, 100.0), ("c5.xlarge", 8, 80.0), ("r5.xlarge", 2, 300.0)]
            .iter()
            .map(|&(machine, scaleout, runtime_s)| RuntimeRecord {
                job: JobKind::Grep,
                org: "a".into(),
                machine: machine.into(),
                scaleout,
                job_features: vec![10.0, 0.2],
                runtime_s,
            })
            .collect();
        let repo = RuntimeDataRepo::from_records(JobKind::Grep, recs);
        let cloud = Cloud::aws_like();
        let (space, x, y) = Featurizer::new(&cloud).fit(&repo);
        assert!(x.data.iter().all(|v| v.is_finite()));
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(space.sd.iter().all(|s| *s >= 1e-6));
    }

    fn assert_fit_bits_equal(
        a: &(FeatureSpace, MatF32, Vec<f32>),
        b: &(FeatureSpace, MatF32, Vec<f32>),
    ) {
        assert_eq!(a.0.names, b.0.names);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a.0.mean), bits(&b.0.mean));
        assert_eq!(bits(&a.0.sd), bits(&b.0.sd));
        assert_eq!(a.0.y_mean.to_bits(), b.0.y_mean.to_bits());
        assert_eq!(a.0.y_sd.to_bits(), b.0.y_sd.to_bits());
        assert_eq!((a.1.rows, a.1.cols), (b.1.rows, b.1.cols));
        assert_eq!(bits(&a.1.data), bits(&b.1.data));
        assert_eq!(bits(&a.2), bits(&b.2));
    }

    #[test]
    fn cache_fit_matches_from_scratch_across_mutations() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let mut repo = small_repo();
        let mut cache = FeatureMatrixCache::new();
        assert_eq!(cache.refresh(&f, &repo), 0, "cold cache rebuilds");
        assert_fit_bits_equal(&cache.fit(&repo), &f.fit(&repo));

        // append via contribute: only the new row is featurized
        repo.contribute(RuntimeRecord {
            job: JobKind::Grep,
            org: "c".into(),
            machine: "m5.2xlarge".into(),
            scaleout: 6,
            job_features: vec![12.0, 0.2],
            runtime_s: 140.0,
        })
        .unwrap();
        assert_eq!(cache.refresh(&f, &repo), 3, "three rows reused");
        assert_fit_bits_equal(&cache.fit(&repo), &f.fit(&repo));

        // replacement via merge (same config as record 0, lower runtime)
        let winner = RuntimeRecord {
            org: "z".into(),
            runtime_s: 90.0,
            ..repo.records()[0].clone()
        };
        let out = repo.merge_records(&[winner]).unwrap();
        assert_eq!(out.replaced, 1);
        cache.refresh(&f, &repo);
        assert_fit_bits_equal(&cache.fit(&repo), &f.fit(&repo));

        // canonical reorder replays as a permutation
        repo.canonicalize();
        assert_eq!(cache.refresh(&f, &repo), repo.len(), "reorder reuses all rows");
        assert_fit_bits_equal(&cache.fit(&repo), &f.fit(&repo));
    }

    #[test]
    fn knn_padding_survives_target_only_changes() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let mut repo = small_repo();
        let mut cache = FeatureMatrixCache::new();
        cache.refresh(&f, &repo);
        let before = cache.padded_x(16, 12).clone();
        assert!(cache.knn_pad_is_warm(16, 12));

        // replace record 0's runtime only: identical raw feature bits
        let winner = RuntimeRecord {
            org: "z".into(),
            runtime_s: 90.0,
            ..repo.records()[0].clone()
        };
        assert_eq!(repo.merge_records(&[winner]).unwrap().replaced, 1);
        cache.refresh(&f, &repo);
        assert!(
            cache.knn_pad_is_warm(16, 12),
            "target-only change must not invalidate the padded block"
        );
        let after = cache.padded_x(16, 12);
        let bits = |m: &MatF32| m.data.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&before), bits(after));

        // an appended record DOES invalidate it
        repo.contribute(RuntimeRecord {
            job: JobKind::Grep,
            org: "c".into(),
            machine: "m5.2xlarge".into(),
            scaleout: 6,
            job_features: vec![12.0, 0.2],
            runtime_s: 140.0,
        })
        .unwrap();
        cache.refresh(&f, &repo);
        assert!(!cache.knn_pad_is_warm(16, 12));
    }

    #[test]
    #[should_panic(expected = "unknown machine type")]
    fn unknown_machine_panics() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        f.raw_row("tpu.9000", 2, &[1.0]);
    }

    #[test]
    #[should_panic(expected = "empty repo")]
    fn empty_repo_panics() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        f.fit(&RuntimeDataRepo::new(JobKind::Sort));
    }
}
