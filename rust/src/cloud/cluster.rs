//! Cluster lifecycle and the EMR-like provisioning model.
//!
//! The paper motivates avoiding profiling runs partly by EMR's provisioning
//! delay of "seven or more minutes" per cluster. The provisioning model
//! here samples from a right-skewed distribution centered near that figure
//! (larger clusters take slightly longer), so iterative-search baselines
//! (CherryPick) pay a realistic wall-clock and dollar cost per probe.

use super::catalog::MachineType;
use crate::util::rng::Pcg32;

/// Lifecycle state of a simulated cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterState {
    /// Requested but not yet usable (inside the provisioning delay).
    Provisioning,
    /// Bootstrapped and accepting jobs.
    Running,
    /// Terminated; retains billing totals.
    Terminated,
}

/// Provisioning-delay model.
#[derive(Debug, Clone)]
pub struct ProvisioningModel {
    /// Base delay in seconds (cluster-size independent part).
    pub base_s: f64,
    /// Additional seconds per node.
    pub per_node_s: f64,
    /// Log-normal sigma of the multiplicative noise.
    pub sigma: f64,
}

impl ProvisioningModel {
    /// EMR-like: ~7 min base + 6 s/node, ±15% log-normal noise.
    pub fn emr_like() -> Self {
        ProvisioningModel {
            base_s: 7.0 * 60.0,
            per_node_s: 6.0,
            sigma: 0.15,
        }
    }

    /// Zero-delay model for unit tests.
    pub fn instant() -> Self {
        ProvisioningModel {
            base_s: 0.0,
            per_node_s: 0.0,
            sigma: 0.0,
        }
    }

    /// Sample a provisioning delay for a cluster of `count` nodes.
    pub fn sample_delay_s(&self, count: u32, rng: &mut Pcg32) -> f64 {
        let det = self.base_s + self.per_node_s * count as f64;
        if self.sigma == 0.0 {
            det
        } else {
            det * rng.lognormal_noise(self.sigma)
        }
    }
}

/// A provisioned (simulated) cluster.
#[derive(Debug, Clone)]
pub struct Cluster {
    machine: MachineType,
    count: u32,
    provisioning_delay_s: f64,
    state: ClusterState,
    busy_seconds: f64,
}

impl Cluster {
    pub(crate) fn new(machine: MachineType, count: u32, provisioning_delay_s: f64) -> Self {
        Cluster {
            machine,
            count,
            provisioning_delay_s,
            state: ClusterState::Provisioning,
            busy_seconds: 0.0,
        }
    }

    /// The machine type of every node (EMR uniform instance groups).
    pub fn machine(&self) -> &MachineType {
        &self.machine
    }

    /// Number of worker nodes.
    pub fn count(&self) -> u32 {
        self.count
    }

    /// Aggregate vCPUs across the cluster.
    pub fn total_vcpus(&self) -> u32 {
        self.count * self.machine.vcpus
    }

    /// Aggregate memory in GiB across the cluster.
    pub fn total_memory_gib(&self) -> f64 {
        self.count as f64 * self.machine.memory_gib
    }

    /// Sampled provisioning delay for this cluster.
    pub fn provisioning_delay_s(&self) -> f64 {
        self.provisioning_delay_s
    }

    /// Current lifecycle state.
    pub fn state(&self) -> ClusterState {
        self.state
    }

    /// Finish bootstrapping (advance Provisioning → Running).
    pub fn mark_running(&mut self) {
        assert_eq!(self.state, ClusterState::Provisioning, "already started");
        self.state = ClusterState::Running;
    }

    /// Record `seconds` of busy time (job execution) on this cluster.
    pub fn record_busy(&mut self, seconds: f64) {
        assert_eq!(self.state, ClusterState::Running, "cluster not running");
        assert!(seconds >= 0.0);
        self.busy_seconds += seconds;
    }

    /// Terminate; returns total held wall-clock seconds (provisioning +
    /// busy time), the quantity billing applies to.
    pub fn terminate(&mut self) -> f64 {
        assert_ne!(self.state, ClusterState::Terminated, "double terminate");
        self.state = ClusterState::Terminated;
        self.provisioning_delay_s + self.busy_seconds
    }

    /// Busy seconds so far.
    pub fn busy_seconds(&self) -> f64 {
        self.busy_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::catalog::aws_like_catalog;

    fn some_machine() -> MachineType {
        aws_like_catalog().remove(0)
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut c = Cluster::new(some_machine(), 4, 420.0);
        assert_eq!(c.state(), ClusterState::Provisioning);
        c.mark_running();
        c.record_busy(100.0);
        c.record_busy(50.0);
        let held = c.terminate();
        assert!((held - 570.0).abs() < 1e-9);
        assert_eq!(c.state(), ClusterState::Terminated);
    }

    #[test]
    #[should_panic(expected = "not running")]
    fn busy_before_running_panics() {
        let mut c = Cluster::new(some_machine(), 4, 420.0);
        c.record_busy(1.0);
    }

    #[test]
    #[should_panic(expected = "double terminate")]
    fn double_terminate_panics() {
        let mut c = Cluster::new(some_machine(), 4, 420.0);
        c.mark_running();
        c.terminate();
        c.terminate();
    }

    #[test]
    fn emr_delay_mean_near_seven_minutes() {
        let model = ProvisioningModel::emr_like();
        let mut rng = Pcg32::new(2);
        let n = 2000;
        let mean: f64 = (0..n).map(|_| model.sample_delay_s(8, &mut rng)).sum::<f64>() / n as f64;
        // 420 + 48 base, log-normal mean slightly above median
        assert!((440.0..520.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn bigger_clusters_take_longer_on_average() {
        let model = ProvisioningModel::emr_like();
        let mut rng = Pcg32::new(3);
        let n = 2000;
        let small: f64 = (0..n).map(|_| model.sample_delay_s(2, &mut rng)).sum::<f64>() / n as f64;
        let big: f64 = (0..n).map(|_| model.sample_delay_s(32, &mut rng)).sum::<f64>() / n as f64;
        assert!(big > small + 60.0, "small {small} big {big}");
    }

    #[test]
    fn instant_model_is_deterministic_zero() {
        let model = ProvisioningModel::instant();
        let mut rng = Pcg32::new(4);
        assert_eq!(model.sample_delay_s(10, &mut rng), 0.0);
    }

    #[test]
    fn aggregates() {
        let c = Cluster::new(some_machine(), 3, 0.0); // c5.large: 2 vcpu, 4 GiB
        assert_eq!(c.total_vcpus(), 6);
        assert!((c.total_memory_gib() - 12.0).abs() < 1e-9);
    }
}
