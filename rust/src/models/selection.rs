//! Dynamic model selection (paper §V-C).
//!
//! "Based on cross-validation, the most accurate model averaged over the
//! test datasets is chosen to predict new data points." — k-fold CV over
//! the shared repository for each model family, pick the lower mean MAPE,
//! retrain the winner on the full data. Retraining happens on the arrival
//! of new runtime data (driven by the coordinator).
//!
//! ## Parallel CV with a serial bit pattern
//!
//! The `folds × ModelKind::all()` CV tasks are independent: each builds
//! its own training sub-repo, fits from scratch, and scores its own
//! held-out fold. [`select_and_train_pooled`] fans them across a
//! [`ComputePool`] and collects results into fixed `(kind, fold)` index
//! order, then reduces exactly as the serial loop does (fold MAPEs in
//! fold order → [`stats::mean`]; winner via the same `min_by` over
//! [`ModelKind::all`] order). Every task runs [`fold_mape`] — the one
//! per-fold code path shared with the serial [`cv_mape`] — on a
//! [`ModelTrainer::fork_native`] clone, which trains
//! bitwise-identically to its parent (the native backend is pure
//! configuration). Fold MAPEs, their means, and the selected winner are
//! therefore bit-identical to serial execution at any thread count;
//! thread-pinned backends (PJRT) report no native fork and stay serial,
//! which is trivially bit-identical too.

use crate::cloud::Cloud;
use crate::compute::ComputePool;
use crate::models::{ConfigQuery, ModelKind, ModelTrainer, TrainedModel};
use crate::repo::featurize::FeatureMatrixCache;
use crate::repo::RuntimeDataRepo;
use crate::util::rng::Pcg32;
use crate::util::stats;
use anyhow::{bail, Result};

/// Outcome of one dynamic selection round.
#[derive(Debug, Clone)]
pub struct SelectionReport {
    /// Mean CV MAPE (%) per model kind.
    pub cv_mape: Vec<(ModelKind, f64)>,
    pub chosen: ModelKind,
    pub folds: usize,
    pub records: usize,
    /// Wall-clock nanoseconds the cross-validation sweep took (all
    /// model kinds, all folds). Timing only — never feeds a decision.
    pub cv_nanos: u64,
    /// Wall-clock nanoseconds the winner's full-repository fit took.
    pub fit_nanos: u64,
    /// Nanoseconds the CV fan spent waiting on compute-pool helper
    /// threads (0 when selection ran serially). Timing only.
    pub pool_wait_nanos: u64,
}

impl SelectionReport {
    pub fn mape_of(&self, kind: ModelKind) -> f64 {
        self.cv_mape
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, m)| *m)
            .unwrap_or(f64::NAN)
    }
}

/// Deterministic shuffled k-fold split of record indices.
pub fn kfold_indices(n: usize, folds: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(folds >= 2, "need at least 2 folds");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg32::new(seed);
    rng.shuffle(&mut idx);
    let mut out = vec![Vec::new(); folds];
    for (i, r) in idx.into_iter().enumerate() {
        out[i % folds].push(r);
    }
    out
}

/// MAPE of one `(kind, fold)` CV task: train a model of `kind` on
/// everything but `test_idx`, score the held-out fold. The single
/// per-fold code path — both the serial [`cv_mape`] loop and the
/// pooled fan of [`select_and_train_pooled`] execute exactly this, so
/// their per-fold results are bit-identical by construction.
fn fold_mape(
    trainer: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    test_idx: &[usize],
    kind: ModelKind,
) -> Result<f64> {
    let records = repo.records();
    let test_set: std::collections::BTreeSet<usize> = test_idx.iter().copied().collect();
    let train = RuntimeDataRepo::from_records(
        repo.job(),
        records
            .iter()
            .enumerate()
            .filter(|(i, _)| !test_set.contains(i))
            .map(|(_, r)| r.clone()),
    );
    let model = trainer.train(cloud, &train, kind)?;
    let queries: Vec<ConfigQuery> = test_idx
        .iter()
        .map(|&i| ConfigQuery {
            machine: records[i].machine.clone(),
            scaleout: records[i].scaleout,
            job_features: records[i].job_features.clone(),
        })
        .collect();
    let truth: Vec<f64> = test_idx.iter().map(|&i| records[i].runtime_s).collect();
    let preds = trainer.predict(&model, cloud, &queries)?;
    Ok(stats::mape(&preds, &truth))
}

/// Cross-validated MAPE of one model kind on a repository. Works with
/// any [`ModelTrainer`] backend (PJRT predictor or native engine).
pub fn cv_mape(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    kind: ModelKind,
    folds: usize,
    seed: u64,
) -> Result<f64> {
    let n = repo.len();
    if n < folds {
        bail!("repo has {n} records, need at least {folds} for {folds}-fold CV");
    }
    let splits = kfold_indices(n, folds, seed);
    let mut fold_mapes = Vec::with_capacity(folds);
    for test_idx in &splits {
        fold_mapes.push(fold_mape(predictor, cloud, repo, test_idx, kind)?);
    }
    Ok(stats::mean(&fold_mapes))
}

/// Run dynamic selection: CV both families, retrain the winner on the
/// full repository. Works with any [`ModelTrainer`] backend.
pub fn select_and_train(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    folds: usize,
    seed: u64,
) -> Result<(TrainedModel, SelectionReport)> {
    select_and_train_cached(predictor, cloud, repo, folds, seed, None)
}

/// [`select_and_train`] with an optional incremental
/// [`FeatureMatrixCache`] consumed by the winner's full-repository
/// train. The CV folds train on fresh per-fold sub-repos the cache
/// cannot mirror, so they always run from scratch; only the final —
/// and by far largest — fit takes the cached path. Bitwise-identical
/// models either way.
pub fn select_and_train_cached(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    folds: usize,
    seed: u64,
    feat: Option<&mut FeatureMatrixCache>,
) -> Result<(TrainedModel, SelectionReport)> {
    select_and_train_pooled(predictor, cloud, repo, folds, seed, feat, None)
}

/// [`select_and_train_cached`] with an optional [`ComputePool`] that
/// fans the `folds × ModelKind::all()` CV tasks across helper threads.
/// See the module docs for why the selection outcome is bit-identical
/// to serial execution at any thread count; a backend without a native
/// fork (PJRT) or a width-1 pool simply runs the serial loop.
pub fn select_and_train_pooled(
    predictor: &mut dyn ModelTrainer,
    cloud: &Cloud,
    repo: &RuntimeDataRepo,
    folds: usize,
    seed: u64,
    feat: Option<&mut FeatureMatrixCache>,
    pool: Option<&ComputePool>,
) -> Result<(TrainedModel, SelectionReport)> {
    let cv_started = std::time::Instant::now();
    let mut pool_wait_nanos = 0u64;
    let fan = pool
        .filter(|p| p.threads() > 1)
        .and_then(|p| predictor.fork_native().map(|proto| (p, proto)));
    let cv: Vec<(ModelKind, f64)> = match fan {
        Some((pool, proto)) => {
            let n = repo.len();
            if n < folds {
                // the same guard (and message) cv_mape raises serially
                bail!("repo has {n} records, need at least {folds} for {folds}-fold CV");
            }
            let splits = kfold_indices(n, folds, seed);
            // kind-major, fold-minor: the exact iteration order of the
            // serial loops, so the ordered collection below reduces in
            // the serial order
            let mut tasks = Vec::with_capacity(ModelKind::all().len() * folds);
            for kind in ModelKind::all() {
                for test_idx in &splits {
                    let mut engine = proto.clone();
                    tasks.push(move || {
                        fold_mape(&mut engine, cloud, repo, test_idx.as_slice(), kind)
                    });
                }
            }
            let (results, wait) = pool.map_ordered_timed(tasks);
            pool_wait_nanos = wait;
            let mut results = results.into_iter();
            let mut cv = Vec::with_capacity(ModelKind::all().len());
            for kind in ModelKind::all() {
                let mut fold_mapes = Vec::with_capacity(folds);
                for _ in 0..folds {
                    // `?` in (kind, fold) order: the first failing task
                    // propagates, exactly as the serial loop would
                    fold_mapes.push(results.next().expect("one result per task")?);
                }
                cv.push((kind, stats::mean(&fold_mapes)));
            }
            cv
        }
        None => {
            let mut cv = Vec::new();
            for kind in ModelKind::all() {
                let mape = cv_mape(predictor, cloud, repo, kind, folds, seed)?;
                cv.push((kind, mape));
            }
            cv
        }
    };
    let cv_nanos = cv_started.elapsed().as_nanos() as u64;
    let chosen = cv
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(k, _)| *k)
        .unwrap();
    let fit_started = std::time::Instant::now();
    let model = predictor.train_cached(cloud, repo, chosen, feat)?;
    Ok((
        model,
        SelectionReport {
            cv_mape: cv,
            chosen,
            folds,
            records: repo.len(),
            cv_nanos,
            fit_nanos: fit_started.elapsed().as_nanos() as u64,
            pool_wait_nanos,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Predictor;
    use crate::runtime::Runtime;
    use crate::workloads::{ExperimentGrid, JobKind};

    #[test]
    fn kfold_partitions_everything_once() {
        let folds = kfold_indices(103, 5, 7);
        assert_eq!(folds.len(), 5);
        let mut all: Vec<usize> = folds.concat();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn kfold_is_seeded() {
        assert_eq!(kfold_indices(50, 5, 1), kfold_indices(50, 5, 1));
        assert_ne!(kfold_indices(50, 5, 1), kfold_indices(50, 5, 2));
    }

    #[test]
    fn selection_runs_and_reports() {
        let dir = Runtime::default_dir();
        if !Runtime::artifacts_available(&dir) {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let cloud = Cloud::aws_like();
        // small sort corpus: dense grid → pessimistic should win or tie
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 3,
        };
        let repo = grid.execute(&cloud, 3).repo_for(JobKind::Sort);
        let mut p = Predictor::new(&dir).unwrap();
        let (model, report) = select_and_train(&mut p, &cloud, &repo, 4, 9).unwrap();
        assert_eq!(model.kind, report.chosen);
        for (_, mape) in &report.cv_mape {
            assert!(mape.is_finite() && *mape > 0.0, "{report:?}");
        }
        // the winner's CV MAPE is the minimum
        let winner = report.mape_of(report.chosen);
        for (_, m) in &report.cv_mape {
            assert!(winner <= *m + 1e-12);
        }
        // on this dense, low-noise grid both models should be usable
        assert!(winner < 30.0, "winner MAPE {winner}");
    }

    #[test]
    fn cv_rejects_tiny_repo() {
        let dir = Runtime::default_dir();
        if !Runtime::artifacts_available(&dir) {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let cloud = Cloud::aws_like();
        let mut p = Predictor::new(&dir).unwrap();
        let repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(cv_mape(&mut p, &cloud, &repo, ModelKind::Pessimistic, 5, 1).is_err());
    }

    #[test]
    fn selection_runs_on_native_backend() {
        // No artifacts required: the native engine serves dynamic
        // selection end to end.
        let cloud = Cloud::aws_like();
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 1,
        };
        let repo = grid.execute(&cloud, 3).repo_for(JobKind::Sort);
        let mut engine = crate::models::native::NativeEngine::default();
        let (model, report) = select_and_train(&mut engine, &cloud, &repo, 4, 9).unwrap();
        assert_eq!(model.kind, report.chosen);
        let winner = report.mape_of(report.chosen);
        for (_, m) in &report.cv_mape {
            assert!(m.is_finite() && *m > 0.0, "{report:?}");
            assert!(winner <= *m + 1e-12);
        }
        assert!(winner < 30.0, "native winner MAPE {winner}");
    }

    #[test]
    fn cv_rejects_tiny_repo_native() {
        let cloud = Cloud::aws_like();
        let mut engine = crate::models::native::NativeEngine::default();
        let repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(cv_mape(&mut engine, &cloud, &repo, ModelKind::Pessimistic, 5, 1).is_err());
    }

    #[test]
    fn pooled_selection_is_bitwise_identical_to_serial() {
        use crate::models::native::NativeEngine;
        use crate::models::OptTrainConfig;
        let cloud = Cloud::aws_like();
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 1,
        };
        let repo = grid.execute(&cloud, 3).repo_for(JobKind::Sort);
        let proto = NativeEngine {
            opt_cfg: OptTrainConfig {
                max_steps: 60,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut serial = proto.clone();
        let (smodel, sreport) = select_and_train(&mut serial, &cloud, &repo, 4, 9).unwrap();
        for width in [1usize, 2, 8] {
            let pool = ComputePool::new(width);
            let mut engine = proto.clone();
            let (pmodel, preport) =
                select_and_train_pooled(&mut engine, &cloud, &repo, 4, 9, None, Some(&pool))
                    .unwrap();
            assert_eq!(pmodel.kind, smodel.kind, "width {width}");
            assert_eq!(preport.chosen, sreport.chosen, "width {width}");
            for (kind, m) in &preport.cv_mape {
                assert_eq!(
                    m.to_bits(),
                    sreport.mape_of(*kind).to_bits(),
                    "width {width} {kind:?}: {m} vs {}",
                    sreport.mape_of(*kind)
                );
            }
        }
    }

    #[test]
    fn pooled_selection_rejects_tiny_repo_like_serial() {
        let cloud = Cloud::aws_like();
        let mut engine = crate::models::native::NativeEngine::default();
        let repo = RuntimeDataRepo::new(JobKind::Sort);
        let pool = ComputePool::new(4);
        let err = select_and_train_pooled(&mut engine, &cloud, &repo, 4, 1, None, Some(&pool))
            .unwrap_err();
        assert!(err.to_string().contains("need at least"), "{err}");
    }
}
