//! The sharded, concurrent coordinator service — the "many organizations,
//! heavy traffic" deployment shape.
//!
//! Architecture (contrast with the strictly-ordered single-worker
//! [`super::session`]):
//!
//! * **Shards** — one [`JobShard`] per [`JobKind`], each behind its own
//!   mutex. A submission only locks its own kind's shard, so concurrent
//!   clients working on different kinds never serialize against each
//!   other; same-kind submissions serialize exactly as much as the shared
//!   repository requires.
//! * **Workers** — `N` threads pull requests from one shared queue. Every
//!   worker owns its **own model engine**, constructed on the worker's
//!   thread: the first `pjrt_workers` try to own a PJRT runtime (the PJRT
//!   client is thread-pinned, hence "pinned workers"); the rest always use
//!   the pure-Rust native engine ("free-floating"). Trained models are
//!   plain data stored in the shard, padded to one fixed layout, so a
//!   model trained by any worker is served by every other.
//! * **Per-request replies** — each request carries its own reply
//!   channel. There is no ordered reply stream to hold up: a client
//!   blocked on a slow submission never delays another client's reply
//!   (the session's single ordered `Receiver` could not offer this).
//! * **Generation-cached models** — shards retrain only when the repo
//!   generation moved past the retrain threshold (see [`JobShard`]), so
//!   request throughput is decoupled from training frequency.
//!
//! ```no_run
//! use c3o::cloud::Cloud;
//! use c3o::configurator::JobRequest;
//! use c3o::coordinator::service::{CoordinatorService, ServiceConfig};
//! use c3o::coordinator::Organization;
//!
//! let service = CoordinatorService::spawn(Cloud::aws_like(), ServiceConfig::default());
//! let client = service.client(); // Clone one per client thread
//! let org = Organization::new("acme");
//! let outcome = client.submit(&org, JobRequest::sort(15.0)).unwrap();
//! println!("ran on {} x{}", outcome.machine, outcome.scaleout);
//! service.shutdown();
//! ```

use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::coordinator::shard::{JobShard, ShardPolicy};
use crate::coordinator::{JobOutcome, Metrics, Organization};
use crate::models::Engine;
use crate::repo::RuntimeDataRepo;
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;
use crate::workloads::JobKind;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Deployment knobs for a [`CoordinatorService`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads serving the request queue.
    pub workers: usize,
    /// How many of the workers attempt to own a PJRT runtime (pinned);
    /// the remainder always run the native engine. Ignored when the
    /// artifacts are absent — every worker then falls back to native.
    pub pjrt_workers: usize,
    /// Artifacts directory for the PJRT-capable workers.
    pub artifacts_dir: PathBuf,
    /// Retrain/cold-start policy applied by every shard.
    pub policy: ShardPolicy,
    /// Master seed; each shard derives its own RNG stream from it.
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
            pjrt_workers: 1,
            artifacts_dir: Runtime::default_dir(),
            policy: ShardPolicy::default(),
            seed: 0xC30,
        }
    }
}

impl ServiceConfig {
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_policy(mut self, policy: ShardPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_artifacts_dir(mut self, dir: PathBuf) -> Self {
        self.artifacts_dir = dir;
        self
    }

    /// How many workers attempt to own a PJRT runtime. `0` forces every
    /// worker onto the native engine (e.g. for backend-controlled
    /// benchmarks).
    pub fn with_pjrt_workers(mut self, pjrt_workers: usize) -> Self {
        self.pjrt_workers = pjrt_workers;
        self
    }
}

/// A request paired with its own reply channel (no cross-client ordering).
enum Request {
    Share(RuntimeDataRepo, mpsc::Sender<Result<usize>>),
    Submit(Organization, JobRequest, mpsc::Sender<Result<JobOutcome>>),
    Metrics(mpsc::Sender<Metrics>),
    Shutdown,
}

/// Shared state every worker sees.
struct Shared {
    shards: HashMap<JobKind, Mutex<JobShard>>,
    metrics: Mutex<Metrics>,
    cloud: Cloud,
    policy: ShardPolicy,
}

/// The running service: owns the worker threads and the request queue.
pub struct CoordinatorService {
    tx: mpsc::Sender<Request>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

/// A cloneable client handle; one per client thread. Each call blocks on
/// its own reply channel only.
#[derive(Clone)]
pub struct ServiceClient {
    tx: mpsc::Sender<Request>,
}

fn share_on(tx: &mpsc::Sender<Request>, repo: RuntimeDataRepo) -> Result<usize> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Share(repo, rtx))
        .map_err(|_| anyhow!("service stopped"))?;
    rrx.recv().map_err(|_| anyhow!("service dropped the reply"))?
}

fn submit_on(
    tx: &mpsc::Sender<Request>,
    org: &Organization,
    request: JobRequest,
) -> Result<JobOutcome> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Submit(org.clone(), request, rtx))
        .map_err(|_| anyhow!("service stopped"))?;
    rrx.recv().map_err(|_| anyhow!("service dropped the reply"))?
}

fn metrics_on(tx: &mpsc::Sender<Request>) -> Result<Metrics> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Request::Metrics(rtx))
        .map_err(|_| anyhow!("service stopped"))?;
    rrx.recv().map_err(|_| anyhow!("service dropped the reply"))
}

impl ServiceClient {
    /// Merge shared runtime data into the owning shard's repository.
    pub fn share(&self, repo: RuntimeDataRepo) -> Result<usize> {
        share_on(&self.tx, repo)
    }

    /// Submit a job; blocks on this request's own reply only.
    pub fn submit(&self, org: &Organization, request: JobRequest) -> Result<JobOutcome> {
        submit_on(&self.tx, org, request)
    }

    /// Snapshot the service-wide metrics.
    pub fn metrics(&self) -> Result<Metrics> {
        metrics_on(&self.tx)
    }
}

impl CoordinatorService {
    /// Spawn the service: shards for every job kind plus `workers`
    /// threads, each constructing its engine on its own thread.
    pub fn spawn(cloud: Cloud, config: ServiceConfig) -> CoordinatorService {
        let (tx, rx) = mpsc::channel::<Request>();
        let queue = Arc::new(Mutex::new(rx));
        let mut seed_rng = Pcg32::new(config.seed);
        let mut shards = HashMap::new();
        for kind in JobKind::all() {
            shards.insert(kind, Mutex::new(JobShard::new(kind, seed_rng.next_u64())));
        }
        let shared = Arc::new(Shared {
            shards,
            metrics: Mutex::new(Metrics::default()),
            cloud,
            policy: config.policy.clone(),
        });
        let n = config.workers.max(1);
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let queue = Arc::clone(&queue);
            let shared = Arc::clone(&shared);
            let artifacts_dir = config.artifacts_dir.clone();
            let try_pjrt = i < config.pjrt_workers;
            workers.push(std::thread::spawn(move || {
                worker_loop(queue, shared, try_pjrt, artifacts_dir);
            }));
        }
        CoordinatorService {
            tx,
            shared,
            workers,
        }
    }

    /// A new client handle (clone freely across threads).
    pub fn client(&self) -> ServiceClient {
        ServiceClient {
            tx: self.tx.clone(),
        }
    }

    /// Merge shared runtime data (convenience over [`Self::client`]).
    pub fn share(&self, repo: RuntimeDataRepo) -> Result<usize> {
        share_on(&self.tx, repo)
    }

    /// Submit a job (convenience over [`Self::client`]).
    pub fn submit(&self, org: &Organization, request: JobRequest) -> Result<JobOutcome> {
        submit_on(&self.tx, org, request)
    }

    /// Snapshot the service-wide metrics.
    pub fn metrics(&self) -> Result<Metrics> {
        metrics_on(&self.tx)
    }

    /// Current repo generation of a shard (observability / tests).
    pub fn generation(&self, kind: JobKind) -> u64 {
        self.shared.shards[&kind].lock().unwrap().generation()
    }

    /// The generation the shard's cached model was trained at.
    pub fn trained_at_generation(&self, kind: JobKind) -> Option<u64> {
        self.shared.shards[&kind]
            .lock()
            .unwrap()
            .trained_at_generation()
    }

    /// Graceful shutdown: every worker drains one `Shutdown` and exits.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Request::Shutdown);
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for CoordinatorService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(
    queue: Arc<Mutex<mpsc::Receiver<Request>>>,
    shared: Arc<Shared>,
    try_pjrt: bool,
    artifacts_dir: PathBuf,
) {
    // Engines are per-worker and constructed on the worker's own thread:
    // the PJRT client is not `Send`, so a PJRT-owning worker is pinned to
    // its runtime for its whole life; native workers are pure data.
    let mut engine = if try_pjrt {
        Engine::auto(&artifacts_dir)
    } else {
        Engine::native()
    };
    loop {
        // Hold the queue lock only for the dequeue, never while serving.
        let request = {
            let rx = queue.lock().unwrap();
            rx.recv()
        };
        let Ok(request) = request else {
            break; // all senders gone
        };
        match request {
            Request::Shutdown => break,
            Request::Share(repo, reply) => {
                let result = match shared.shards.get(&repo.job()) {
                    Some(shard) => shard.lock().unwrap().share(&repo),
                    None => Err(anyhow!("no shard for job {}", repo.job().name())),
                };
                let _ = reply.send(result);
            }
            Request::Submit(org, request, reply) => {
                let kind = request.kind();
                let result = match shared.shards.get(&kind) {
                    Some(shard) => {
                        // Stage metrics locally and fold after the shard
                        // lock drops, so the global metrics mutex never
                        // nests inside a busy shard.
                        let mut local = Metrics::default();
                        let outcome = {
                            let mut shard = shard.lock().unwrap();
                            shard.submit(
                                &mut engine,
                                &shared.cloud,
                                &shared.policy,
                                &mut local,
                                &org,
                                &request,
                            )
                        };
                        shared.metrics.lock().unwrap().fold(&local);
                        outcome
                    }
                    None => Err(anyhow!("no shard for job {}", kind.name())),
                };
                let _ = reply.send(result);
            }
            Request::Metrics(reply) => {
                let _ = reply.send(shared.metrics.lock().unwrap().clone());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_starts_and_shuts_down() {
        let service =
            CoordinatorService::spawn(Cloud::aws_like(), ServiceConfig::default().with_workers(2));
        let metrics = service.metrics().unwrap();
        assert_eq!(metrics.submissions, 0);
        service.shutdown();
    }

    #[test]
    fn client_outlives_service_with_clean_errors() {
        let service =
            CoordinatorService::spawn(Cloud::aws_like(), ServiceConfig::default().with_workers(1));
        let client = service.client();
        service.shutdown();
        let err = client.metrics();
        assert!(err.is_err(), "stopped service must error, not hang");
    }

    #[test]
    fn submit_without_data_takes_cold_start_path() {
        let service = CoordinatorService::spawn(
            Cloud::aws_like(),
            ServiceConfig::default().with_workers(2).with_seed(7),
        );
        let org = Organization::new("cold");
        let outcome = service.submit(&org, JobRequest::sort(12.0)).unwrap();
        assert!(outcome.model_used.is_none());
        let metrics = service.metrics().unwrap();
        assert_eq!(metrics.submissions, 1);
        assert_eq!(metrics.fallbacks, 1);
        assert_eq!(service.generation(JobKind::Sort), 1, "run was contributed");
        service.shutdown();
    }
}
