//! Integration tests across the full stack: corpus → repositories →
//! coordinator (PJRT models) → configurator → simulated execution →
//! contribution, plus persistence round-trips.

use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::session::Session;
use c3o::coordinator::{Coordinator, Organization};
use c3o::repo::sampling::{coverage_sample, covering_radius};
use c3o::repo::RuntimeDataRepo;
use c3o::runtime::Runtime;
use c3o::workloads::{ExperimentGrid, JobKind};

macro_rules! require_artifacts {
    () => {{
        let dir = Runtime::default_dir();
        if !Runtime::artifacts_available(&dir) {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
        dir
    }};
}

fn slice_grid(kind: JobKind, reps: u32) -> ExperimentGrid {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == kind)
            .collect(),
        repetitions: reps,
    }
}

#[test]
fn corpus_csv_round_trip_all_jobs() {
    let cloud = Cloud::aws_like();
    let corpus = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1().experiments,
        repetitions: 1,
    }
    .execute(&cloud, 77);
    let dir = std::env::temp_dir().join("c3o_e2e_csv");
    for kind in JobKind::all() {
        let repo = corpus.repo_for(kind);
        let path = dir.join(format!("{}.csv", kind.name()));
        repo.save(&path).unwrap();
        let back = RuntimeDataRepo::load(kind, &path).unwrap();
        assert_eq!(back.records(), repo.records(), "{kind:?}");
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn multi_org_collaboration_improves_over_cold_start() {
    // Orgs joining one by one: the first org pays fallback overprovision
    // costs; once enough data is shared, everyone gets model-served
    // configurations that are substantially cheaper.
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut coord = Coordinator::new(cloud, &dir, 11).unwrap();
    coord.min_records = 15;
    coord.retrain_every = 10;

    let mut cold_costs = Vec::new();
    let mut warm_costs = Vec::new();
    for round in 0..30 {
        let org = Organization::new(&format!("org-{}", round % 3));
        let gb = 10.0 + (round % 10) as f64;
        let o = coord
            .submit(&org, &JobRequest::sort(gb).with_target_seconds(2000.0))
            .unwrap();
        if o.model_used.is_none() {
            cold_costs.push(o.actual_cost_usd);
        } else {
            warm_costs.push(o.actual_cost_usd);
        }
    }
    assert!(!cold_costs.is_empty(), "expected some cold-start submissions");
    assert!(!warm_costs.is_empty(), "expected model-served submissions");
    let cold_avg: f64 = cold_costs.iter().sum::<f64>() / cold_costs.len() as f64;
    let warm_avg: f64 = warm_costs.iter().sum::<f64>() / warm_costs.len() as f64;
    assert!(
        warm_avg < 0.7 * cold_avg,
        "model-served ${warm_avg:.3} should be well below cold-start ${cold_avg:.3}"
    );
}

#[test]
fn oversized_repo_triggers_sampling_and_still_trains() {
    // PageRank corpus (282) + enough contributions exceeds nothing, so
    // build an artificially big repo (> 512) and verify training works
    // through the coverage-sampling path.
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut coord = Coordinator::new(cloud.clone(), &dir, 13).unwrap();
    // two differently-seeded corpus executions → distinct configs merge
    let a = slice_grid(JobKind::PageRank, 1).execute(&cloud, 1);
    coord.share(&a.repo_for(JobKind::PageRank)).unwrap();
    // add per-org replicas at distinct feature points to pass 512
    let mut big = RuntimeDataRepo::new(JobKind::PageRank);
    for r in a.repo_for(JobKind::PageRank).records() {
        for d in 0..2 {
            let mut r2 = r.clone();
            r2.job_features[0] += 1.0 + d as f64; // distinct graph sizes
            r2.org = format!("dup-{d}");
            big.contribute(r2).unwrap();
        }
    }
    coord.share(&big).unwrap();
    let repo_len = coord.repo(JobKind::PageRank).unwrap().len();
    assert!(repo_len > 512, "repo should exceed kNN capacity: {repo_len}");

    let org = Organization::new("sampler");
    let o = coord
        .submit(&org, &JobRequest::pagerank(300.0, 0.001).with_target_seconds(2000.0))
        .unwrap();
    assert!(o.model_used.is_some(), "training must succeed via sampling");
}

#[test]
fn sampling_preserves_coverage_on_real_corpus() {
    let cloud = Cloud::aws_like();
    let repo = slice_grid(JobKind::Sgd, 1)
        .execute(&cloud, 3)
        .repo_for(JobKind::Sgd);
    let sample = coverage_sample(&repo, &cloud, 48);
    let radius = covering_radius(&repo, &cloud, &sample);
    // 48 of 180 points must cover the standardized space reasonably
    assert!(radius < 2.0, "covering radius {radius}");
}

#[test]
fn session_serves_concurrent_submitters() {
    // multiple client threads funnel into the single-owner session
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let corpus = slice_grid(JobKind::Grep, 1).execute(&cloud, 5);
    let session = std::sync::Arc::new(std::sync::Mutex::new(Session::spawn(
        cloud,
        dir,
        17,
    )));
    session
        .lock()
        .unwrap()
        .share(corpus.repo_for(JobKind::Grep))
        .unwrap();

    let mut handles = Vec::new();
    for i in 0..4 {
        let session = session.clone();
        handles.push(std::thread::spawn(move || {
            let org = Organization::new(&format!("client-{i}"));
            let req = JobRequest::grep(10.0 + i as f64 * 2.0, 0.1).with_target_seconds(2000.0);
            session.lock().unwrap().submit(&org, req).unwrap()
        }));
    }
    let mut model_served = 0;
    for h in handles {
        let o = h.join().unwrap();
        if o.model_used.is_some() {
            model_served += 1;
        }
    }
    assert_eq!(model_served, 4);
    let metrics = session.lock().unwrap().metrics().unwrap();
    assert_eq!(metrics.submissions, 4);
}

#[test]
fn full_stack_prediction_quality_gate() {
    // The repository-level claim: with the shared corpus, a new org's
    // first-submission predictions land within 35% MAPE across jobs.
    let dir = require_artifacts!();
    let cloud = Cloud::aws_like();
    let mut coord = Coordinator::new(cloud.clone(), &dir, 19).unwrap();
    for kind in [JobKind::Sort, JobKind::Grep, JobKind::PageRank] {
        let corpus = slice_grid(kind, 3).execute(&cloud, 23);
        coord.share(&corpus.repo_for(kind)).unwrap();
    }
    let org = Organization::new("gate");
    let reqs = [
        JobRequest::sort(16.0).with_target_seconds(2000.0),
        JobRequest::grep(13.0, 0.2).with_target_seconds(2000.0),
        JobRequest::pagerank(350.0, 0.001).with_target_seconds(2000.0),
    ];
    let mut errs = Vec::new();
    for req in &reqs {
        let o = coord.submit(&org, req).unwrap();
        assert!(o.model_used.is_some());
        errs.push(o.prediction_error_pct());
    }
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mean < 35.0, "first-submission MAPE {mean}% ({errs:?})");
}
