//! Peer delta-sync: convergent runtime-data exchange between
//! independently-running C3O deployments.
//!
//! The protocol is three [`crate::api`] requests, all spoken through the
//! deployment-agnostic [`Client`] trait, so any two deployments (two
//! services, a service and a sequential coordinator, ...) can gossip:
//!
//! 1. `Watermarks { job }` — read the local per-org high-water marks.
//! 2. `SyncPull { job, watermarks }` — ask a peer for every record of
//!    each org whose watermark differs; the reply also carries the
//!    peer's own marks, so one round trip primes the reverse direction.
//! 3. `SyncPush { job, records }` — apply a delta through merge-level
//!    dedup with deterministic conflict resolution, then canonicalize
//!    the repo order. Idempotent: re-pushing a delta changes nothing.
//!
//! [`sync_job`] performs one full bidirectional exchange; because merge
//! resolution is a deterministic total order, repeated exchanges drive
//! any set of peers to **bitwise-identical** repositories regardless of
//! gossip order (property-tested in `rust/tests/federation.rs`).
//! [`SyncDriver`] runs exchanges on a background thread at a fixed
//! interval — the service-side gossip loop.

use crate::api::{ApiError, Client};
use crate::workloads::JobKind;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Counters from one or more sync exchanges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// `SyncPull` round trips issued.
    pub pulls: u64,
    /// Records applied locally (adds + replacements).
    pub records_in: u64,
    /// Records the peer applied from us.
    pub records_out: u64,
    /// Records shipped over the wire in either direction, applied or
    /// not. `offered > records_in + records_out` means deltas are being
    /// re-shipped without effect — the per-org granularity re-sends a
    /// whole org whenever watermarks differ, e.g. when one peer holds
    /// blind-contributed duplicate configurations the other's merge
    /// dedup will never accept (see
    /// [`delta_for`](crate::repo::RuntimeDataRepo::delta_for)).
    pub offered: u64,
    /// Runtime disagreements surfaced by either side.
    pub conflicts: u64,
    /// Exchanges that failed (driver keeps going; the next tick retries).
    pub errors: u64,
}

impl SyncStats {
    /// Accumulate another stats block.
    pub fn fold(&mut self, other: &SyncStats) {
        self.pulls += other.pulls;
        self.records_in += other.records_in;
        self.records_out += other.records_out;
        self.offered += other.offered;
        self.conflicts += other.conflicts;
        self.errors += other.errors;
    }

    /// True when the exchange *changed* no repository in either
    /// direction — the peers hold converged (merge-equivalent) data for
    /// the synced jobs. Note this is convergence up to merge dedup:
    /// blind local duplicates are contribution history, not shared
    /// state, so they neither block quiescence nor transfer; a
    /// quiescent exchange can still have `offered > 0` for such orgs.
    pub fn quiescent(&self) -> bool {
        self.records_in == 0 && self.records_out == 0
    }
}

/// One full bidirectional exchange for one job kind.
///
/// Inbound: read local watermarks, pull the peer's delta against them,
/// apply it. Outbound: the pull reply carried the peer's marks — compute
/// our delta against those (a local `SyncPull`) and push it. Both
/// directions reuse merge's dedup, so the exchange is idempotent and
/// over-shipping (the per-org delta granularity) is harmless.
pub fn sync_job(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    let mut stats = SyncStats::default();

    // inbound: what does the peer hold that we lack?
    let ours = local.watermarks(job)?;
    let delta = peer.sync_pull(job, ours.watermarks)?;
    stats.pulls += 1;
    let peer_marks = delta.watermarks.clone();
    stats.offered += delta.records.len() as u64;
    if !delta.records.is_empty() {
        let report = local.sync_push(job, delta.records)?;
        stats.records_in += report.changed() as u64;
        stats.conflicts += report.conflicts.len() as u64;
    }

    // outbound: ship the peer what it lacks. Computed *after* the
    // inbound apply, so records we just learned (that the peer already
    // holds) are not echoed back.
    let out = local.sync_pull(job, peer_marks)?;
    stats.pulls += 1;
    stats.offered += out.records.len() as u64;
    if !out.records.is_empty() {
        let report = peer.sync_push(job, out.records)?;
        stats.records_out += report.changed() as u64;
        stats.conflicts += report.conflicts.len() as u64;
    }
    Ok(stats)
}

/// [`sync_job`] over several job kinds, stats folded.
pub fn sync_all(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<SyncStats, ApiError> {
    let mut total = SyncStats::default();
    for &job in jobs {
        total.fold(&sync_job(local, peer, job)?);
    }
    Ok(total)
}

/// Background gossip loop: exchanges deltas between a local deployment
/// and a set of peers at a fixed interval, on its own thread.
///
/// The driver holds plain [`Client`] handles (e.g.
/// [`ServiceClient`](crate::coordinator::service::ServiceClient)s), so
/// it composes with any deployment. A failed exchange is counted and
/// retried on the next tick; a peer answering
/// [`ApiError::Stopped`] ends the loop (the deployment is gone).
pub struct SyncDriver {
    stop: mpsc::Sender<()>,
    handle: Option<JoinHandle<SyncStats>>,
}

impl SyncDriver {
    /// Spawn the loop: one immediate round, then one round per
    /// `interval` until [`SyncDriver::stop`].
    pub fn spawn<C: Client + Send + 'static>(
        mut local: C,
        mut peers: Vec<C>,
        jobs: Vec<JobKind>,
        interval: Duration,
    ) -> SyncDriver {
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let handle = std::thread::spawn(move || {
            let mut total = SyncStats::default();
            loop {
                for peer in peers.iter_mut() {
                    for &job in &jobs {
                        match sync_job(&mut local, peer, job) {
                            Ok(stats) => total.fold(&stats),
                            Err(ApiError::Stopped) => return total,
                            Err(_) => total.errors += 1,
                        }
                    }
                }
                match stop_rx.recv_timeout(interval) {
                    // stop requested, or the driver handle is gone
                    Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => return total,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                }
            }
        });
        SyncDriver {
            stop: stop_tx,
            handle: Some(handle),
        }
    }

    /// Stop the loop and return the accumulated stats.
    pub fn stop(mut self) -> SyncStats {
        self.stop_inner()
    }

    fn stop_inner(&mut self) -> SyncStats {
        let _ = self.stop.send(());
        match self.handle.take() {
            Some(handle) => handle.join().unwrap_or_default(),
            None => SyncStats::default(),
        }
    }
}

impl Drop for SyncDriver {
    fn drop(&mut self) {
        self.stop_inner();
    }
}
