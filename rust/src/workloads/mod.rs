//! The five benchmark jobs of Table I, compiled to simulator stages.
//!
//! Each job is described by a [`JobSpec`] (its dataset characteristics and
//! algorithm parameters — exactly the features the paper's models consume)
//! and compiled by [`JobSpec::stages`] into the stage list the engine
//! executes. The cost model constants in [`WorkloadCosts`] are calibrated
//! so the five jobs reproduce the paper's phenomena *mechanistically*:
//!
//! * **Sort** — disk/network bound two-stage exchange; runtime linear in
//!   dataset size (Fig. 4).
//! * **Grep** — a parallel scan plus a **serial** stage that writes
//!   matched lines back in their original order (the paper's §IV-B4
//!   explanation). The serial fraction grows with the keyword-occurrence
//!   ratio, which is why the ratio changes the scale-out *shape* while
//!   dataset size does not (Fig. 7).
//! * **SGD** — caches the training set (working set = dataset), then runs
//!   gradient iterations; saturating effective-iteration count makes
//!   runtime nonlinear in `max_iterations` (Fig. 5); the per-iteration
//!   working set triggers the memory-bottleneck of Figs. 3/6.
//! * **K-Means** — likewise cached + iterative; iterations grow
//!   super-linearly with `k`, per-iteration cost is `∝ points · k`
//!   (Fig. 5's nonlinear cluster-count curve).
//! * **PageRank** — MB-scale graph, tens of shuffle-heavy supersteps whose
//!   per-iteration fixed overheads dominate: scales poorly (Fig. 6);
//!   iteration count is logarithmic in the convergence criterion
//!   (Fig. 5's nonlinear convergence curve).

pub mod grid;

pub use grid::{Corpus, Experiment, ExperimentGrid};

use crate::sim::stage::Stage;

/// The five distributed dataflow jobs of Table I.
// Ord follows declaration (= Table I) order; used only for stable map
// keys (e.g. the per-job sync breakdowns), never for semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JobKind {
    Sort,
    Grep,
    Sgd,
    KMeans,
    PageRank,
}

impl JobKind {
    /// All kinds, in Table-I order.
    pub fn all() -> [JobKind; 5] {
        [
            JobKind::Sort,
            JobKind::Grep,
            JobKind::Sgd,
            JobKind::KMeans,
            JobKind::PageRank,
        ]
    }

    /// Stable lowercase name used in repositories and CSV files.
    pub fn name(self) -> &'static str {
        match self {
            JobKind::Sort => "sort",
            JobKind::Grep => "grep",
            JobKind::Sgd => "sgd",
            JobKind::KMeans => "kmeans",
            JobKind::PageRank => "pagerank",
        }
    }

    /// Parse from the stable name.
    pub fn parse(s: &str) -> Option<JobKind> {
        JobKind::all().into_iter().find(|k| k.name() == s)
    }

    /// Names of the job-specific feature columns (dataset characteristics
    /// + algorithm parameters), in the order [`JobSpec::job_features`]
    /// emits them. Cluster features (scale-out, machine descriptors) are
    /// appended by the repository layer.
    pub fn feature_names(self) -> &'static [&'static str] {
        match self {
            JobKind::Sort => &["data_gb"],
            JobKind::Grep => &["data_gb", "keyword_ratio"],
            JobKind::Sgd => &["data_gb", "max_iterations"],
            JobKind::KMeans => &["data_gb", "num_clusters", "convergence"],
            JobKind::PageRank => &["graph_mb", "convergence"],
        }
    }
}

impl std::fmt::Display for JobKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A fully parameterized job: kind + dataset characteristics + parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    /// Sort `data_gb` GB of lines of random characters.
    Sort { data_gb: f64 },
    /// Grep for a fixed keyword in `data_gb` GB of lines; `keyword_ratio`
    /// is the fraction of lines containing it (the characteristic the
    /// paper says matters more than the keyword itself).
    Grep { data_gb: f64, keyword_ratio: f64 },
    /// Logistic-regression SGD over `data_gb` GB of labeled points.
    Sgd { data_gb: f64, max_iterations: u32 },
    /// K-Means over `data_gb` GB of points.
    KMeans {
        data_gb: f64,
        num_clusters: u32,
        convergence: f64,
    },
    /// PageRank over a `graph_mb` MB edge list.
    PageRank { graph_mb: f64, convergence: f64 },
}

impl JobSpec {
    pub fn sort(data_gb: f64) -> Self {
        JobSpec::Sort { data_gb }
    }
    pub fn grep(data_gb: f64, keyword_ratio: f64) -> Self {
        JobSpec::Grep {
            data_gb,
            keyword_ratio,
        }
    }
    pub fn sgd(data_gb: f64, max_iterations: u32) -> Self {
        JobSpec::Sgd {
            data_gb,
            max_iterations,
        }
    }
    pub fn kmeans(data_gb: f64, num_clusters: u32, convergence: f64) -> Self {
        JobSpec::KMeans {
            data_gb,
            num_clusters,
            convergence,
        }
    }
    pub fn pagerank(graph_mb: f64, convergence: f64) -> Self {
        JobSpec::PageRank {
            graph_mb,
            convergence,
        }
    }

    /// Which of the five jobs this is.
    pub fn kind(&self) -> JobKind {
        match self {
            JobSpec::Sort { .. } => JobKind::Sort,
            JobSpec::Grep { .. } => JobKind::Grep,
            JobSpec::Sgd { .. } => JobKind::Sgd,
            JobSpec::KMeans { .. } => JobKind::KMeans,
            JobSpec::PageRank { .. } => JobKind::PageRank,
        }
    }

    /// Job-specific feature values, aligned with
    /// [`JobKind::feature_names`]. Convergence criteria are emitted as
    /// `-log10` so the feature scales comparably to the others.
    pub fn job_features(&self) -> Vec<f64> {
        match *self {
            JobSpec::Sort { data_gb } => vec![data_gb],
            JobSpec::Grep {
                data_gb,
                keyword_ratio,
            } => vec![data_gb, keyword_ratio],
            JobSpec::Sgd {
                data_gb,
                max_iterations,
            } => vec![data_gb, max_iterations as f64],
            JobSpec::KMeans {
                data_gb,
                num_clusters,
                convergence,
            } => vec![data_gb, num_clusters as f64, -convergence.log10()],
            JobSpec::PageRank {
                graph_mb,
                convergence,
            } => vec![graph_mb, -convergence.log10()],
        }
    }

    /// Compile the job into simulator stages using the default cost model.
    pub fn stages(&self) -> Vec<Stage> {
        self.stages_with(&WorkloadCosts::default())
    }

    /// Compile with explicit cost constants (calibration ablations).
    pub fn stages_with(&self, c: &WorkloadCosts) -> Vec<Stage> {
        match *self {
            JobSpec::Sort { data_gb } => sort_stages(data_gb, c),
            JobSpec::Grep {
                data_gb,
                keyword_ratio,
            } => grep_stages(data_gb, keyword_ratio, c),
            JobSpec::Sgd {
                data_gb,
                max_iterations,
            } => sgd_stages(data_gb, max_iterations, c),
            JobSpec::KMeans {
                data_gb,
                num_clusters,
                convergence,
            } => kmeans_stages(data_gb, num_clusters, convergence, c),
            JobSpec::PageRank {
                graph_mb,
                convergence,
            } => pagerank_stages(graph_mb, convergence, c),
        }
    }
}

/// Cost-model constants (normalized core-seconds per MB, etc.).
///
/// These play the role of the real systems' instruction mix: they were
/// hand-calibrated once so that absolute runtimes land in the same band
/// as the paper's EMR runs (minutes for 10–30 GB inputs on 2–12 nodes)
/// and all qualitative figure shapes reproduce. They are *not* fitted per
/// experiment.
#[derive(Debug, Clone)]
pub struct WorkloadCosts {
    /// HDFS-like input partition size, MB (tasks = size / partition).
    pub partition_mb: f64,
    pub sort_map_cpu_per_mb: f64,
    pub sort_sort_cpu_per_mb: f64,
    pub grep_scan_cpu_per_mb: f64,
    pub grep_write_cpu_per_mb: f64,
    pub sgd_parse_cpu_per_mb: f64,
    pub sgd_iter_cpu_per_mb: f64,
    /// Iterations at which SGD converges (saturates `max_iterations`):
    /// `eff = min(max_iter, base + slope · data_gb)`.
    pub sgd_converge_base: f64,
    pub sgd_converge_per_gb: f64,
    pub kmeans_parse_cpu_per_mb: f64,
    /// Per-iteration CPU is `kmeans_iter_cpu_per_mb_k · mb · k`.
    pub kmeans_iter_cpu_per_mb_k: f64,
    /// K-Means iterations: `round(kmeans_iter_scale · k^1.5 · log10(1/conv)/3)`.
    pub kmeans_iter_scale: f64,
    pub pagerank_build_cpu_per_mb: f64,
    pub pagerank_iter_cpu_per_mb: f64,
    /// PageRank damping factor: iterations = `ln(conv)/ln(damping)`.
    pub pagerank_damping: f64,
    /// PageRank in-memory working set multiplier over the edge list.
    pub pagerank_ws_factor: f64,
}

impl Default for WorkloadCosts {
    fn default() -> Self {
        WorkloadCosts {
            partition_mb: 128.0,
            sort_map_cpu_per_mb: 0.003,
            sort_sort_cpu_per_mb: 0.008,
            grep_scan_cpu_per_mb: 0.002,
            grep_write_cpu_per_mb: 0.0005,
            sgd_parse_cpu_per_mb: 0.004,
            sgd_iter_cpu_per_mb: 0.0025,
            sgd_converge_base: 48.0,
            sgd_converge_per_gb: 0.3,
            kmeans_parse_cpu_per_mb: 0.004,
            kmeans_iter_cpu_per_mb_k: 0.0004,
            kmeans_iter_scale: 1.8,
            pagerank_build_cpu_per_mb: 0.02,
            pagerank_iter_cpu_per_mb: 0.012,
            pagerank_damping: 0.85,
            pagerank_ws_factor: 3.0,
        }
    }
}

fn tasks_for(mb: f64, c: &WorkloadCosts) -> u32 {
    ((mb / c.partition_mb).ceil() as u32).max(1)
}

fn sort_stages(data_gb: f64, c: &WorkloadCosts) -> Vec<Stage> {
    let mb = data_gb * 1024.0;
    let tasks = tasks_for(mb, c);
    vec![
        // Read input, range-partition, write shuffle files.
        Stage::parallel("sort:map", tasks)
            .with_cpu(c.sort_map_cpu_per_mb * mb)
            .with_disk(mb, mb),
        // Fetch (all-to-all), sort partitions, write output.
        Stage::shuffle("sort:reduce", tasks)
            .with_cpu(c.sort_sort_cpu_per_mb * mb)
            .with_disk(mb, mb)
            .with_shuffle(mb),
    ]
}

fn grep_stages(data_gb: f64, keyword_ratio: f64, c: &WorkloadCosts) -> Vec<Stage> {
    assert!((0.0..=1.0).contains(&keyword_ratio), "ratio out of range");
    let mb = data_gb * 1024.0;
    let matched_mb = keyword_ratio * mb;
    let tasks = tasks_for(mb, c);
    vec![
        // Parallel keyword scan.
        Stage::parallel("grep:scan", tasks)
            .with_cpu(c.grep_scan_cpu_per_mb * mb)
            .with_disk(mb, 0.0),
        // Write matched lines back *in original order* — sequential
        // (paper §IV-B4): the Amdahl term whose size tracks the ratio.
        Stage::serial("grep:write_matches")
            .with_cpu(c.grep_write_cpu_per_mb * matched_mb)
            .with_disk(0.0, matched_mb),
    ]
}

/// Effective SGD iterations: converges at `base + slope·GB` even if
/// `max_iterations` allows more — the saturation behind Fig. 5.
pub fn sgd_effective_iterations(data_gb: f64, max_iterations: u32, c: &WorkloadCosts) -> u32 {
    let converge = c.sgd_converge_base + c.sgd_converge_per_gb * data_gb;
    (max_iterations as f64).min(converge).round().max(1.0) as u32
}

fn sgd_stages(data_gb: f64, max_iterations: u32, c: &WorkloadCosts) -> Vec<Stage> {
    let mb = data_gb * 1024.0;
    let tasks = tasks_for(mb, c);
    let iters = sgd_effective_iterations(data_gb, max_iterations, c);
    let mut stages = vec![Stage::parallel("sgd:load_cache", tasks)
        .with_cpu(c.sgd_parse_cpu_per_mb * mb)
        .with_disk(mb, 0.0)
        .with_working_set(mb)];
    for i in 0..iters {
        stages.push(
            Stage::iteration(&format!("sgd:iter{i}"), tasks)
                .with_cpu(c.sgd_iter_cpu_per_mb * mb)
                // gradient all-reduce: tiny but nonzero traffic
                .with_shuffle(2.0)
                .with_working_set(mb),
        );
    }
    stages
}

/// K-Means iterations to convergence: grows super-linearly with `k` and
/// logarithmically with the convergence criterion.
pub fn kmeans_iterations(num_clusters: u32, convergence: f64, c: &WorkloadCosts) -> u32 {
    let conv_factor = (-convergence.log10()) / 3.0; // 1.0 at the paper's 0.001
    (c.kmeans_iter_scale * (num_clusters as f64).powf(1.5) * conv_factor)
        .round()
        .max(1.0) as u32
}

fn kmeans_stages(data_gb: f64, num_clusters: u32, convergence: f64, c: &WorkloadCosts) -> Vec<Stage> {
    assert!(num_clusters >= 1);
    assert!(convergence > 0.0 && convergence < 1.0);
    let mb = data_gb * 1024.0;
    let tasks = tasks_for(mb, c);
    let iters = kmeans_iterations(num_clusters, convergence, c);
    let mut stages = vec![Stage::parallel("kmeans:load_cache", tasks)
        .with_cpu(c.kmeans_parse_cpu_per_mb * mb)
        .with_disk(mb, 0.0)
        .with_working_set(mb)];
    for i in 0..iters {
        stages.push(
            Stage::iteration(&format!("kmeans:iter{i}"), tasks)
                .with_cpu(c.kmeans_iter_cpu_per_mb_k * mb * num_clusters as f64)
                // centroid broadcast + partial-sum aggregation
                .with_shuffle(1.0 + 0.05 * num_clusters as f64)
                .with_working_set(mb),
        );
    }
    stages
}

/// PageRank iterations from the power-method contraction rate.
pub fn pagerank_iterations(convergence: f64, c: &WorkloadCosts) -> u32 {
    assert!(convergence > 0.0 && convergence < 1.0);
    (convergence.ln() / c.pagerank_damping.ln()).ceil().max(1.0) as u32
}

fn pagerank_stages(graph_mb: f64, convergence: f64, c: &WorkloadCosts) -> Vec<Stage> {
    // Small graphs: finer partitions, floor of 16 tasks.
    let tasks = ((graph_mb / 32.0).ceil() as u32).max(16);
    let iters = pagerank_iterations(convergence, c);
    let ws = c.pagerank_ws_factor * graph_mb;
    let mut stages = vec![Stage::parallel("pagerank:load", tasks)
        .with_cpu(c.pagerank_build_cpu_per_mb * graph_mb)
        .with_disk(graph_mb, 0.0)
        .with_working_set(ws)];
    for i in 0..iters {
        stages.push(
            Stage::iteration(&format!("pagerank:iter{i}"), tasks)
                .with_cpu(c.pagerank_iter_cpu_per_mb * graph_mb)
                // rank contributions along every edge, both directions
                .with_shuffle(2.0 * graph_mb)
                .with_working_set(ws),
        );
    }
    stages
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::catalog::aws_like_catalog;
    use crate::cloud::MachineType;
    use crate::sim::{SimConfig, Simulator};
    use crate::util::rng::Pcg32;

    fn machine(name: &str) -> MachineType {
        aws_like_catalog()
            .into_iter()
            .find(|m| m.name == name)
            .unwrap()
    }

    fn run(spec: &JobSpec, machine_name: &str, n: u32) -> f64 {
        let sim = Simulator::new(SimConfig::deterministic());
        let mut rng = Pcg32::new(7);
        sim.run(&machine(machine_name), n, &spec.stages(), &mut rng)
            .runtime_s
    }

    #[test]
    fn kind_round_trip() {
        for k in JobKind::all() {
            assert_eq!(JobKind::parse(k.name()), Some(k));
        }
        assert_eq!(JobKind::parse("wordcount"), None);
    }

    #[test]
    fn feature_names_align_with_values() {
        let specs = [
            JobSpec::sort(15.0),
            JobSpec::grep(15.0, 0.1),
            JobSpec::sgd(20.0, 50),
            JobSpec::kmeans(15.0, 5, 0.001),
            JobSpec::pagerank(300.0, 0.001),
        ];
        for s in &specs {
            assert_eq!(
                s.job_features().len(),
                s.kind().feature_names().len(),
                "{:?}",
                s.kind()
            );
        }
    }

    #[test]
    fn sort_runtime_linear_in_size() {
        // Fig. 4: double the data, double the (overhead-corrected) runtime.
        let t10 = run(&JobSpec::sort(10.0), "m5.xlarge", 4);
        let t20 = run(&JobSpec::sort(20.0), "m5.xlarge", 4);
        let overhead = 12.0 + 2.0 * (0.9 + 0.2);
        let ratio = (t20 - overhead) / (t10 - overhead);
        assert!((ratio - 2.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn sort_runtime_band_is_plausible() {
        // 20 GB on 4× m5.xlarge: minutes, not seconds or hours.
        let t = run(&JobSpec::sort(20.0), "m5.xlarge", 4);
        assert!((60.0..900.0).contains(&t), "t = {t}");
    }

    #[test]
    fn grep_serial_fraction_tracks_ratio() {
        // Fig. 7: scale-out shape changes with ratio, not size.
        let curve = |spec: &JobSpec| -> Vec<f64> {
            [2u32, 4, 8, 12].iter().map(|&n| run(spec, "m5.xlarge", n)).collect()
        };
        let lo = curve(&JobSpec::grep(15.0, 0.01));
        let hi = curve(&JobSpec::grep(15.0, 0.3));
        // high ratio flattens the curve: relative speedup 2->12 is smaller
        let sp_lo = lo[0] / lo[3];
        let sp_hi = hi[0] / hi[3];
        assert!(sp_lo > sp_hi + 0.3, "lo {sp_lo} hi {sp_hi}");
        // size invariance: normalized 10 vs 20 GB curves diverge much less
        // than the ratio-varied curves do (the Fig. 7 claim is relative)
        let a = curve(&JobSpec::grep(10.0, 0.1));
        let b = curve(&JobSpec::grep(20.0, 0.1));
        let div_size = crate::util::stats::curve_shape_divergence(&a, &b);
        let div_ratio = crate::util::stats::curve_shape_divergence(&lo, &hi);
        assert!(
            div_size < 0.5 * div_ratio,
            "size divergence {div_size} vs ratio divergence {div_ratio}"
        );
    }

    #[test]
    fn sgd_iterations_saturate() {
        let c = WorkloadCosts::default();
        assert_eq!(sgd_effective_iterations(10.0, 1, &c), 1);
        assert_eq!(sgd_effective_iterations(10.0, 25, &c), 25);
        let sat = sgd_effective_iterations(10.0, 100, &c);
        assert_eq!(sat, 51); // 48 + 0.3*10
        assert_eq!(sgd_effective_iterations(10.0, 80, &c), 51);
    }

    #[test]
    fn sgd_memory_bottleneck_at_two_nodes() {
        // Fig. 6: speedup(2 -> 4) > 2 for the big dataset on m5.xlarge.
        let spec = JobSpec::sgd(30.0, 100);
        let t2 = run(&spec, "m5.xlarge", 2);
        let t4 = run(&spec, "m5.xlarge", 4);
        assert!(t2 / t4 > 2.0, "speedup {}", t2 / t4);
        // and the r5 family does NOT bottleneck at 2 nodes
        let r2 = run(&spec, "r5.xlarge", 2);
        let r4 = run(&spec, "r5.xlarge", 4);
        assert!(r2 / r4 < 2.2, "r5 speedup {}", r2 / r4);
    }

    #[test]
    fn kmeans_nonlinear_in_k() {
        // Fig. 5: runtime grows faster than linearly in k.
        let t3 = run(&JobSpec::kmeans(15.0, 3, 0.001), "m5.xlarge", 4);
        let t9 = run(&JobSpec::kmeans(15.0, 9, 0.001), "m5.xlarge", 4);
        // linear-in-k would give < 3 once fixed overheads are counted;
        // iterations growing as k^1.35 push it well past that.
        let tripled = t9 / t3;
        assert!(tripled > 3.2, "k 3->9 runtime ratio {tripled} (want superlinear)");
    }

    #[test]
    fn pagerank_iterations_log_in_convergence() {
        let c = WorkloadCosts::default();
        let i1 = pagerank_iterations(0.01, &c);
        let i2 = pagerank_iterations(0.0001, &c);
        assert_eq!(i1, 29);
        assert_eq!(i2, 57);
        // halving log-convergence doubles iterations — nonlinear in conv.
        assert!((i2 as f64 / i1 as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn pagerank_scales_poorly() {
        // Fig. 6: speedup from 2 to 12 nodes stays small.
        let spec = JobSpec::pagerank(300.0, 0.001);
        let t2 = run(&spec, "m5.xlarge", 2);
        let t12 = run(&spec, "m5.xlarge", 12);
        let speedup = t2 / t12;
        assert!(speedup < 2.0, "pagerank speedup {speedup} (want < 2 over 6x nodes)");
        // while sort over the same node range speeds up much more
        let s2 = run(&JobSpec::sort(15.0), "m5.xlarge", 2);
        let s12 = run(&JobSpec::sort(15.0), "m5.xlarge", 12);
        assert!(s2 / s12 > speedup + 1.0, "sort {} vs pagerank {}", s2 / s12, speedup);
    }

    #[test]
    fn all_stage_lists_validate() {
        let specs = [
            JobSpec::sort(10.0),
            JobSpec::grep(20.0, 0.3),
            JobSpec::sgd(30.0, 100),
            JobSpec::kmeans(20.0, 9, 0.001),
            JobSpec::pagerank(440.0, 0.0001),
        ];
        for s in &specs {
            for st in s.stages() {
                st.validate().unwrap();
            }
        }
    }

    #[test]
    #[should_panic(expected = "ratio out of range")]
    fn grep_bad_ratio_panics() {
        JobSpec::grep(10.0, 1.5).stages();
    }
}
