"""AOT export: lower the L2 graphs to HLO text artifacts for the Rust
runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. Lowering goes through
stablehlo -> XlaComputation with ``return_tuple=True``; the Rust side
unwraps with ``to_tuple1()`` / ``to_tuple()``.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


#: artifact name -> (function, example-args factory)
ARTIFACTS = {
    "knn_predict": (model.knn_predict, model.knn_example_args),
    "optimistic_predict": (
        model.optimistic_predict,
        model.optimistic_predict_example_args,
    ),
    "optimistic_train": (
        model.optimistic_train_step,
        model.optimistic_train_example_args,
    ),
}


def manifest_rows():
    """Shape constants the Rust runtime must agree on, as (key, value)."""
    return [
        ("feature_dim", model.F),
        ("knn_train_rows", model.KNN_T),
        ("knn_query_rows", model.KNN_Q),
        ("knn_k", model.KNN_K),
        ("opt_batch", model.OPT_BATCH),
        ("opt_params", model.OPT_PARAMS),
    ]


def export_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, args_fn) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*args_fn())
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")
    manifest = os.path.join(out_dir, "manifest.csv")
    with open(manifest, "w") as f:
        f.write("key,value\n")
        for k, v in manifest_rows():
            f.write(f"{k},{v}\n")
    print(f"wrote manifest       {manifest}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # kept for the Makefile's single-file dependency tracking
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    export_all(out_dir or ".")


if __name__ == "__main__":
    main()
