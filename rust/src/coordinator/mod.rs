//! The collaboration coordinator — the C3O system runtime (paper Fig. 1/2).
//!
//! The coordination stack is **sharded by job kind** and layered so one
//! submission pipeline serves every deployment shape:
//!
//! * [`shard`] — a [`JobShard`](shard::JobShard) per [`JobKind`] owns that
//!   kind's shared runtime-data repository, its RNG stream, and its
//!   **generation-cached model**: trained models are keyed by the repo's
//!   monotone generation counter and retrained only when the shared
//!   corpus actually advanced past the retrain threshold. Model training
//!   uses **dynamic model selection** (§V-C) between the pessimistic and
//!   optimistic families; repositories beyond the kNN capacity train on a
//!   coverage-preserving sample (§III-C).
//! * [`Coordinator`] (this module) — the sequential facade: one engine,
//!   plain shards, the ergonomic API for examples, benches, and the CLI.
//! * [`session`] — the legacy single-worker deployment: one thread owns a
//!   whole coordinator behind an **ordered** request/reply channel pair.
//!   Kept as the throughput baseline the service is benchmarked against.
//! * [`service`] — the concurrent deployment: shards behind mutexes, `N`
//!   worker threads (PJRT-owning workers pinned to their runtime,
//!   native-fallback workers free-floating), and **per-request reply
//!   channels** so concurrent clients never block on each other's
//!   submissions.
//!
//! One submission flows: route to the kind's shard → ensure a
//! generation-fresh model → score **all** `machine × scaleout` candidates
//! in one featurized batch and pick the cheapest configuration meeting
//! the target → provision (paying the EMR-like delay) and run on the
//! dataflow simulator → contribute the measurement back to the shared
//! repository, closing the collaborative loop. Cold-start submissions
//! (too little shared data) fall back to conservative overprovisioning —
//! and the run they contribute shrinks that window for everyone.
//!
//! Model execution is backend-agnostic ([`crate::models::ModelTrainer`]):
//! PJRT-compiled artifacts when available, bit-compatible pure-Rust
//! engines otherwise, so the whole stack works on a bare `cargo test`.

pub mod service;
pub mod session;
pub mod shard;

pub use service::{CoordinatorService, ServiceClient, ServiceConfig};
pub use shard::{JobShard, ShardPolicy};

use crate::cloud::Cloud;
use crate::configurator::{ClusterChoice, JobRequest};
use crate::models::selection::SelectionReport;
use crate::models::{Engine, ModelKind, ModelTrainer};
use crate::repo::RuntimeDataRepo;
use crate::util::rng::Pcg32;
use crate::workloads::JobKind;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;

/// A participating organization (provenance + its usual submission niche).
#[derive(Debug, Clone, PartialEq)]
pub struct Organization {
    pub name: String,
}

impl Organization {
    pub fn new(name: &str) -> Self {
        Organization {
            name: name.to_string(),
        }
    }
}

/// The outcome of one submitted job, end to end.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub org: String,
    pub job: JobKind,
    /// The configuration decision (None when the cold-start fallback ran).
    pub choice: Option<ClusterChoice>,
    pub machine: String,
    pub scaleout: u32,
    pub model_used: Option<ModelKind>,
    pub predicted_runtime_s: f64,
    pub actual_runtime_s: f64,
    /// Cluster cost of the actual run (incl. provisioning).
    pub actual_cost_usd: f64,
    pub provisioning_s: f64,
    pub target_s: Option<f64>,
    pub met_target: bool,
}

impl JobOutcome {
    /// Absolute percentage error of the runtime prediction (NaN for
    /// fallback runs without a prediction).
    pub fn prediction_error_pct(&self) -> f64 {
        if self.predicted_runtime_s.is_nan() {
            f64::NAN
        } else {
            100.0 * ((self.predicted_runtime_s - self.actual_runtime_s) / self.actual_runtime_s).abs()
        }
    }
}

/// Aggregate coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub submissions: u64,
    pub fallbacks: u64,
    /// Model (re)trainings actually performed.
    pub retrains: u64,
    /// Submissions served from a generation-fresh cached model (the
    /// observable complement of `retrains`: no new shared data ⇒ only
    /// this counter moves).
    pub cache_hits: u64,
    pub targets_given: u64,
    pub targets_met: u64,
    pub total_cost_usd: f64,
    /// Sum + count of absolute percentage errors (model-served runs).
    pub ape_sum: f64,
    pub ape_count: u64,
}

impl Metrics {
    pub fn mean_prediction_error_pct(&self) -> f64 {
        if self.ape_count == 0 {
            f64::NAN
        } else {
            self.ape_sum / self.ape_count as f64
        }
    }

    pub fn target_hit_rate(&self) -> f64 {
        if self.targets_given == 0 {
            f64::NAN
        } else {
            self.targets_met as f64 / self.targets_given as f64
        }
    }

    /// Fold another metrics block into this one (the service workers
    /// stage per-request metrics locally and fold them in afterwards).
    pub fn fold(&mut self, other: &Metrics) {
        self.submissions += other.submissions;
        self.fallbacks += other.fallbacks;
        self.retrains += other.retrains;
        self.cache_hits += other.cache_hits;
        self.targets_given += other.targets_given;
        self.targets_met += other.targets_met;
        self.total_cost_usd += other.total_cost_usd;
        self.ape_sum += other.ape_sum;
        self.ape_count += other.ape_count;
    }
}

/// The sequential C3O coordinator: one model engine over per-job-kind
/// shards. The concurrent deployment of the same pipeline is
/// [`service::CoordinatorService`].
pub struct Coordinator {
    cloud: Cloud,
    engine: Engine,
    shards: HashMap<JobKind, JobShard>,
    /// Retrain when the repo generation advanced this far since the last
    /// training.
    pub retrain_every: u64,
    /// Minimum records before the model path activates (cold-start
    /// threshold).
    pub min_records: usize,
    /// CV folds for dynamic selection.
    pub cv_folds: usize,
    metrics: Metrics,
    seed_rng: Pcg32,
}

impl Coordinator {
    /// Build a coordinator over a cloud and an artifacts directory. Uses
    /// the PJRT backend when the artifacts load, the native engines
    /// otherwise — construction itself cannot fail on a missing runtime.
    pub fn new(cloud: Cloud, artifacts_dir: &Path, seed: u64) -> Result<Coordinator> {
        Ok(Coordinator::with_engine(
            cloud,
            Engine::auto(artifacts_dir),
            seed,
        ))
    }

    /// Build over an explicit model engine.
    pub fn with_engine(cloud: Cloud, engine: Engine, seed: u64) -> Coordinator {
        let policy = ShardPolicy::default();
        Coordinator {
            cloud,
            engine,
            shards: HashMap::new(),
            retrain_every: policy.retrain_every,
            min_records: policy.min_records,
            cv_folds: policy.cv_folds,
            metrics: Metrics::default(),
            seed_rng: Pcg32::new(seed),
        }
    }

    pub fn cloud(&self) -> &Cloud {
        &self.cloud
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Which model backend serves this coordinator (`"pjrt"`/`"native"`).
    pub fn backend(&self) -> &'static str {
        self.engine.backend()
    }

    /// The shared repository for a job (empty if nothing shared yet).
    pub fn repo(&self, job: JobKind) -> Option<&RuntimeDataRepo> {
        self.shards.get(&job).map(|s| s.repo())
    }

    /// Current repo generation for a job (0 if nothing shared yet).
    pub fn generation(&self, job: JobKind) -> u64 {
        self.shards.get(&job).map_or(0, |s| s.generation())
    }

    /// Latest selection report for a job's model, if trained.
    pub fn selection_report(&self, job: JobKind) -> Option<&SelectionReport> {
        self.shards.get(&job).and_then(|s| s.selection_report())
    }

    fn policy(&self) -> ShardPolicy {
        ShardPolicy {
            retrain_every: self.retrain_every,
            min_records: self.min_records,
            cv_folds: self.cv_folds,
        }
    }

    fn shard_mut(&mut self, job: JobKind) -> &mut JobShard {
        if !self.shards.contains_key(&job) {
            let seed = self.seed_rng.next_u64();
            self.shards.insert(job, JobShard::new(job, seed));
        }
        self.shards.get_mut(&job).expect("just inserted")
    }

    /// Merge externally shared data (e.g. the public corpus) into the
    /// job's repository — "users can contribute their generated runtime
    /// data" (§III-A). Returns records actually added.
    pub fn share(&mut self, repo: &RuntimeDataRepo) -> Result<usize> {
        self.shard_mut(repo.job()).share(repo)
    }

    /// Full submission loop for one job request.
    pub fn submit(&mut self, org: &Organization, request: &JobRequest) -> Result<JobOutcome> {
        let policy = self.policy();
        let job = request.kind();
        self.shard_mut(job); // ensure the shard exists
        let shard = self.shards.get_mut(&job).expect("just ensured");
        shard.submit(
            &mut self.engine,
            &self.cloud,
            &policy,
            &mut self.metrics,
            org,
            request,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::workloads::ExperimentGrid;

    fn corpus_repo(cloud: &Cloud, kind: JobKind) -> RuntimeDataRepo {
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == kind)
                .collect(),
            repetitions: 3,
        };
        grid.execute(cloud, 21).repo_for(kind)
    }

    // No artifacts gate: Engine::auto falls back to the native models, so
    // the full coordinator loop runs on a bare `cargo test`.
    fn coordinator(cloud: Cloud, seed: u64) -> Coordinator {
        Coordinator::new(cloud, &Runtime::default_dir(), seed).unwrap()
    }

    #[test]
    fn cold_start_falls_back_then_model_takes_over() {
        let cloud = Cloud::aws_like();
        let mut coord = coordinator(cloud, 1);
        coord.min_records = 5;
        coord.retrain_every = 5;
        let org = Organization::new("lab-a");
        // no shared data yet: fallback
        let o1 = coord.submit(&org, &JobRequest::sort(12.0)).unwrap();
        assert!(o1.model_used.is_none());
        assert_eq!(coord.metrics().fallbacks, 1);
        // a few more submissions build up the repo
        for gb in [10.0, 14.0, 16.0, 18.0] {
            coord.submit(&org, &JobRequest::sort(gb)).unwrap();
        }
        // now the model path must engage
        let o = coord.submit(&org, &JobRequest::sort(15.0)).unwrap();
        assert!(o.model_used.is_some(), "model should be trained now");
        assert!(coord.metrics().retrains >= 1);
        assert!(o.predicted_runtime_s > 0.0);
    }

    #[test]
    fn shared_corpus_enables_first_submission_model() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Grep);
        let mut coord = coordinator(cloud, 2);
        let added = coord.share(&repo).unwrap();
        assert_eq!(added, 162);
        let org = Organization::new("new-org");
        let req = JobRequest::grep(15.0, 0.1).with_target_seconds(500.0);
        let o = coord.submit(&org, &req).unwrap();
        // the very first submission is model-served — the paper's pitch
        assert!(o.model_used.is_some());
        assert!(o.prediction_error_pct() < 60.0, "err {}", o.prediction_error_pct());
        // and the new org's run landed in the shared repo
        let repo_after = coord.repo(JobKind::Grep).unwrap();
        assert_eq!(repo_after.len(), 163);
        assert!(repo_after.organizations().contains("new-org"));
    }

    #[test]
    fn retrain_cadence_respected() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 3);
        coord.retrain_every = 4;
        coord.share(&repo).unwrap();
        let org = Organization::new("o");
        for i in 0..9 {
            coord
                .submit(&org, &JobRequest::sort(10.0 + i as f64))
                .unwrap();
        }
        // initial train + retrains every 4 contributions: 1 + 2
        assert_eq!(coord.metrics().retrains, 3, "{:?}", coord.metrics());
    }

    #[test]
    fn retraining_is_gated_by_repo_generation() {
        // The model cache is keyed by the repo generation: with no new
        // shared data past the threshold, repeated submissions must
        // trigger zero retrains — only cache hits.
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 5);
        coord.retrain_every = 1000; // far beyond this test's contributions
        coord.share(&repo).unwrap();
        let org = Organization::new("steady");
        coord.submit(&org, &JobRequest::sort(12.0)).unwrap();
        assert_eq!(coord.metrics().retrains, 1, "initial training only");

        // re-sharing the identical corpus adds nothing and must not move
        // the generation
        let gen = coord.generation(JobKind::Sort);
        assert_eq!(coord.share(&repo).unwrap(), 0);
        assert_eq!(coord.generation(JobKind::Sort), gen);

        for i in 0..6 {
            let o = coord
                .submit(&org, &JobRequest::sort(11.0 + i as f64))
                .unwrap();
            assert!(o.model_used.is_some());
        }
        let m = coord.metrics();
        assert_eq!(m.retrains, 1, "no retrain without new shared data: {m:?}");
        assert_eq!(m.cache_hits, 6, "every further submission is a cache hit");
    }

    #[test]
    fn metrics_accumulate() {
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = coordinator(cloud, 4);
        coord.share(&repo).unwrap();
        let org = Organization::new("o");
        let req = JobRequest::sort(15.0).with_target_seconds(2000.0);
        let o = coord.submit(&org, &req).unwrap();
        assert!(o.met_target, "loose target should be met");
        let m = coord.metrics();
        assert_eq!(m.submissions, 1);
        assert_eq!(m.targets_given, 1);
        assert_eq!(m.targets_met, 1);
        assert!(m.total_cost_usd > 0.0);
        assert!(m.mean_prediction_error_pct().is_finite());
    }
}
