//! Featurization: turn shared [`RuntimeRecord`]s into model-ready
//! matrices.
//!
//! The paper (§IV) lists the runtime-influencing factors a black-box model
//! must see: the machine type and scale-out of the cluster, key dataset
//! characteristics, and algorithm parameters. Machine types are encoded
//! by their *descriptors* (vCPUs, memory, relative core speed, disk and
//! network bandwidth) rather than one-hot names, so a model trained on
//! collaboratively shared data can generalize to machine types that no
//! contributor has measured — the heterogeneous-context requirement of §V.
//!
//! All features and the target are standardized; runtimes are modeled in
//! log space (multiplicative errors, matching MAPE evaluation).

use crate::cloud::Cloud;
use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::util::matrix::MatF32;

/// Fitted feature-space metadata: column names and z-scoring parameters,
/// learned from a training repo and applied to queries.
#[derive(Debug, Clone)]
pub struct FeatureSpace {
    pub names: Vec<String>,
    pub mean: Vec<f32>,
    pub sd: Vec<f32>,
    /// Mean/sd of log-runtime (target scaling).
    pub y_mean: f32,
    pub y_sd: f32,
}

impl FeatureSpace {
    /// Number of feature columns.
    pub fn dim(&self) -> usize {
        self.names.len()
    }

    /// Map a standardized log-runtime prediction back to seconds.
    pub fn unscale_runtime(&self, y_std: f32) -> f64 {
        ((y_std * self.y_sd + self.y_mean) as f64).exp()
    }

    /// Map a runtime in seconds to the standardized log target.
    pub fn scale_runtime(&self, runtime_s: f64) -> f32 {
        ((runtime_s.ln() as f32) - self.y_mean) / self.y_sd
    }
}

/// Builds feature matrices from records, resolving machine descriptors
/// against a cloud catalog.
#[derive(Debug, Clone)]
pub struct Featurizer<'a> {
    cloud: &'a Cloud,
}

/// Machine-descriptor column names appended after the job features.
pub const CLUSTER_FEATURES: [&str; 6] = [
    "scaleout",
    "m_vcpus",
    "m_memory_gib",
    "m_cpu_perf",
    "m_disk_mb_s",
    "m_net_mb_s",
];

impl<'a> Featurizer<'a> {
    pub fn new(cloud: &'a Cloud) -> Self {
        Featurizer { cloud }
    }

    /// Raw (unscaled) feature row for a record-shaped query.
    ///
    /// # Panics
    /// Panics if the machine type is not in the catalog.
    pub fn raw_row(&self, machine: &str, scaleout: u32, job_features: &[f64]) -> Vec<f32> {
        let m = self
            .cloud
            .machine(machine)
            .unwrap_or_else(|| panic!("unknown machine type {machine:?}"));
        let mut row: Vec<f32> = job_features.iter().map(|&f| f as f32).collect();
        row.extend_from_slice(&[
            scaleout as f32,
            m.vcpus as f32,
            m.memory_gib as f32,
            m.cpu_perf as f32,
            m.disk_mb_s as f32,
            m.net_mb_s as f32,
        ]);
        row
    }

    /// Fit a [`FeatureSpace`] on a repo and return the standardized
    /// feature matrix + standardized log-runtime targets.
    ///
    /// # Panics
    /// Panics on an empty repo.
    pub fn fit(&self, repo: &RuntimeDataRepo) -> (FeatureSpace, MatF32, Vec<f32>) {
        assert!(!repo.is_empty(), "cannot featurize an empty repo");
        let rows: Vec<Vec<f32>> = repo
            .records()
            .iter()
            .map(|r| self.raw_row(&r.machine, r.scaleout, &r.job_features))
            .collect();
        let mut x = MatF32::from_rows(&rows);
        let (mean, sd) = x.col_stats();
        x.standardize(&mean, &sd);

        let log_y: Vec<f32> = repo
            .records()
            .iter()
            .map(|r| r.runtime_s.ln() as f32)
            .collect();
        let y_mean = log_y.iter().sum::<f32>() / log_y.len() as f32;
        let y_var = log_y.iter().map(|y| (y - y_mean).powi(2)).sum::<f32>() / log_y.len() as f32;
        let y_sd = y_var.sqrt().max(1e-6);
        let y: Vec<f32> = log_y.iter().map(|v| (v - y_mean) / y_sd).collect();

        let mut names: Vec<String> = repo
            .job()
            .feature_names()
            .iter()
            .map(|s| s.to_string())
            .collect();
        names.extend(CLUSTER_FEATURES.iter().map(|s| s.to_string()));

        (
            FeatureSpace {
                names,
                mean,
                sd,
                y_mean,
                y_sd,
            },
            x,
            y,
        )
    }

    /// Standardize a query row with an existing feature space.
    pub fn transform(
        &self,
        space: &FeatureSpace,
        machine: &str,
        scaleout: u32,
        job_features: &[f64],
    ) -> Vec<f32> {
        let mut row = self.raw_row(machine, scaleout, job_features);
        assert_eq!(row.len(), space.dim(), "feature arity mismatch");
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - space.mean[i]) / space.sd[i];
        }
        row
    }

    /// Transform a batch of record-shaped queries.
    pub fn transform_records(&self, space: &FeatureSpace, records: &[RuntimeRecord]) -> MatF32 {
        let rows: Vec<Vec<f32>> = records
            .iter()
            .map(|r| self.transform(space, &r.machine, r.scaleout, &r.job_features))
            .collect();
        MatF32::from_rows(&rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::RuntimeRecord;
    use crate::workloads::JobKind;

    fn small_repo() -> RuntimeDataRepo {
        let recs = vec![
            RuntimeRecord {
                job: JobKind::Grep,
                org: "a".into(),
                machine: "m5.xlarge".into(),
                scaleout: 4,
                job_features: vec![10.0, 0.1],
                runtime_s: 100.0,
            },
            RuntimeRecord {
                job: JobKind::Grep,
                org: "a".into(),
                machine: "c5.xlarge".into(),
                scaleout: 8,
                job_features: vec![20.0, 0.3],
                runtime_s: 80.0,
            },
            RuntimeRecord {
                job: JobKind::Grep,
                org: "b".into(),
                machine: "r5.xlarge".into(),
                scaleout: 2,
                job_features: vec![15.0, 0.01],
                runtime_s: 300.0,
            },
        ];
        RuntimeDataRepo::from_records(JobKind::Grep, recs)
    }

    #[test]
    fn dimensions_and_names() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let (space, x, y) = f.fit(&small_repo());
        assert_eq!(space.dim(), 2 + 6); // grep features + cluster features
        assert_eq!(x.rows, 3);
        assert_eq!(x.cols, 8);
        assert_eq!(y.len(), 3);
        assert_eq!(space.names[0], "data_gb");
        assert_eq!(space.names[2], "scaleout");
    }

    #[test]
    fn standardization_round_trip() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let repo = small_repo();
        let (space, _, y) = f.fit(&repo);
        // unscale(scale(t)) == t
        for (i, r) in repo.records().iter().enumerate() {
            let back = space.unscale_runtime(y[i]);
            assert!(
                (back - r.runtime_s).abs() / r.runtime_s < 1e-3,
                "{} vs {}",
                back,
                r.runtime_s
            );
            let fwd = space.scale_runtime(r.runtime_s);
            assert!((fwd - y[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn transform_matches_fit_columns() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        let repo = small_repo();
        let (space, x, _) = f.fit(&repo);
        let r0 = &repo.records()[0];
        let q = f.transform(&space, &r0.machine, r0.scaleout, &r0.job_features);
        for c in 0..x.cols {
            assert!((q[c] - x.at(0, c)).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "unknown machine type")]
    fn unknown_machine_panics() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        f.raw_row("tpu.9000", 2, &[1.0]);
    }

    #[test]
    #[should_panic(expected = "empty repo")]
    fn empty_repo_panics() {
        let cloud = Cloud::aws_like();
        let f = Featurizer::new(&cloud);
        f.fit(&RuntimeDataRepo::new(JobKind::Sort));
    }
}
