//! The runtime-data repository — the collaborative core of C3O.
//!
//! The paper's idea (§III): runtime data is shared *alongside the code* of
//! a job, so a new user benefits from every execution anyone ever
//! contributed. This module implements that repository:
//!
//! * [`RuntimeRecord`] — one shared observation: which job, on what
//!   cluster (machine type + scale-out), with which dataset
//!   characteristics and parameters, and the resulting runtime (median of
//!   repetitions, matching the paper's protocol). Records carry the
//!   contributing organization for provenance.
//! * [`RuntimeDataRepo`] — a per-job collection with CSV persistence
//!   (the "runtime data repository" of Fig. 2), deduplication, and
//!   **fork/merge** versioning in the style of DataHub/DVC (§III-C).
//!   [`RuntimeDataRepo::merge`] is the convergence primitive of the
//!   federation layer ([`crate::store`]): duplicate configurations are
//!   resolved by a deterministic total order, so merging is idempotent,
//!   commutative, and associative over record *sets* — independently
//!   gossiping peers converge — and disagreements are surfaced as
//!   structured [`MergeConflict`]s instead of silently dropped.
//! * **Watermarks** — the repo maintains one [`OrgWatermark`] (record
//!   count + order-independent content digest) per contributing
//!   organization, updated incrementally on every mutation.
//!   [`RuntimeDataRepo::delta_for`] extracts exactly the records a peer
//!   with different watermarks is missing — the unit of transfer of the
//!   `SyncPull`/`SyncPush` protocol.
//! * [`sampling`] — the paper's proposed mitigation when the shared
//!   dataset grows too large: download only a *coverage-preserving
//!   sample* of bounded size (farthest-point sampling in feature space).
//! * [`featurize`] — turns records into model-ready matrices: job
//!   features + scale-out + machine descriptors, z-scored.

pub mod featurize;
pub mod sampling;

pub use featurize::{FeatureSpace, Featurizer};

use crate::util::csv::Table;
use crate::util::hash::fnv1a64_parts;
use crate::workloads::JobKind;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// One shared runtime observation.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeRecord {
    pub job: JobKind,
    /// Contributing organization (provenance; "emulated collaborator").
    pub org: String,
    /// Machine type name, resolvable in the cloud catalog.
    pub machine: String,
    /// Horizontal scale-out (worker count).
    pub scaleout: u32,
    /// Job-specific features, aligned with `JobKind::feature_names()`.
    pub job_features: Vec<f64>,
    /// Median runtime over the repetitions, seconds.
    pub runtime_s: f64,
}

/// Canonical text form of one feature value for [`RuntimeRecord::config_key`].
///
/// Float formatting alone is not a stable identity: `-0.0` and `0.0` are
/// equal grid points but format differently under `{:.6e}`, and the 2^52
/// NaN payloads all denote the same (invalid) point. Normalize before
/// formatting so equal configurations can never produce distinct keys.
fn canonical_feature(f: f64) -> String {
    if f.is_nan() {
        return "nan".to_string();
    }
    let f = if f == 0.0 { 0.0 } else { f }; // collapse -0.0 into 0.0
    format!("{f:.6e}")
}

impl RuntimeRecord {
    /// Stable identity key for deduplication: everything except runtime
    /// and org (two orgs measuring the same configuration are duplicates
    /// of the same grid point). Feature values are canonicalized
    /// (`-0.0` ≡ `0.0`, all NaNs ≡ `nan`) before formatting.
    pub fn config_key(&self) -> String {
        let feats: Vec<String> = self
            .job_features
            .iter()
            .map(|f| canonical_feature(*f))
            .collect();
        format!(
            "{}|{}|{}|{}",
            self.job.name(),
            self.machine,
            self.scaleout,
            feats.join(",")
        )
    }

    /// Stable 64-bit content hash over identity *and* measurement
    /// (config key, org, runtime bits). XOR-combining these hashes gives
    /// the order-independent set digests of [`OrgWatermark`].
    pub fn content_hash(&self) -> u64 {
        fnv1a64_parts(&[
            self.config_key().as_bytes(),
            self.org.as_bytes(),
            &self.runtime_s.to_bits().to_le_bytes(),
        ])
    }

    /// The deterministic merge-priority key: of two records sharing a
    /// configuration, the one with the **smaller** key survives a
    /// merge. Runtimes are validated positive, so the bit order equals
    /// the value order. The rule is arbitrary but *total* and
    /// *order-independent*, which is what makes federated merging
    /// converge regardless of gossip order.
    pub fn merge_priority(&self) -> (u64, &str) {
        (self.runtime_s.to_bits(), self.org.as_str())
    }

    /// The canonical federation ordering key (config key, org, runtime
    /// bits) — the one total order [`RuntimeDataRepo::canonicalize`]
    /// sorts by; converged peers are bitwise-identical *because* they
    /// all sort by this same key.
    pub fn canonical_sort_key(&self) -> (String, String, u64) {
        (self.config_key(), self.org.clone(), self.runtime_s.to_bits())
    }

    /// A copy of the record re-attributed to `org` (e.g. when building
    /// per-organization corpora for federation demos and tests).
    pub fn with_org(&self, org: &str) -> RuntimeRecord {
        RuntimeRecord {
            org: org.to_string(),
            ..self.clone()
        }
    }

    fn wins_over(&self, other: &RuntimeRecord) -> bool {
        self.merge_priority() < other.merge_priority()
    }

    fn validate(&self) -> Result<(), String> {
        if self.scaleout == 0 {
            return Err("scaleout must be >= 1".into());
        }
        // line-oriented persistence (the segment store WAL) frames one
        // record per physical line; reject control characters that
        // would break that framing at the one validation choke point
        // every ingress path shares
        if self.org.contains('\n') || self.org.contains('\r') {
            return Err(format!("org may not contain newlines: {:?}", self.org));
        }
        if self.machine.contains('\n') || self.machine.contains('\r') {
            return Err(format!(
                "machine may not contain newlines: {:?}",
                self.machine
            ));
        }
        if !(self.runtime_s.is_finite() && self.runtime_s > 0.0) {
            return Err(format!("bad runtime {}", self.runtime_s));
        }
        if self.job_features.len() != self.job.feature_names().len() {
            return Err(format!(
                "{}: {} features, expected {}",
                self.job.name(),
                self.job_features.len(),
                self.job.feature_names().len()
            ));
        }
        if self.job_features.iter().any(|f| !f.is_finite()) {
            return Err("non-finite feature".into());
        }
        Ok(())
    }
}

/// Per-organization high-water mark: how much of that organization's
/// data a repository holds. `count` is the number of records attributed
/// to the org; `digest` is the XOR of their [`RuntimeRecord::content_hash`]es
/// — order-independent, so two repos holding the same record set for an
/// org agree on the watermark no matter how the records arrived.
///
/// Watermarks are the unit of the delta-sync protocol: a peer sends its
/// marks, and [`RuntimeDataRepo::delta_for`] returns the records of
/// every org whose mark differs. The granularity is per-org, not
/// per-record — over-sending is harmless because merge dedups — which
/// keeps the watermark exchange O(orgs), not O(records).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OrgWatermark {
    /// Records attributed to the organization.
    pub count: u64,
    /// XOR of the records' content hashes (order-independent).
    pub digest: u64,
}

/// One surfaced merge disagreement: two records shared a configuration
/// key but disagreed on the measured runtime. The deterministic order
/// ([`RuntimeRecord::wins_over`]) decides which survives; the loser is
/// reported here instead of being silently skipped — federated peers
/// need to *see* that their measurement was contested.
#[derive(Debug, Clone, PartialEq)]
pub struct MergeConflict {
    pub config_key: String,
    pub kept_org: String,
    pub kept_runtime_s: f64,
    pub dropped_org: String,
    pub dropped_runtime_s: f64,
}

/// Structured result of a merge.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MergeOutcome {
    /// Records with previously-unknown configurations, appended.
    pub added: usize,
    /// Existing records replaced because the incoming record wins the
    /// deterministic order (in place — the slot index is preserved).
    pub replaced: usize,
    /// Runtime disagreements encountered (whether or not the incoming
    /// side won).
    pub conflicts: Vec<MergeConflict>,
    /// The records that actually changed the repository (adds and
    /// replacement winners), in application order. Each advanced the
    /// generation by exactly one; the segment store WAL-logs exactly
    /// these.
    pub applied: Vec<RuntimeRecord>,
}

impl MergeOutcome {
    /// Total mutations (adds + replacements) — how far the generation
    /// advanced.
    pub fn changed(&self) -> usize {
        self.added + self.replaced
    }
}

/// A per-job shared repository of runtime records.
#[derive(Debug, Clone)]
pub struct RuntimeDataRepo {
    job: JobKind,
    records: Vec<RuntimeRecord>,
    /// Monotone generation counter: advances by the number of records a
    /// mutation actually added or replaced, and never moves otherwise.
    /// Consumers (the coordinator shards' model caches) key trained
    /// models on this value, so "the corpus did not change" is
    /// observable as "the generation did not change" — re-merging
    /// already-known data is a guaranteed no-op for retraining.
    generation: u64,
    /// Machine-type refcounts, maintained incrementally so the sorted
    /// observed-machines list is O(machines) per snapshot publish
    /// instead of O(records).
    machines: BTreeMap<String, usize>,
    /// Per-org watermarks (count + XOR digest), maintained incrementally.
    org_marks: BTreeMap<String, OrgWatermark>,
    /// Merge-representative slot per configuration key: the slot of
    /// the record with the **smallest** [`RuntimeRecord::merge_priority`]
    /// among same-key records. Using the priority winner (not the first
    /// occurrence) keeps merging idempotent even when the blind
    /// contribute path has appended duplicate configurations: an
    /// incoming record identical to the local best is a no-op rather
    /// than a spurious replacement of a weaker duplicate. Maintained
    /// incrementally so merging `m` records into a repo of `n` is
    /// O(m log n); rebuilt after [`RuntimeDataRepo::canonicalize`]
    /// reorders the records.
    key_index: BTreeMap<String, usize>,
}

impl RuntimeDataRepo {
    /// Empty repository for a job.
    pub fn new(job: JobKind) -> Self {
        RuntimeDataRepo {
            job,
            records: Vec::new(),
            generation: 0,
            machines: BTreeMap::new(),
            org_marks: BTreeMap::new(),
            key_index: BTreeMap::new(),
        }
    }

    /// Build from records (e.g. a corpus slice); invalid or foreign-job
    /// records are rejected.
    pub fn from_records<I: IntoIterator<Item = RuntimeRecord>>(job: JobKind, records: I) -> Self {
        let mut repo = RuntimeDataRepo::new(job);
        for r in records {
            repo.contribute(r).expect("invalid record");
        }
        repo
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    pub fn records(&self) -> &[RuntimeRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Current generation: advances by the number of records added or
    /// replaced. A repository whose generation is unchanged is
    /// guaranteed to hold exactly the same data, which is what the
    /// coordinator's model cache keys on.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Legacy alias for [`RuntimeDataRepo::generation`].
    pub fn version(&self) -> u64 {
        self.generation
    }

    /// Restore the generation counter after segment-store recovery. The
    /// generation can run ahead of `len()` (conflict replacements
    /// advance it without growing the repo), so replaying records alone
    /// cannot always reconstruct it. Recovery-only; must be monotone.
    pub(crate) fn restore_generation(&mut self, generation: u64) {
        assert!(
            generation >= self.generation,
            "generation restore must be monotone ({} < {})",
            generation,
            self.generation
        );
        self.generation = generation;
    }

    fn cache_add(&mut self, r: &RuntimeRecord) {
        *self.machines.entry(r.machine.clone()).or_insert(0) += 1;
        let mark = self.org_marks.entry(r.org.clone()).or_default();
        mark.count += 1;
        mark.digest ^= r.content_hash();
    }

    fn cache_remove(&mut self, r: &RuntimeRecord) {
        if let Some(n) = self.machines.get_mut(&r.machine) {
            *n -= 1;
            if *n == 0 {
                self.machines.remove(&r.machine);
            }
        }
        if let Some(mark) = self.org_marks.get_mut(&r.org) {
            mark.count -= 1;
            mark.digest ^= r.content_hash();
            if mark.count == 0 {
                self.org_marks.remove(&r.org);
            }
        }
    }

    /// Contribute one record (the "capture and save" step of Fig. 1).
    pub fn contribute(&mut self, r: RuntimeRecord) -> Result<(), String> {
        if r.job != self.job {
            return Err(format!(
                "record for {} contributed to {} repo",
                r.job.name(),
                self.job.name()
            ));
        }
        r.validate()?;
        self.cache_add(&r);
        let next_slot = self.records.len();
        match self.key_index.entry(r.config_key()) {
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(next_slot);
            }
            std::collections::btree_map::Entry::Occupied(mut e) => {
                // duplicate configuration: the representative stays the
                // merge-priority winner
                if r.merge_priority() < self.records[*e.get()].merge_priority() {
                    e.insert(next_slot);
                }
            }
        }
        self.records.push(r);
        self.generation += 1;
        Ok(())
    }

    /// Distinct contributing organizations.
    pub fn organizations(&self) -> BTreeSet<String> {
        self.org_marks.keys().cloned().collect()
    }

    /// Machine types observed in the shared data, sorted — served from
    /// the incremental refcount cache in O(machines), not O(records).
    pub fn observed_machines(&self) -> Vec<String> {
        self.machines.keys().cloned().collect()
    }

    /// Per-org high-water marks (count + order-independent digest) —
    /// what a peer sends to ask "what am I missing?".
    pub fn watermarks(&self) -> BTreeMap<String, OrgWatermark> {
        self.org_marks.clone()
    }

    /// Delta extraction by watermark: every record of each organization
    /// whose local watermark differs from `theirs` (including orgs the
    /// peer has never seen). Per-org granularity — a changed org ships
    /// whole, which merge-level dedup makes harmless — so the transfer
    /// cost scales with *changed* organizations, not corpus size.
    ///
    /// Known cost of that granularity: blind-contributed duplicate
    /// configurations (the submit path's local history) are never
    /// accepted by a peer's merge, so the org's watermarks stay
    /// permanently unequal and its slice is re-offered on every
    /// exchange. The exchange stays correct and quiescence-detection
    /// unaffected (both count *applied* records); the waste is visible
    /// as `SyncStats::offered` exceeding applied counts. Record-level
    /// deltas are a ROADMAP follow-up.
    pub fn delta_for(&self, theirs: &BTreeMap<String, OrgWatermark>) -> Vec<RuntimeRecord> {
        let stale: BTreeSet<&String> = self
            .org_marks
            .iter()
            .filter(|&(org, mark)| theirs.get(org) != Some(mark))
            .map(|(org, _)| org)
            .collect();
        if stale.is_empty() {
            return Vec::new();
        }
        self.records
            .iter()
            .filter(|r| stale.contains(&r.org))
            .cloned()
            .collect()
    }

    /// Order-independent digest of the whole record set (XOR of content
    /// hashes). Two converged peers agree on it; a cheap equality probe
    /// for the `c3o sync` driver and the federation tests. (Exact
    /// duplicate records XOR-cancel — use [`Self::canonical_records`]
    /// for a collision-proof comparison.)
    pub fn content_digest(&self) -> u64 {
        self.records.iter().fold(0u64, |acc, r| acc ^ r.content_hash())
    }

    /// Sort the records into the canonical federation order (config
    /// key, then org, then runtime bits). Two repos holding the same
    /// record *set* become bitwise-identical — including iteration
    /// order, hence identical downstream featurization and training
    /// inputs. Content is unchanged, so the generation does not move.
    /// The sync write path canonicalizes after applying a delta.
    pub fn canonicalize(&mut self) {
        self.records
            .sort_by_cached_key(RuntimeRecord::canonical_sort_key);
        // the reorder invalidated the representative slots; rebuild
        // them as the merge-priority winner per key
        self.key_index.clear();
        for (i, r) in self.records.iter().enumerate() {
            match self.key_index.entry(r.config_key()) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(i);
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    if r.merge_priority() < self.records[*e.get()].merge_priority() {
                        e.insert(i);
                    }
                }
            }
        }
    }

    /// A canonically-ordered clone of the records — the equality form
    /// the federation tests compare peers by.
    pub fn canonical_records(&self) -> Vec<RuntimeRecord> {
        let mut rs = self.records.clone();
        rs.sort_by_cached_key(RuntimeRecord::canonical_sort_key);
        rs
    }

    /// Fork: an independent copy (DataHub/DVC-style).
    pub fn fork(&self) -> RuntimeDataRepo {
        self.clone()
    }

    /// Merge another repository of the same job into this one. See
    /// [`RuntimeDataRepo::merge_records`] for the semantics.
    pub fn merge(&mut self, other: &RuntimeDataRepo) -> Result<MergeOutcome, String> {
        if other.job != self.job {
            return Err("cannot merge repos of different jobs".into());
        }
        self.merge_records(&other.records)
    }

    /// Merge a batch of records (the `SyncPush` application path, and
    /// the body of [`RuntimeDataRepo::merge`]).
    ///
    /// Per incoming record, by [`RuntimeRecord::config_key`]:
    ///
    /// * **unknown configuration** — appended (`added`).
    /// * **known configuration, incoming wins** the deterministic total
    ///   order ([`RuntimeRecord::wins_over`]) — replaces the existing
    ///   record *in place* (`replaced`); a runtime disagreement is also
    ///   reported as a [`MergeConflict`].
    /// * **known configuration, existing wins** — nothing changes; a
    ///   runtime disagreement is still reported.
    ///
    /// The winner rule is order-independent, so merging is idempotent
    /// and commutative over record sets: peers exchanging deltas in any
    /// gossip order converge to the same contents. The generation
    /// advances by `added + replaced` — exactly the records in
    /// [`MergeOutcome::applied`]. An `Err` applies **nothing**: the
    /// batch is validated in full before the first mutation.
    pub fn merge_records(&mut self, incoming: &[RuntimeRecord]) -> Result<MergeOutcome, String> {
        // Validate the WHOLE batch before applying anything: a
        // half-applied delta would advance the generation while the
        // request errors, leaving callers (and any attached segment
        // store, which only logs successful applies) desynced from the
        // repo. Rejecting up front keeps a failed merge side-effect-free.
        for r in incoming {
            if r.job != self.job {
                return Err(format!(
                    "record for {} merged into {} repo",
                    r.job.name(),
                    self.job.name()
                ));
            }
            r.validate()?;
        }
        // The maintained index resolves each incoming record against
        // its merge representative — the priority winner among local
        // same-key records, so a record the repo already holds (even
        // alongside weaker blind-contributed duplicates) merges as a
        // no-op.
        let mut out = MergeOutcome::default();
        for r in incoming {
            let key = r.config_key();
            match self.key_index.get(&key).copied() {
                None => {
                    self.key_index.insert(key, self.records.len());
                    self.cache_add(r);
                    self.records.push(r.clone());
                    self.generation += 1;
                    out.added += 1;
                    out.applied.push(r.clone());
                }
                Some(slot) => {
                    let existing = &self.records[slot];
                    let disagrees = existing.runtime_s.to_bits() != r.runtime_s.to_bits();
                    if r.wins_over(existing) {
                        if disagrees {
                            out.conflicts.push(MergeConflict {
                                config_key: key,
                                kept_org: r.org.clone(),
                                kept_runtime_s: r.runtime_s,
                                dropped_org: existing.org.clone(),
                                dropped_runtime_s: existing.runtime_s,
                            });
                        }
                        let dropped = self.records[slot].clone();
                        self.cache_remove(&dropped);
                        self.cache_add(r);
                        self.records[slot] = r.clone();
                        self.generation += 1;
                        out.replaced += 1;
                        out.applied.push(r.clone());
                    } else if disagrees {
                        out.conflicts.push(MergeConflict {
                            config_key: key,
                            kept_org: existing.org.clone(),
                            kept_runtime_s: existing.runtime_s,
                            dropped_org: r.org.clone(),
                            dropped_runtime_s: r.runtime_s,
                        });
                    }
                    // identical record (same key, org, runtime): no-op
                }
            }
        }
        Ok(out)
    }

    /// CSV header for this job's schema.
    fn header(&self) -> Vec<String> {
        let mut h = vec![
            "job".to_string(),
            "org".to_string(),
            "machine".to_string(),
            "scaleout".to_string(),
        ];
        h.extend(self.job.feature_names().iter().map(|s| s.to_string()));
        h.push("runtime_s".to_string());
        h
    }

    /// Serialize to a CSV [`Table`] (the on-disk sharing format).
    pub fn to_table(&self) -> Table {
        let header = self.header();
        let mut t = Table {
            header,
            rows: Vec::new(),
        };
        for r in &self.records {
            let mut row = vec![
                r.job.name().to_string(),
                r.org.clone(),
                r.machine.clone(),
                r.scaleout.to_string(),
            ];
            row.extend(r.job_features.iter().map(|f| format!("{f}")));
            row.push(format!("{}", r.runtime_s));
            t.push(row);
        }
        t
    }

    /// Persist to CSV.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        self.to_table().save(path)
    }

    /// Load from CSV; rejects schema mismatches.
    pub fn load(job: JobKind, path: &Path) -> Result<RuntimeDataRepo, String> {
        let t = Table::load(path).map_err(|e| e.to_string())?;
        Self::from_table(job, &t)
    }

    /// Parse from a CSV table.
    pub fn from_table(job: JobKind, t: &Table) -> Result<RuntimeDataRepo, String> {
        let mut repo = RuntimeDataRepo::new(job);
        let expect = repo.header();
        if t.header != expect {
            return Err(format!(
                "schema mismatch: got {:?}, want {:?}",
                t.header, expect
            ));
        }
        let nf = job.feature_names().len();
        for row in &t.rows {
            let parse_f = |s: &str| -> Result<f64, String> {
                s.parse().map_err(|_| format!("bad number {s:?}"))
            };
            let rec = RuntimeRecord {
                job: JobKind::parse(&row[0]).ok_or_else(|| format!("bad job {:?}", row[0]))?,
                org: row[1].clone(),
                machine: row[2].clone(),
                scaleout: row[3].parse().map_err(|_| "bad scaleout".to_string())?,
                job_features: row[4..4 + nf]
                    .iter()
                    .map(|s| parse_f(s))
                    .collect::<Result<_, _>>()?,
                runtime_s: parse_f(&row[4 + nf])?,
            };
            repo.contribute(rec)?;
        }
        Ok(repo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(org: &str, machine: &str, scaleout: u32, gb: f64, runtime: f64) -> RuntimeRecord {
        RuntimeRecord {
            job: JobKind::Sort,
            org: org.into(),
            machine: machine.into(),
            scaleout,
            job_features: vec![gb],
            runtime_s: runtime,
        }
    }

    #[test]
    fn contribute_and_len() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.is_empty());
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(repo.len(), 1);
        assert_eq!(repo.version(), 1);
    }

    #[test]
    fn rejects_wrong_job() {
        let mut repo = RuntimeDataRepo::new(JobKind::Grep);
        let err = repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0));
        assert!(err.is_err());
    }

    #[test]
    fn rejects_invalid_records() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.contribute(rec("a", "m", 0, 10.0, 100.0)).is_err());
        assert!(repo.contribute(rec("a", "m", 4, 10.0, -5.0)).is_err());
        assert!(repo.contribute(rec("a", "m", 4, f64::NAN, 5.0)).is_err());
        let wrong_arity = RuntimeRecord {
            job_features: vec![1.0, 2.0],
            ..rec("a", "m", 4, 10.0, 100.0)
        };
        assert!(repo.contribute(wrong_arity).is_err());
    }

    #[test]
    fn merge_dedups_by_config_and_reports_conflicts() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        let mut b = a.fork();
        b.contribute(rec("orgB", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        // orgB also re-measured orgA's config — duplicate by key, with a
        // disagreeing (and losing: 102 > 100) runtime
        b.contribute(rec("orgB", "m5.xlarge", 4, 10.0, 102.0)).unwrap();
        let out = a.merge(&b).unwrap();
        assert_eq!(out.added, 1, "only the new configuration is merged");
        assert_eq!(out.replaced, 0, "the existing lower runtime wins");
        assert_eq!(a.len(), 2);
        // the disagreement is surfaced, not silently skipped
        assert_eq!(out.conflicts.len(), 1);
        let c = &out.conflicts[0];
        assert_eq!(c.kept_org, "orgA");
        assert_eq!(c.dropped_org, "orgB");
        assert_eq!(c.kept_runtime_s, 100.0);
        assert_eq!(c.dropped_runtime_s, 102.0);
        // merging again changes nothing (the conflict is re-reported)
        let again = a.merge(&b).unwrap();
        assert_eq!(again.changed(), 0);
        assert_eq!(again.conflicts.len(), 1);
    }

    #[test]
    fn merge_replacement_is_deterministic_and_order_independent() {
        // Same configuration measured twice with different runtimes: the
        // deterministic order keeps the smaller (runtime, org) pair on
        // BOTH merge directions, so peers converge.
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 10.0, 102.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("orgB", "m5.xlarge", 4, 10.0, 100.0)).unwrap();

        let mut ab = a.fork();
        let out = ab.merge(&b).unwrap();
        assert_eq!((out.added, out.replaced), (0, 1), "incoming 100.0 wins");
        assert_eq!(out.conflicts.len(), 1);
        assert_eq!(out.applied.len(), 1);
        assert_eq!(ab.len(), 1);
        assert_eq!(ab.records()[0].org, "orgB");
        assert_eq!(ab.generation(), 2, "replacement advances the generation");

        let mut ba = b.fork();
        let out = ba.merge(&a).unwrap();
        assert_eq!((out.added, out.replaced), (0, 0), "existing 100.0 wins");
        assert_eq!(out.conflicts.len(), 1);
        assert_eq!(ba.records(), ab.records(), "both directions converge");
    }

    #[test]
    fn config_key_normalizes_signed_zero_and_nan() {
        // -0.0 and 0.0 are the same grid point; they must share one key.
        let pos = rec("a", "m5.xlarge", 4, 0.0, 100.0);
        let neg = rec("b", "m5.xlarge", 4, -0.0, 102.0);
        assert_eq!(pos.config_key(), neg.config_key());
        // every NaN payload canonicalizes to the same token (config_key
        // must stay total even on records that validation would reject)
        let nan_a = rec("a", "m5.xlarge", 4, f64::NAN, 100.0);
        let nan_b = rec("a", "m5.xlarge", 4, -f64::NAN, 100.0);
        assert_eq!(nan_a.config_key(), nan_b.config_key());
        assert!(nan_a.config_key().contains("nan"));
    }

    #[test]
    fn merge_dedups_signed_zero_grid_points() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 0.0, 100.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("orgB", "m5.xlarge", 4, -0.0, 101.0)).unwrap();
        let out = a.merge(&b).unwrap();
        assert_eq!(out.added, 0, "-0.0 must dedup against 0.0");
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn generation_tracks_records_added() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        assert_eq!(a.generation(), 0);
        a.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(a.generation(), 1);
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("b", "m5.xlarge", 6, 10.0, 90.0)).unwrap();
        b.contribute(rec("b", "m5.xlarge", 8, 10.0, 80.0)).unwrap();
        a.merge(&b).unwrap();
        assert_eq!(a.generation(), 3, "merge advances by records added");
        // idempotent re-merge: no data change, no generation change
        let before = a.generation();
        assert_eq!(a.merge(&b).unwrap().changed(), 0);
        assert_eq!(a.generation(), before);
    }

    #[test]
    fn merge_rejects_cross_job() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        let b = RuntimeDataRepo::new(JobKind::Grep);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn merge_is_idempotent_despite_blind_duplicates() {
        // The submit path appends duplicate configurations blindly; the
        // merge representative must be the priority winner among them,
        // so re-receiving a record the repo already holds is a no-op —
        // not a spurious replacement of the weaker duplicate.
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 90.0)).unwrap(); // dup, better
        let before = repo.records().to_vec();
        let gen = repo.generation();
        // a peer ships back exactly the winner we already hold
        let out = repo
            .merge_records(&[rec("a", "m5.xlarge", 4, 10.0, 90.0)])
            .unwrap();
        assert_eq!(out.changed(), 0, "identical-to-best must be a no-op");
        assert_eq!(repo.records(), &before[..], "no duplication, no swap");
        assert_eq!(repo.generation(), gen);
        // a genuinely better measurement still replaces the winner
        let out = repo
            .merge_records(&[rec("b", "m5.xlarge", 4, 10.0, 80.0)])
            .unwrap();
        assert_eq!(out.replaced, 1);
        assert_eq!(
            repo.records().iter().filter(|r| r.runtime_s == 80.0).count(),
            1
        );
    }

    #[test]
    fn rejects_framing_unsafe_org_and_machine() {
        // the WAL is line-framed: newlines in text fields are rejected
        // at validation, before any repository (or store) mutation
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.contribute(rec("or\ng", "m5.xlarge", 4, 10.0, 1.0)).is_err());
        assert!(repo.contribute(rec("org", "m5\r.xlarge", 4, 10.0, 1.0)).is_err());
        assert!(repo.is_empty());
    }

    #[test]
    fn failed_merge_applies_nothing() {
        // A batch with an invalid record mid-stream must be rejected
        // atomically: no records applied, no generation movement —
        // otherwise a durable shard's store mirror would desync from
        // the half-mutated repo.
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        let gen = repo.generation();
        let batch = vec![
            rec("b", "m5.xlarge", 8, 11.0, 90.0), // valid, would be added
            rec("b", "m5.xlarge", 0, 12.0, 80.0), // invalid scaleout
        ];
        assert!(repo.merge_records(&batch).is_err());
        assert_eq!(repo.len(), 1, "nothing from the failed batch landed");
        assert_eq!(repo.generation(), gen);
        assert_eq!(repo.watermarks().len(), 1);
    }

    #[test]
    fn observed_machines_cache_matches_recompute() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "c5.xlarge", 4, 11.0, 90.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 8, 12.0, 80.0)).unwrap();
        assert_eq!(
            repo.observed_machines(),
            vec!["c5.xlarge".to_string(), "m5.xlarge".to_string()]
        );

        // a replacement reattributes the record: the machine set is
        // unchanged (the config key pins the machine), but the org
        // watermark moves from the loser to the winner
        let mut only = RuntimeDataRepo::new(JobKind::Sort);
        only.contribute(rec("x", "r5.xlarge", 4, 10.0, 100.0)).unwrap();
        let mut winner = RuntimeDataRepo::new(JobKind::Sort);
        winner.contribute(rec("w", "r5.xlarge", 4, 10.0, 50.0)).unwrap();
        let out = only.merge(&winner).unwrap();
        assert_eq!(out.replaced, 1);
        assert_eq!(only.observed_machines(), vec!["r5.xlarge".to_string()]);
        assert_eq!(
            only.organizations().into_iter().collect::<Vec<_>>(),
            vec!["w".to_string()],
            "the dropped org's watermark entry is removed"
        );
    }

    #[test]
    fn watermarks_track_counts_and_digests() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        let marks = repo.watermarks();
        assert_eq!(marks.len(), 2);
        assert_eq!(marks["a"].count, 2);
        assert_eq!(marks["b"].count, 1);

        // the digest is order-independent: a repo built in another order
        // agrees per org
        let mut other = RuntimeDataRepo::new(JobKind::Sort);
        other.contribute(rec("b", "m5.xlarge", 2, 10.0, 200.0)).unwrap();
        other.contribute(rec("a", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        other.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(repo.watermarks(), other.watermarks());
        assert_eq!(repo.content_digest(), other.content_digest());
    }

    #[test]
    fn delta_for_ships_only_stale_orgs() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        repo.contribute(rec("b", "m5.xlarge", 2, 10.0, 200.0)).unwrap();

        // peer that matches org "a" but has never seen "b"
        let mut peer = RuntimeDataRepo::new(JobKind::Sort);
        peer.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        let delta = repo.delta_for(&peer.watermarks());
        assert_eq!(delta.len(), 2);
        assert!(delta.iter().all(|r| r.org == "b"));

        // a converged peer gets an empty delta
        peer.merge_records(&delta).unwrap();
        assert!(repo.delta_for(&peer.watermarks()).is_empty());
        assert!(peer.delta_for(&repo.watermarks()).is_empty());
    }

    #[test]
    fn canonicalize_orders_and_preserves_content() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("z", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        a.contribute(rec("a", "c5.xlarge", 4, 11.0, 90.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("a", "c5.xlarge", 4, 11.0, 90.0)).unwrap();
        b.contribute(rec("z", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        assert_ne!(a.records(), b.records(), "insertion orders differ");
        let gen = a.generation();
        a.canonicalize();
        b.canonicalize();
        assert_eq!(a.records(), b.records(), "canonical order is unique");
        assert_eq!(a.generation(), gen, "reordering is not a data change");
        assert_eq!(a.canonical_records(), a.records().to_vec());
    }

    #[test]
    fn csv_round_trip() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("orgA", "m5.xlarge", 4, 12.5, 123.456)).unwrap();
        repo.contribute(rec("orgB", "c5.xlarge", 8, 20.0, 77.7)).unwrap();
        let t = repo.to_table();
        let back = RuntimeDataRepo::from_table(JobKind::Sort, &t).unwrap();
        assert_eq!(back.records(), repo.records());
        assert_eq!(back.watermarks(), repo.watermarks());
        assert_eq!(back.observed_machines(), repo.observed_machines());
    }

    #[test]
    fn csv_schema_mismatch_rejected() {
        let repo = RuntimeDataRepo::new(JobKind::Grep);
        let t = repo.to_table();
        assert!(RuntimeDataRepo::from_table(JobKind::Sort, &t).is_err());
    }

    #[test]
    fn organizations_collected() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("b", "m5.xlarge", 4, 10.0, 1.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 1.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 2, 10.0, 1.0)).unwrap();
        let orgs: Vec<String> = repo.organizations().into_iter().collect();
        assert_eq!(orgs, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn file_round_trip() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("orgA", "m5.xlarge", 4, 12.5, 123.0)).unwrap();
        let dir = std::env::temp_dir().join("c3o_repo_test");
        let path = dir.join("sort.csv");
        repo.save(&path).unwrap();
        let back = RuntimeDataRepo::load(JobKind::Sort, &path).unwrap();
        assert_eq!(back.records(), repo.records());
        let _ = std::fs::remove_dir_all(dir);
    }
}
