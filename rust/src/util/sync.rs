//! Poison-tolerant lock acquisition for the serving path.
//!
//! The serving zone (`api`, `coordinator`) is panic-free by lint
//! (`no-panic-serving` in `rust/lint`), which makes the classic
//! `.lock().unwrap()` idiom doubly wrong there: it is itself a panic
//! site, and the poisoning it propagates can only originate from a bug
//! that the lint exists to keep out. These extension traits recover
//! the guard from a poisoned lock via [`std::sync::PoisonError::into_inner`]
//! instead of unwinding: every protected structure in the service
//! (shards, metrics, snapshots, the request queue) is kept
//! crash-consistent by the store's WAL, so serving a possibly
//! mid-update in-memory view beats taking the whole coordinator down.
//!
//! The method names intentionally end in `_unpoisoned` and keep the
//! `lock`/`read`/`write` prefixes so `c3o-lint`'s lock-discipline rule
//! still recognizes them as acquisitions (it matches method names, not
//! types).

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Poison-tolerant [`Mutex`] acquisition.
pub trait LockExt<T> {
    /// Acquire the mutex, recovering the guard if a previous holder
    /// panicked.
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Poison-tolerant [`RwLock`] acquisition.
pub trait RwLockExt<T> {
    /// Acquire a shared read guard, recovering from poisoning.
    fn read_unpoisoned(&self) -> RwLockReadGuard<'_, T>;
    /// Acquire the exclusive write guard, recovering from poisoning.
    fn write_unpoisoned(&self) -> RwLockWriteGuard<'_, T>;
}

impl<T> RwLockExt<T> for RwLock<T> {
    fn read_unpoisoned(&self) -> RwLockReadGuard<'_, T> {
        self.read().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn write_unpoisoned(&self) -> RwLockWriteGuard<'_, T> {
        self.write().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn mutex_recovers_after_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*m.lock_unpoisoned(), 7);
        *m.lock_unpoisoned() = 8;
        assert_eq!(*m.lock_unpoisoned(), 8);
    }

    #[test]
    fn rwlock_recovers_after_poison() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _guard = l2.write().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(l.read_unpoisoned().len(), 3);
        l.write_unpoisoned().push(4);
        assert_eq!(l.read_unpoisoned().len(), 4);
    }
}
