//! PJRT runtime: the only bridge between the Rust coordinator (L3) and
//! the AOT-compiled model graphs (L2/L1).
//!
//! `make artifacts` lowers the JAX graphs to HLO *text* (see
//! `python/compile/aot.py` for why text, not serialized protos). This
//! module loads those artifacts with the `xla` crate
//! (`PjRtClient::cpu() → HloModuleProto::from_text_file →
//! client.compile → execute`), caches the compiled executables, and
//! exposes typed helpers for the model layer.
//!
//! Python never runs on this path: once `artifacts/` exists, the binary
//! is self-contained.

use crate::util::csv::Table;
use crate::util::matrix::MatF32;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape constants shared with the Python export (artifacts/manifest.csv).
/// The Rust side pads inputs to these shapes and masks the padding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub feature_dim: usize,
    pub knn_train_rows: usize,
    pub knn_query_rows: usize,
    pub knn_k: usize,
    pub opt_batch: usize,
    pub opt_params: usize,
}

impl Manifest {
    /// Parse from the `key,value` CSV written by `aot.py`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let t = Table::load(path).map_err(|e| anyhow!("manifest: {e}"))?;
        if t.header != vec!["key".to_string(), "value".to_string()] {
            bail!("manifest schema mismatch: {:?}", t.header);
        }
        let mut map = HashMap::new();
        for row in &t.rows {
            map.insert(row[0].clone(), row[1].clone());
        }
        let get = |k: &str| -> Result<usize> {
            map.get(k)
                .with_context(|| format!("manifest missing key {k}"))?
                .parse()
                .with_context(|| format!("manifest key {k} not an integer"))
        };
        Ok(Manifest {
            feature_dim: get("feature_dim")?,
            knn_train_rows: get("knn_train_rows")?,
            knn_query_rows: get("knn_query_rows")?,
            knn_k: get("knn_k")?,
            opt_batch: get("opt_batch")?,
            opt_params: get("opt_params")?,
        })
    }
}

/// Names of the three model artifacts.
pub const ARTIFACT_NAMES: [&str; 3] = ["knn_predict", "optimistic_predict", "optimistic_train"];

/// The PJRT runtime: CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory. Compilation is lazy:
    /// each artifact compiles on first use and is cached for the process
    /// lifetime.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.csv")).with_context(|| {
            format!("loading manifest from {artifacts_dir:?} (run `make artifacts`)")
        })?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            executables: HashMap::new(),
        })
    }

    /// Locate the default artifacts directory: `$C3O_ARTIFACTS`, else
    /// `./artifacts` relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("C3O_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// True if the artifacts directory looks complete (all artifacts +
    /// manifest present). Tests use this to skip gracefully when
    /// `make artifacts` has not run.
    pub fn artifacts_available(dir: &Path) -> bool {
        dir.join("manifest.csv").exists()
            && ARTIFACT_NAMES
                .iter()
                .all(|n| dir.join(format!("{n}.hlo.txt")).exists())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Force-compile every artifact (used at coordinator startup so the
    /// request path never pays compile latency).
    pub fn warmup(&mut self) -> Result<()> {
        for name in ARTIFACT_NAMES {
            self.executable(name)?;
        }
        Ok(())
    }

    /// Number of executables compiled so far (observability).
    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }

    /// Execute an artifact. Inputs are f32 literals; the result tuple is
    /// decomposed into its elements.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        literal
            .to_tuple()
            .map_err(|e| anyhow!("untuple result of {name}: {e:?}"))
    }

    /// Execute an artifact with device-resident input buffers (§Perf:
    /// skips the per-call host→device transfer for inputs that don't
    /// change between calls, e.g. the kNN training set).
    pub fn execute_buffers(
        &mut self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| anyhow!("execute_b {name}: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        literal
            .to_tuple()
            .map_err(|e| anyhow!("untuple result of {name}: {e:?}"))
    }

    /// Upload a 1-D f32 buffer to the device.
    pub fn buffer_vec(&self, xs: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(xs, &[xs.len()], None)
            .map_err(|e| anyhow!("host->device vec: {e:?}"))
    }

    /// Upload a row-major f32 matrix to the device.
    pub fn buffer_mat(&self, m: &MatF32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&m.data, &[m.rows, m.cols], None)
            .map_err(|e| anyhow!("host->device mat: {e:?}"))
    }

    // --- literal helpers ---------------------------------------------------

    /// 1-D f32 literal.
    pub fn lit_vec(xs: &[f32]) -> xla::Literal {
        xla::Literal::vec1(xs)
    }

    /// 2-D f32 literal from a row-major matrix.
    pub fn lit_mat(m: &MatF32) -> Result<xla::Literal> {
        xla::Literal::vec1(&m.data)
            .reshape(&[m.rows as i64, m.cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    /// Scalar f32 literal.
    pub fn lit_scalar(x: f32) -> xla::Literal {
        xla::Literal::scalar(x)
    }

    /// Extract an f32 vector from a literal.
    pub fn vec_from(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>()
            .map_err(|e| anyhow!("literal to_vec: {e:?}"))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("manifest", &self.manifest)
            .field("compiled", &self.executables.keys().collect::<Vec<_>>())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Skip (with a loud note) when artifacts haven't been built; CI runs
    /// `make artifacts` first, so these exercise the real PJRT path.
    macro_rules! require_artifacts {
        () => {{
            let dir = Runtime::default_dir();
            if !Runtime::artifacts_available(&dir) {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                return;
            }
            dir
        }};
    }

    #[test]
    fn manifest_loads_and_matches_python() {
        let dir = require_artifacts!();
        let m = Manifest::load(&dir.join("manifest.csv")).unwrap();
        assert_eq!(m.feature_dim, 16);
        assert_eq!(m.opt_params, 1 + 3 * m.feature_dim);
        assert_eq!(m.knn_train_rows % 64, 0);
        assert_eq!(m.knn_query_rows % 64, 0);
        assert!(m.knn_k >= 1);
    }

    #[test]
    fn optimistic_predict_executes_and_matches_formula() {
        let dir = require_artifacts!();
        let mut rt = Runtime::load(&dir).unwrap();
        let man = rt.manifest().clone();
        // params: bias 0.5, all coefficients zero except feature0 linear = 2
        let mut params = vec![0.0f32; man.opt_params];
        params[0] = 0.5;
        params[1] = 2.0;
        let mut x = MatF32::zeros(man.opt_batch, man.feature_dim);
        x.set(0, 0, 0.25);
        x.set(1, 0, 1.0);
        let out = rt
            .execute(
                "optimistic_predict",
                &[Runtime::lit_vec(&params), Runtime::lit_mat(&x).unwrap()],
            )
            .unwrap();
        let pred = Runtime::vec_from(&out[0]).unwrap();
        assert_eq!(pred.len(), man.opt_batch);
        // log1p(0) = 0, inv term has coefficient 0 — only the linear term
        // contributes: 0.5 + 2*x
        assert!((pred[0] - 1.0).abs() < 1e-5, "{}", pred[0]);
        assert!((pred[1] - 2.5).abs() < 1e-5, "{}", pred[1]);
    }

    #[test]
    fn knn_predict_executes_exact_neighbour() {
        let dir = require_artifacts!();
        let mut rt = Runtime::load(&dir).unwrap();
        let man = rt.manifest().clone();
        let mut train_x = MatF32::zeros(man.knn_train_rows, man.feature_dim);
        let mut train_y = vec![0.0f32; man.knn_train_rows];
        let mut valid = vec![0.0f32; man.knn_train_rows];
        // 10 valid rows at distinct positions, runtime = row index
        for i in 0..10 {
            train_x.set(i, 0, i as f32);
            train_y[i] = i as f32;
            valid[i] = 1.0;
        }
        let weights = {
            let mut w = vec![0.0f32; man.feature_dim];
            w[0] = 1.0;
            w
        };
        // all queries sit exactly on training row 3
        let mut queries = MatF32::zeros(man.knn_query_rows, man.feature_dim);
        for q in 0..man.knn_query_rows {
            queries.set(q, 0, 3.0);
        }
        let out = rt
            .execute(
                "knn_predict",
                &[
                    Runtime::lit_mat(&train_x).unwrap(),
                    Runtime::lit_vec(&train_y),
                    Runtime::lit_vec(&valid),
                    Runtime::lit_vec(&weights),
                    Runtime::lit_mat(&queries).unwrap(),
                ],
            )
            .unwrap();
        let pred = Runtime::vec_from(&out[0]).unwrap();
        for &p in &pred {
            assert!((p - 3.0).abs() < 1e-2, "{p}");
        }
    }

    #[test]
    fn optimistic_train_step_reduces_loss() {
        let dir = require_artifacts!();
        let mut rt = Runtime::load(&dir).unwrap();
        let man = rt.manifest().clone();
        let n = man.opt_batch;
        // target: y = 1 + 3*x0 over x0 in [0,1]
        let mut x = MatF32::zeros(n, man.feature_dim);
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let v = i as f32 / n as f32;
            x.set(i, 0, v);
            y[i] = 1.0 + 3.0 * v;
        }
        let mask = vec![1.0f32; n];
        let mut params = vec![0.0f32; man.opt_params];
        let mut m = vec![0.0f32; man.opt_params];
        let mut v = vec![0.0f32; man.opt_params];
        let mut losses = Vec::new();
        for step in 1..=200 {
            let out = rt
                .execute(
                    "optimistic_train",
                    &[
                        Runtime::lit_vec(&params),
                        Runtime::lit_vec(&m),
                        Runtime::lit_vec(&v),
                        Runtime::lit_scalar(step as f32),
                        Runtime::lit_mat(&x).unwrap(),
                        Runtime::lit_vec(&y),
                        Runtime::lit_vec(&mask),
                        Runtime::lit_scalar(0.05),
                    ],
                )
                .unwrap();
            params = Runtime::vec_from(&out[0]).unwrap();
            m = Runtime::vec_from(&out[1]).unwrap();
            v = Runtime::vec_from(&out[2]).unwrap();
            losses.push(Runtime::vec_from(&out[3]).unwrap()[0]);
        }
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(last < 0.05 * first, "loss should collapse: {first} -> {last}");
    }

    #[test]
    fn executable_cache_hits() {
        let dir = require_artifacts!();
        let mut rt = Runtime::load(&dir).unwrap();
        rt.warmup().unwrap();
        assert_eq!(rt.compiled_count(), 3);
        // second warmup is a no-op
        rt.warmup().unwrap();
        assert_eq!(rt.compiled_count(), 3);
    }
}
