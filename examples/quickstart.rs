//! Quickstart: the C3O loop in ~40 lines of user code, written against
//! the deployment-agnostic [`Client`] protocol.
//!
//! 1. Build a simulated cloud and share a (small) corpus of historical
//!    runtime data for a Grep job — a **write**, which also trains the
//!    runtime prediction models (dynamic cross-validation selection
//!    between the pessimistic and optimistic families).
//! 2. Ask for a **read-only recommendation**: the cheapest cluster that
//!    greps 15 GB in under five minutes, scored from the shared data
//!    without running anything.
//! 3. Submit the job for real (decide → provision → run → contribute),
//!    and check the submission decided exactly what the recommendation
//!    promised.
//!
//! The `client` variable is `&mut dyn Client`: swap the sequential
//! coordinator for a `Session` or a `ServiceClient` and every line below
//! keeps working.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use c3o::prelude::*;

fn main() -> anyhow::Result<()> {
    let artifacts = c3o::runtime::Runtime::default_dir();
    if !c3o::runtime::Runtime::artifacts_available(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // A simulated public cloud (m5/c5/r5-like catalog, EMR-like delays).
    let cloud = Cloud::aws_like();

    // Historical executions shared by other organizations: here, the
    // Grep slice of the paper's 930-experiment grid.
    println!("generating shared corpus (Grep slice of Table I)...");
    let grid = ExperimentGrid::paper_table1();
    let grep_only = ExperimentGrid {
        experiments: grid
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Grep)
            .collect(),
        repetitions: 5,
    };
    let corpus = grep_only.execute(&cloud, 42);
    let shared = corpus.repo_for(JobKind::Grep);
    println!(
        "  {} records from {} organizations",
        shared.len(),
        shared.organizations().len()
    );

    // The coordinator owns models + repositories + the cloud loop; the
    // code below only speaks the protocol.
    let mut coordinator = Coordinator::new(cloud, &artifacts, 7)?;
    let client: &mut dyn Client = &mut coordinator;

    // WRITE: merge the shared data (this also trains the model that
    // serves every read below).
    client.share(shared)?;
    let info = client.snapshot_info(JobKind::Grep)?;
    println!(
        "\nsnapshot: {} records at generation {}, model {:?}",
        info.records, info.generation, info.model
    );

    // READ: a brand-new organization asks what to buy — no cluster is
    // provisioned, nothing runs.
    let request = JobRequest::grep(15.0, 0.1).with_target_seconds(300.0);
    let rec = client.recommend(request.clone())?;
    println!("\nrecommendation (read-only):");
    println!(
        "  cluster:   {} x{}  (~{:.1} s predicted, ~${:.3})",
        rec.choice.machine_type,
        rec.choice.node_count,
        rec.choice.predicted_runtime_s,
        rec.choice.expected_cost_usd
    );

    // WRITE: submit for real. The submission decides through the same
    // model snapshot, so it picks exactly the recommended cluster.
    let org = Organization::new("quickstart-org");
    let outcome = client.submit(&org, request)?;
    assert_eq!(outcome.machine, rec.choice.machine_type);
    assert_eq!(outcome.scaleout, rec.choice.node_count);

    println!("\nsubmission (full loop):");
    println!("  cluster:   {} x{}", outcome.machine, outcome.scaleout);
    println!("  predicted: {:.1} s", outcome.predicted_runtime_s);
    println!("  actual:    {:.1} s", outcome.actual_runtime_s);
    println!(
        "  error:     {:.1}%  |  met 300 s target: {}",
        outcome.prediction_error_pct(),
        outcome.met_target
    );
    println!("  cost:      ${:.3}", outcome.actual_cost_usd);

    // The run was contributed back automatically; an externally-observed
    // run would be recorded with `client.contribute(record)`.
    let after = client.snapshot_info(JobKind::Grep)?;
    println!(
        "\nshared repository grew: generation {} -> {}",
        info.generation, after.generation
    );
    Ok(())
}
