//! End-to-end driver: the full collaborative workflow of the paper on a
//! real (simulated-cloud) workload — the repository's E2E validation run,
//! recorded in EXPERIMENTS.md.
//!
//! Phases:
//!   1. **Corpus** — execute the full 930-experiment grid of Table I
//!      (5 repetitions each → 4650 simulated Spark runs), attributed to
//!      nine emulated organizations.
//!   2. **Sharing** — merge every organization's data into per-job shared
//!      repositories through the threaded coordinator session.
//!   3. **Serving** — a *new* organization (zero own history) submits 25
//!      jobs across all five algorithms with runtime targets; every
//!      decision is model-served from collaborative data (no profiling).
//!   4. **Report** — headline metrics: runtime-prediction MAPE, target
//!      hit rate, and cost vs the naive-overprovisioning strategy the
//!      paper says users fall back to.
//!   5. **Persistence + federation** — two durable coordinators with
//!      disjoint org corpora converge via SyncPull/SyncPush, and one is
//!      recovered from its segment store.
//!   6. **Record-level deltas** — after convergence, a single new
//!      measurement travels as exactly ONE sequence-numbered op on the
//!      next exchange (O(changed records), not O(org corpus)) — the
//!      paper's "continuous cheap sharing" premise at steady state.
//!   7. **Gossip mesh** — the deployments join one roster, a late peer
//!      with zero history catches up through rotating-fanout
//!      anti-entropy rounds, and the acks each round reports back let
//!      every peer fold its fully-acknowledged op-log prefix away
//!      (acked-floor truncation) — bitwise convergence intact.
//!
//! Run with: `make artifacts && cargo run --release --example collaborative_workflow`

use c3o::baselines::{ConfigSearch, NaiveMax};
use c3o::coordinator::session::Session;
use c3o::models::oracle::SimOracle;
use c3o::prelude::*;
use c3o::util::stats;

fn main() -> anyhow::Result<()> {
    let artifacts = c3o::runtime::Runtime::default_dir();
    if !c3o::runtime::Runtime::artifacts_available(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cloud = Cloud::aws_like();
    let t0 = std::time::Instant::now();

    // ---- phase 1: the shared corpus (Table I) --------------------------
    println!("[1/7] executing the 930-experiment grid (5 reps each)...");
    let grid = ExperimentGrid::paper_table1();
    let corpus = grid.execute(&cloud, 42);
    let mut orgs: std::collections::BTreeSet<String> = Default::default();
    for r in &corpus.records {
        orgs.insert(r.org.clone());
    }
    println!(
        "      {} unique experiments from {} organizations ({:.1}s)",
        corpus.len(),
        orgs.len(),
        t0.elapsed().as_secs_f64()
    );
    assert_eq!(corpus.len(), 930, "Table I count");

    // ---- phase 2: share through the coordinator session ----------------
    println!("[2/7] sharing runtime data into the coordinator...");
    let session = Session::spawn(cloud.clone(), artifacts, 7);
    for kind in JobKind::all() {
        let shared = session.share(corpus.repo_for(kind))?;
        println!("      {:>9}: {} records shared", kind.name(), shared.added);
    }

    // ---- phase 3: a new organization submits real work ------------------
    println!("[3/7] new organization submits 25 jobs (targets attached)...");
    let org = Organization::new("fresh-org");
    let battery: Vec<JobRequest> = vec![
        JobRequest::sort(11.0).with_target_seconds(500.0),
        JobRequest::sort(14.0).with_target_seconds(350.0),
        JobRequest::sort(17.5).with_target_seconds(300.0),
        JobRequest::sort(19.0).with_target_seconds(800.0),
        JobRequest::sort(12.5).with_target_seconds(250.0),
        JobRequest::grep(11.0, 0.05).with_target_seconds(200.0),
        JobRequest::grep(14.0, 0.15).with_target_seconds(240.0),
        JobRequest::grep(18.0, 0.25).with_target_seconds(400.0),
        JobRequest::grep(19.5, 0.02).with_target_seconds(300.0),
        JobRequest::grep(13.0, 0.30).with_target_seconds(350.0),
        JobRequest::sgd(12.0, 40).with_target_seconds(400.0),
        JobRequest::sgd(22.0, 60).with_target_seconds(700.0),
        JobRequest::sgd(28.0, 90).with_target_seconds(1200.0),
        JobRequest::sgd(15.0, 100).with_target_seconds(800.0),
        JobRequest::sgd(25.0, 20).with_target_seconds(500.0),
        JobRequest::kmeans(11.0, 4, 0.001).with_target_seconds(400.0),
        JobRequest::kmeans(16.0, 6, 0.001).with_target_seconds(900.0),
        JobRequest::kmeans(19.0, 8, 0.001).with_target_seconds(2000.0),
        JobRequest::kmeans(13.0, 9, 0.001).with_target_seconds(1500.0),
        JobRequest::kmeans(18.0, 3, 0.001).with_target_seconds(400.0),
        JobRequest::pagerank(150.0, 0.001).with_target_seconds(300.0),
        JobRequest::pagerank(250.0, 0.01).with_target_seconds(200.0),
        JobRequest::pagerank(350.0, 0.0001).with_target_seconds(700.0),
        JobRequest::pagerank(420.0, 0.0005).with_target_seconds(600.0),
        JobRequest::pagerank(200.0, 0.0001).with_target_seconds(500.0),
    ];

    println!(
        "      {:<9} {:>11} {:>3} {:>9} {:>9} {:>7} {:>5}",
        "job", "machine", "n", "pred_s", "actual_s", "err%", "met"
    );
    let mut errors = Vec::new();
    let mut c3o_cost = 0.0;
    let mut outcomes = Vec::new();
    for req in &battery {
        let o = session.submit(&org, req.clone())?;
        println!(
            "      {:<9} {:>11} {:>3} {:>9.1} {:>9.1} {:>7.1} {:>5}",
            o.job.name(),
            o.machine,
            o.scaleout,
            o.predicted_runtime_s,
            o.actual_runtime_s,
            o.prediction_error_pct(),
            o.met_target
        );
        assert!(o.model_used.is_some(), "every job must be model-served");
        errors.push(o.prediction_error_pct());
        c3o_cost += o.actual_cost_usd;
        outcomes.push(o);
    }

    // ---- phase 4: headline metrics --------------------------------------
    println!("[4/7] headline report");
    let metrics = session.metrics()?;
    let hit_rate = 100.0 * metrics.target_hit_rate();
    let mape = stats::mean(&errors);

    // naive-overprovisioning comparison on the same battery
    let mut naive_cost = 0.0;
    let mut naive = NaiveMax::default();
    for req in &battery {
        let mut oracle = SimOracle::new(req.kind(), 99);
        let out = naive.search(&cloud, &mut oracle, req)?;
        let q = ConfigQuery {
            machine: out.machine.clone(),
            scaleout: out.scaleout,
            job_features: req.spec.job_features(),
        };
        let mut runner = SimOracle::new(req.kind(), 123);
        let t = runner.run_once(&cloud, &q)?;
        naive_cost += cloud.cost_usd(&out.machine, out.scaleout, t + 7.0 * 60.0);
    }

    println!("      jobs served:            {}", metrics.submissions);
    println!("      model retrains:         {}", metrics.retrains);
    println!("      prediction MAPE:        {mape:.1}%");
    println!("      target hit rate:        {hit_rate:.0}%");
    println!("      total cost (C3O):       ${c3o_cost:.2}");
    println!("      total cost (naive-max): ${naive_cost:.2}");
    println!(
        "      cost saving:            {:.0}%",
        100.0 * (1.0 - c3o_cost / naive_cost)
    );
    println!("      wall clock:             {:.1}s", t0.elapsed().as_secs_f64());

    // E2E validation gates (EXPERIMENTS.md cites these)
    assert!(mape < 40.0, "MAPE {mape}% too high");
    assert!(hit_rate >= 70.0, "hit rate {hit_rate}% too low");
    assert!(c3o_cost < naive_cost, "C3O must beat overprovisioning");
    session.shutdown();

    // ---- phase 5: persistence + federation ------------------------------
    // The `c3o store` / `c3o sync` flow as a library walkthrough: two
    // organizations run their *own* durable coordinators, each persisting
    // through a segment store, and exchange runtime data through the
    // SyncPull/SyncPush protocol until both hold the identical corpus.
    // CLI equivalent:
    //   c3o store --dir /tmp/c3o-alpha --mode seed     (durable corpus)
    //   c3o sync                                        (two-service demo)
    println!("[5/7] persistence + federation walkthrough...");
    let store_alpha = std::env::temp_dir().join(format!("c3o_wf_alpha_{}", std::process::id()));
    let store_beta = std::env::temp_dir().join(format!("c3o_wf_beta_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_alpha);
    let _ = std::fs::remove_dir_all(&store_beta);
    let artifacts = c3o::runtime::Runtime::default_dir();

    // each org contributes its half of the sort corpus, durably
    let sort_repo = corpus.repo_for(JobKind::Sort);
    let half = sort_repo.len() / 2;
    let relabel = |records: &[RuntimeRecord], org: &str| -> RuntimeDataRepo {
        RuntimeDataRepo::from_records(JobKind::Sort, records.iter().map(|r| r.with_org(org)))
    };
    let mut alpha =
        Coordinator::open_with_store(cloud.clone(), &artifacts, 71, &store_alpha)?;
    let mut beta = Coordinator::open_with_store(cloud.clone(), &artifacts, 72, &store_beta)?;
    alpha.share(&relabel(&sort_repo.records()[..half], "org-alpha"))?;
    beta.share(&relabel(&sort_repo.records()[half..], "org-beta"))?;

    // gossip until quiescent (here: one bidirectional exchange)
    let sort_only = SyncOptions {
        scope: SyncScope::Job(JobKind::Sort),
        ..SyncOptions::default()
    };
    let stats = c3o::store::sync(&mut alpha, &mut beta, &sort_only)?.stats;
    println!(
        "      sync moved {} records ({} conflicts); generations {} / {}",
        stats.records_in + stats.records_out,
        stats.conflicts,
        alpha.generation(JobKind::Sort),
        beta.generation(JobKind::Sort),
    );
    assert_eq!(
        alpha.repo(JobKind::Sort).unwrap().records(),
        beta.repo(JobKind::Sort).unwrap().records(),
        "converged peers hold bitwise-identical repositories"
    );

    // durability: drop alpha entirely and recover it from its store —
    // corpus, generation, op logs, and a warm model, before any new write
    let gen_before = alpha.generation(JobKind::Sort);
    drop(alpha);
    let mut recovered =
        Coordinator::open_with_store(cloud.clone(), &artifacts, 71, &store_alpha)?;
    assert_eq!(recovered.generation(JobKind::Sort), gen_before);
    let rec = recovered.recommend(&JobRequest::sort(14.0).with_target_seconds(600.0))?;
    println!(
        "      recovered coordinator at generation {} recommends {} x{}",
        gen_before, rec.choice.machine_type, rec.choice.node_count
    );

    // ---- phase 6: record-level deltas at steady state --------------------
    // The converged federation now lives its real life: occasionally one
    // new measurement lands somewhere. With the per-(org, job) op log,
    // the next exchange ships exactly that op — not the whole org corpus.
    println!("[6/7] record-level delta: one new measurement, one shipped op...");
    recovered.contribute(RuntimeRecord {
        job: JobKind::Sort,
        org: "org-alpha".to_string(),
        machine: "m5.xlarge".to_string(),
        scaleout: 6,
        job_features: vec![23.75],
        runtime_s: 411.0,
    })?;
    let stats = c3o::store::sync(&mut recovered, &mut beta, &sort_only)?.stats;
    println!(
        "      exchange shipped {} op(s), applied {}, skipped {}",
        stats.offered,
        stats.records_in + stats.records_out,
        stats.skipped
    );
    assert_eq!(stats.offered, 1, "exactly the changed record ships");
    assert_eq!(stats.records_in + stats.records_out, 1);
    let quiet = c3o::store::sync(&mut recovered, &mut beta, &sort_only)?.stats;
    assert!(quiet.quiescent() && quiet.offered == 0, "then silence");
    // the contributor appended locally (no reorder); the receiver
    // canonicalized on apply — content is identical, compared in the
    // canonical form
    assert_eq!(
        recovered.repo(JobKind::Sort).unwrap().canonical_records(),
        beta.repo(JobKind::Sort).unwrap().canonical_records(),
        "peers hold identical corpora again"
    );

    // ---- phase 7: gossip mesh + acked-floor truncation -------------------
    // The deployments stop hand-wiring peer lists. Each carries a mesh
    // roster; anti-entropy rounds pick rotating fanout targets from it,
    // the batched (v4) exchange covers every job kind in one
    // conversation, and the acks each round reports back let every peer
    // fold the fully-acknowledged prefix of its op logs into the base
    // snapshots. A brand-new deployment joins by hello and catches up.
    // CLI equivalent:  c3o mesh --peers 3 --fanout 1
    println!("[7/7] gossip mesh: roster join, anti-entropy rounds, log truncation...");
    recovered.set_mesh_name("org-alpha");
    beta.set_mesh_name("org-beta");
    let mut gamma = Coordinator::with_engine(cloud.clone(), Engine::native(), 73);
    gamma.set_mesh_name("org-gamma");

    // one hello carrying the full member list introduces the roster
    // (gossip-joined members are live, so fanout targeting works at once)
    let roster: Vec<MeshPeer> = ["org-alpha", "org-beta", "org-gamma"]
        .iter()
        .map(|name| c3o::store::mesh_peer(name))
        .collect();
    recovered.mesh_hello(MeshHello {
        from: roster[1].clone(),
        known: roster.clone(),
        acked: Vec::new(),
    })?;
    beta.mesh_hello(MeshHello {
        from: roster[2].clone(),
        known: roster.clone(),
        acked: Vec::new(),
    })?;
    gamma.mesh_hello(MeshHello {
        from: roster[0].clone(),
        known: roster.clone(),
        acked: Vec::new(),
    })?;

    /// One sweep: every deployment runs one anti-entropy round against
    /// the rest of the roster. Returns (records changed, round trips).
    fn mesh_sweep3(
        alpha: &mut Coordinator,
        beta: &mut Coordinator,
        gamma: &mut Coordinator,
    ) -> Result<(u64, u64), ApiError> {
        let (mut changed, mut trips) = (0u64, 0u64);
        {
            let mut refs: Vec<(String, &mut dyn Client)> = vec![
                ("org-beta".into(), &mut *beta),
                ("org-gamma".into(), &mut *gamma),
            ];
            let r = mesh_round(alpha, &mut refs, 1)?;
            changed += r.changed;
            trips += r.peer_round_trips;
        }
        {
            let mut refs: Vec<(String, &mut dyn Client)> = vec![
                ("org-alpha".into(), &mut *alpha),
                ("org-gamma".into(), &mut *gamma),
            ];
            let r = mesh_round(beta, &mut refs, 1)?;
            changed += r.changed;
            trips += r.peer_round_trips;
        }
        {
            let mut refs: Vec<(String, &mut dyn Client)> = vec![
                ("org-alpha".into(), &mut *alpha),
                ("org-beta".into(), &mut *beta),
            ];
            let r = mesh_round(gamma, &mut refs, 1)?;
            changed += r.changed;
            trips += r.peer_round_trips;
        }
        Ok((changed, trips))
    }

    let (mut moved, mut trips) = (0u64, 0u64);
    let mut converged = false;
    for _ in 0..16 {
        let (changed, t) = mesh_sweep3(&mut recovered, &mut beta, &mut gamma)?;
        moved += changed;
        trips += t;
        let reference = recovered.repo(JobKind::Sort).unwrap().content_digest();
        let agree = [&beta, &gamma]
            .iter()
            .all(|p| p.repo(JobKind::Sort).map(|r| r.content_digest()) == Some(reference));
        if changed == 0 && agree {
            converged = true;
            break;
        }
    }
    assert!(converged, "mesh did not converge");
    // a few extra sweeps: acks finish propagating, self-ticks truncate
    for _ in 0..8 {
        let (_, t) = mesh_sweep3(&mut recovered, &mut beta, &mut gamma)?;
        trips += t;
    }

    // the late joiner holds the identical corpus — bitwise — and every
    // peer's op logs folded down to the unacked suffix (empty)
    assert_eq!(
        gamma.repo(JobKind::Sort).unwrap().canonical_records(),
        recovered.repo(JobKind::Sort).unwrap().canonical_records()
    );
    assert_eq!(
        gamma.repo(JobKind::Sort).unwrap().content_digest(),
        recovered.repo(JobKind::Sort).unwrap().content_digest()
    );
    let peers = [&recovered, &beta, &gamma];
    let truncated: u64 = peers.iter().map(|p| p.metrics().ops_truncated).sum();
    let retained: usize = peers
        .iter()
        .map(|p| p.repo(JobKind::Sort).unwrap().retained_log_entries())
        .sum();
    assert!(truncated > 0, "acked floors truncated the op logs");
    assert_eq!(retained, 0, "only the unacked suffix is retained");
    println!(
        "      3-peer mesh converged: {moved} records to the late joiner, {trips} peer round trips"
    );
    println!(
        "      acked-floor truncation folded {truncated} ops; retained log entries: {retained}"
    );
    let _ = std::fs::remove_dir_all(&store_alpha);
    let _ = std::fs::remove_dir_all(&store_beta);

    println!("\nE2E validation PASSED");
    Ok(())
}
